#!/usr/bin/env python
"""Static-analysis driver: AST lint + jaxpr program audit.

Usage:
    PYTHONPATH=src python tools/analyze.py --check        # lint + audit (CI)
    PYTHONPATH=src python tools/analyze.py --lint         # level 1 only
    PYTHONPATH=src python tools/analyze.py --audit        # level 2 only
    PYTHONPATH=src python tools/analyze.py --audit --no-donation
    PYTHONPATH=src python tools/analyze.py --update-baseline
    PYTHONPATH=src python tools/analyze.py --lint --baseline /dev/null

Exit codes (docs/analysis.md): 0 clean; when every non-baselined finding
shares one rule, that rule's distinct code (RA101→11 … RA106→16,
RA201→21 … RA204→24); 1 for mixed-rule findings. CI greps the code to
tell failure classes apart.

The baseline (``src/repro/analysis/baseline.json``) suppresses accepted
pre-existing findings by (code, path, stripped-line) fingerprint;
``--update-baseline`` regenerates it from the current tree. Sanctioned
sites prefer an inline ``# ra: allow[RAxxx] reason`` comment instead.
"""
from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = p.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="lint + audit (the CI default)")
    mode.add_argument("--lint", action="store_true", help="AST lint only")
    mode.add_argument("--audit", action="store_true",
                      help="jaxpr audit only")
    mode.add_argument("--update-baseline", action="store_true",
                      help="accept all current LINT findings into the "
                           "baseline (audit findings are never "
                           "baselined: the program invariants hold or "
                           "the build is broken)")
    p.add_argument("--baseline", default=None,
                   help="baseline path (default: the checked-in one; "
                        "/dev/null disables suppression)")
    p.add_argument("--no-donation", action="store_true",
                   help="skip the RA204 donation compile (~10 s) in the "
                        "audit")
    args = p.parse_args(argv)

    from repro.analysis import (exit_code_for, load_baseline, run_audit,
                                run_lint, save_baseline, split_baselined)

    do_lint = args.lint or args.check or args.update_baseline \
        or not (args.lint or args.audit)
    do_audit = args.audit or args.check or not (
        args.lint or args.audit or args.update_baseline)

    findings = []
    if do_lint:
        lint_findings = run_lint(REPO_ROOT)
        if args.update_baseline:
            path = save_baseline(lint_findings, args.baseline)
            print(f"baseline: {len(lint_findings)} suppression(s) "
                  f"written to {path}")
            return 0
        findings += lint_findings
    if do_audit:
        findings += run_audit(donation=not args.no_donation)

    new, baselined = split_baselined(findings, load_baseline(args.baseline))
    for f in new:
        print(f.render())
    tag = f" ({len(baselined)} baselined)" if baselined else ""
    print(f"analyze: {len(new)} finding(s){tag} — "
          f"{'FAIL' if new else 'ok'}")
    return exit_code_for(new)


if __name__ == "__main__":
    sys.exit(main())
