#!/usr/bin/env python
"""Render a per-client attribution report from a flight recording.

Stdlib-only (like tools/report_run.py): the ``ledger.npz`` written by
``repro.telemetry.ledger.FlightRecorder`` is a zip of ``.npy`` members,
parsed here with ``zipfile`` + ``struct`` so the report runs anywhere —
no numpy, no jax, no repo install.

Sections:
  - run summary (rounds, cohort size, wire bytes/client)
  - top-k drifters: clients ranked by mean drift contribution
    (the per-client Fig. 2 decomposition — docs/paper_map.md)
  - rejection timeline: rounds where any upload was dropped/rejected,
    with reason codes
  - bytes-per-client histogram: who dominates the wire
  - ``--compare OTHER_DIR``: per-client drift/bytes deltas vs a second
    recording (same population ids matched by client_id)

Usage: python tools/ledger_report.py LEDGER_DIR [--compare DIR] [--top K]
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import struct
import sys
import zipfile

LEDGER_NPZ = "ledger.npz"
LEDGER_MANIFEST = "ledger_manifest.json"


# ------------------------------------------------------- npy/npz parsing

def _parse_npy(data: bytes):
    """Minimal .npy v1/v2 reader -> (shape, flat list of python nums)."""
    if data[:6] != b"\x93NUMPY":
        raise ValueError("not a .npy payload")
    major = data[6]
    if major == 1:
        (hlen,) = struct.unpack("<H", data[8:10])
        off = 10
    else:
        (hlen,) = struct.unpack("<I", data[8:12])
        off = 12
    header = ast.literal_eval(data[off:off + hlen].decode("latin1"))
    if header.get("fortran_order"):
        raise ValueError("fortran-order arrays unsupported")
    descr, shape = header["descr"], tuple(header["shape"])
    fmt = {"<f4": "f", "<f8": "d", "<i4": "i", "<i8": "q",
           "|b1": "?", "<u4": "I", "<u8": "Q"}[descr]
    count = 1
    for d in shape:
        count *= d
    body = data[off + hlen:]
    vals = list(struct.unpack(
        "<%d%s" % (count, fmt), body[:count * struct.calcsize(fmt)]))
    return shape, vals


def load_recording(ledger_dir: str) -> dict:
    """-> {manifest, rounds: [int], shape: (R, S, C), stats: flat list}"""
    with open(os.path.join(ledger_dir, LEDGER_MANIFEST)) as fh:
        manifest = json.load(fh)
    with zipfile.ZipFile(os.path.join(ledger_dir, LEDGER_NPZ)) as zf:
        _, rounds = _parse_npy(zf.read("rounds.npy"))
        shape, stats = _parse_npy(zf.read("stats.npy"))
    return {"manifest": manifest, "rounds": [int(r) for r in rounds],
            "shape": shape, "stats": stats}


def _cell(rec: dict, r: int, s: int, col: str) -> float:
    R, S, C = rec["shape"]
    c = rec["manifest"]["columns"].index(col)
    return rec["stats"][(r * S + s) * C + c]


def per_client(rec: dict) -> dict:
    """client_id -> {rounds, steps, drift_sum, upload_sum, bytes,
    clipped, dropped, rejected}"""
    R, S, _ = rec["shape"]
    out: dict = {}
    for r in range(R):
        for s in range(S):
            cid = int(_cell(rec, r, s, "client_id"))
            d = out.setdefault(cid, {
                "rounds": 0, "steps": 0.0, "drift_sum": 0.0,
                "upload_sum": 0.0, "bytes": 0.0, "clipped": 0,
                "dropped": 0, "rejected": 0})
            d["rounds"] += 1
            d["steps"] += _cell(rec, r, s, "steps")
            d["drift_sum"] += _cell(rec, r, s, "drift_sq")
            d["upload_sum"] += _cell(rec, r, s, "upload_l2")
            d["bytes"] += _cell(rec, r, s, "wire_bytes")
            d["clipped"] += int(_cell(rec, r, s, "dp_clipped"))
            v = _cell(rec, r, s, "verdict")
            if v == 1.0:
                d["dropped"] += 1
            elif v == 2.0:
                d["rejected"] += 1
    return out


# --------------------------------------------------------------- report

def _fmt(v, nd=3):
    return f"{v:.{nd}f}" if isinstance(v, float) else str(v)


def _table(rows, headers) -> list:
    cols = [headers] + [[_fmt(c) for c in row] for row in rows]
    widths = [max(len(r[i]) for r in cols) for i in range(len(headers))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for row in cols[1:]:
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
    return lines


def _histogram(items, width=30) -> list:
    top = max((v for _, v in items), default=0.0)
    lines = []
    for label, v in items:
        bar = "#" * (int(width * v / top) if top else 0)
        lines.append(f"  {label:>10}  {v:>14.0f}  {bar}")
    return lines


def report(ledger_dir: str, compare_dir: str = "", top: int = 10) -> str:
    rec = load_recording(ledger_dir)
    man = rec["manifest"]
    R, S, C = rec["shape"]
    inv_verdict = {float(v): k for k, v in man["verdict_codes"].items()}
    inv_inject = {float(v): k for k, v in man["injected_codes"].items()}
    out = [f"# flight recording: {ledger_dir}", ""]
    out.append(f"rounds recorded      {R}")
    out.append(f"clients per round    {S}")
    out.append(f"wire bytes/client    {man['wire_bytes_per_client']}")
    meta = man.get("meta", {})
    if meta:
        out.append("meta                 " + ", ".join(
            f"{k}={v}" for k, v in sorted(meta.items())))
    clients = per_client(rec)

    out += ["", f"## top {top} drifters (mean per-round drift "
                "contribution — Fig. 2 decomposition per client)"]
    ranked = sorted(clients.items(),
                    key=lambda kv: -kv[1]["drift_sum"] / kv[1]["rounds"])
    rows = [(cid, d["rounds"], _fmt(d["drift_sum"] / d["rounds"], 5),
             _fmt(d["upload_sum"] / d["rounds"], 4),
             d["clipped"], d["dropped"], d["rejected"])
            for cid, d in ranked[:top]]
    out += _table(rows, ["client", "rounds", "mean_drift_sq",
                         "mean_upload_l2", "clipped", "dropped",
                         "rejected"])

    out += ["", "## rejection timeline (rounds with non-accepted "
                "verdicts)"]
    events = []
    for r in range(R):
        bad = {}
        for s in range(S):
            v = _cell(rec, r, s, "verdict")
            if v != 0.0:
                cid = int(_cell(rec, r, s, "client_id"))
                inj = inv_inject.get(
                    _cell(rec, r, s, "fault_injected"), "?")
                bad.setdefault(inv_verdict.get(v, "?"), []).append(
                    f"{cid}({inj})")
        if bad:
            events.append(f"  round {rec['rounds'][r]:>4}:  " + "; ".join(
                f"{verdict}: {', '.join(cl)}"
                for verdict, cl in sorted(bad.items())))
    out += events if events else ["  (none — every upload accepted)"]

    out += ["", "## wire bytes per client (total over recording)"]
    byte_items = sorted(((f"client {cid}", d["bytes"])
                         for cid, d in clients.items()),
                        key=lambda kv: -kv[1])
    out += _histogram(byte_items[:top])

    if compare_dir:
        other = per_client(load_recording(compare_dir))
        out += ["", f"## compare vs {compare_dir} "
                    "(this-run minus other-run, shared clients)"]
        shared = sorted(set(clients) & set(other))
        rows = []
        for cid in shared:
            a, b = clients[cid], other[cid]
            rows.append((cid,
                         _fmt(a["drift_sum"] / a["rounds"]
                              - b["drift_sum"] / b["rounds"], 5),
                         _fmt(a["bytes"] - b["bytes"], 0),
                         a["rejected"] - b["rejected"]))
        out += _table(rows, ["client", "d_mean_drift_sq", "d_bytes",
                             "d_rejected"])
        only = sorted(set(clients) ^ set(other))
        if only:
            out.append(f"  clients in one run only: {only}")
    return "\n".join(out) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("ledger_dir", help="directory with ledger.npz + "
                                       "ledger_manifest.json")
    ap.add_argument("--compare", default="",
                    help="second recording to diff against")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the ranked sections")
    args = ap.parse_args(argv)
    for d in filter(None, (args.ledger_dir, args.compare)):
        if not os.path.exists(os.path.join(d, LEDGER_MANIFEST)):
            print(f"ledger_report: no {LEDGER_MANIFEST} in {d}",
                  file=sys.stderr)
            return 2
    print(report(args.ledger_dir, compare_dir=args.compare,
                 top=args.top), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
