#!/usr/bin/env python
"""Markdown link checker: every relative link/anchor target must exist.

Usage: python tools/check_links.py README.md CHANGES.md docs/*.md

Checks inline ``[text](target)`` links in the given markdown files:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* relative targets must resolve to an existing file or directory,
  relative to the markdown file that references them;
* ``#fragment``-only links are accepted (same-page anchors).

Exit code 0 when every link resolves, 1 otherwise (one line per broken
link). Used by the CI ``docs`` job and ``tests/test_docs.py`` so the
docs can't rot silently.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List, Tuple

# inline links, skipping images' leading ! is harmless (same target rule)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def iter_links(md_path: Path) -> List[Tuple[int, str]]:
    """(line_number, target) for every inline link outside code fences."""
    out: List[Tuple[int, str]] = []
    in_fence = False
    for i, line in enumerate(md_path.read_text().splitlines(), start=1):
        if _CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            out.append((i, m.group(1)))
    return out


def broken_links(md_path: Path) -> List[str]:
    """Human-readable description of each broken link in one file."""
    problems = []
    for lineno, target in iter_links(md_path):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            continue  # same-page anchor
        path_part = target.split("#", 1)[0]
        resolved = (md_path.parent / path_part)
        if not resolved.exists():
            problems.append(
                f"{md_path}:{lineno}: broken link -> {target}")
    return problems


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: check_links.py <file.md> [...]", file=sys.stderr)
        return 2
    problems: List[str] = []
    checked = 0
    for name in argv:
        p = Path(name)
        if not p.exists():
            problems.append(f"{name}: file not found")
            continue
        checked += 1
        problems.extend(broken_links(p))
    for line in problems:
        print(line, file=sys.stderr)
    print(f"checked {checked} markdown file(s): "
          f"{'OK' if not problems else f'{len(problems)} broken link(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
