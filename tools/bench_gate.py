#!/usr/bin/env python
"""CI perf-regression gate over the committed benchmark baselines.

Re-measures ``benchmarks/round_throughput.py`` (interleaved reps,
min-of-reps — the benchmark's own noise discipline) and compares the
fresh report against the committed ``BENCH_round_throughput.json`` with
explicit tolerances; optionally audits the uploadfuse fusion-bytes
ratio against ``benchmarks/out/roofline_fusion.json``. Exit 0 = green,
1 = regression (with an actionable per-check diff), 2 = usage error.

Checks
------
C1  parity       fresh ``parity_bitexact`` must be True — the
                 pipelined/fused engines drifted from the eager
                 trajectory. Machine-independent, always enforced.
C2  speedup      fresh ``speedup_pipelined_fused_vs_eager`` must be at
                 least ``(1 - tol-speedup)`` of the baseline's. Only
                 comparable when the measurement CONFIG matches the
                 baseline's (smoke-scale CI runs vs a full-scale
                 committed baseline measure different dispatch/compute
                 ratios); skipped with a note otherwise.
C3  rounds/s     per-mode absolute throughput within ``tol`` of the
                 baseline. Absolute rounds/s only transfer between
                 identical machines AND configs, so this check is
                 skipped (with a note) unless both fingerprints match.
C4  bytes ratio  fused-interface vs separate-pass bytes from the
                 roofline fusion audit: a program property (machine
                 independent), so the fused interface must stay
                 strictly smaller and the ratio within ``tol-bytes``
                 of the committed audit. Enabled via ``--roofline``.

``--update-baseline`` re-measures at FULL scale and rewrites the
baseline JSON. ``--selftest-regression F`` is the CI red-canary: it
perturbs a fresh measurement by slowing every mode by fraction F and
exits 0 only if the gate correctly goes red — proving the gate can
fail.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "BENCH_round_throughput.json")
DEFAULT_ROOFLINE = os.path.join(REPO, "benchmarks", "out",
                                "roofline_fusion.json")

MODES = ("eager", "pipelined", "pipelined_fused")


# --------------------------------------------------------- measurement

def measure_throughput(smoke: bool = True) -> dict:
    """Fresh interleaved-reps measurement via the benchmark's own
    driver (which asserts bit-exact trajectory parity internally)."""
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    sys.path.insert(0, os.path.join(REPO, "src"))
    from round_throughput import Bench
    report, _speedup = Bench(smoke=smoke).run()
    return report


def measure_fusion_audit(smoke: bool = True) -> dict:
    """Fresh uploadfuse fusion-bytes audit (program properties — no
    timing involved)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.path.insert(0, REPO)  # roofline_report imports benchmarks.common
    from benchmarks import roofline_report
    return roofline_report._fusion_audit(smoke=smoke)


# --------------------------------------------------------- comparison

def _machine_match(fresh: dict, base: dict) -> bool:
    return fresh.get("machine") == base.get("machine")


def _config_match(fresh: dict, base: dict) -> bool:
    return fresh.get("config") == base.get("config")


def compare_reports(fresh: dict, base: dict, *, tol: float = 0.15,
                    tol_speedup: float = 0.5):
    """Return ``(ok, lines)`` — the gate verdict plus the per-check
    diff table (one line per check, PASS/FAIL/SKIP prefixed)."""
    lines = []
    ok = True

    # C1: parity is sacred — and machine-independent
    parity = bool(fresh.get("parity_bitexact", False))
    lines.append(f"{'PASS' if parity else 'FAIL'}  C1 parity_bitexact: "
                 f"fresh={parity} (required: True)")
    ok &= parity

    cfg_match = _config_match(fresh, base)
    m_match = _machine_match(fresh, base)

    # C2: fusion speedup ratio (needs a config match — smoke-scale
    # blocks amortize dispatch differently than the full-scale baseline)
    f_spd = float(fresh.get("speedup_pipelined_fused_vs_eager", 0.0))
    b_spd = float(base.get("speedup_pipelined_fused_vs_eager", 0.0))
    if cfg_match and b_spd > 0:
        floor = max(1.0, b_spd * (1.0 - tol_speedup))
        good = f_spd >= floor
        lines.append(
            f"{'PASS' if good else 'FAIL'}  C2 speedup: fresh={f_spd:.2f} "
            f"baseline={b_spd:.2f} floor={floor:.2f} "
            f"(tol-speedup={tol_speedup})")
        ok &= good
    else:
        lines.append(
            f"SKIP  C2 speedup: config mismatch vs baseline "
            f"(fresh smoke={fresh.get('config', {}).get('smoke')}, "
            f"baseline smoke={base.get('config', {}).get('smoke')}) — "
            f"informational: fresh={f_spd:.2f} baseline={b_spd:.2f}")

    # C3: absolute per-mode rounds/s (needs machine AND config match)
    if m_match and cfg_match:
        for mode in MODES:
            f_rs = float(fresh["modes"][mode]["rounds_per_s"])
            b_rs = float(base["modes"][mode]["rounds_per_s"])
            floor = b_rs * (1.0 - tol)
            good = f_rs >= floor
            pct = 100.0 * (f_rs - b_rs) / b_rs if b_rs else 0.0
            lines.append(
                f"{'PASS' if good else 'FAIL'}  C3 {mode}: "
                f"fresh={f_rs:.1f} r/s baseline={b_rs:.1f} r/s "
                f"({pct:+.1f}%, floor={floor:.1f}, tol={tol})")
            ok &= good
    else:
        why = ("machine" if not m_match else "config")
        lines.append(
            f"SKIP  C3 rounds/s: {why} fingerprint mismatch vs baseline "
            f"(absolute throughput only transfers between identical "
            f"machines and configs)")

    return ok, lines


def compare_fusion(fresh: dict, base: dict, *, tol_bytes: float = 0.25):
    """``(ok, lines)`` for the roofline fusion-bytes check (C4)."""
    lines = []
    fused = float(fresh["fused_interface_bytes"])
    sep = float(fresh["separate_pass_bytes"])
    strict = fused < sep
    lines.append(f"{'PASS' if strict else 'FAIL'}  C4 fusion invariant: "
                 f"fused={fused:.0f} B < separate={sep:.0f} B")
    ok = strict
    f_ratio = sep / max(fused, 1.0)
    b_ratio = float(base.get("separate_over_fused", 0.0))
    if b_ratio > 0:
        floor = b_ratio * (1.0 - tol_bytes)
        good = f_ratio >= floor
        lines.append(
            f"{'PASS' if good else 'FAIL'}  C4 bytes ratio: "
            f"fresh={f_ratio:.2f}x baseline={b_ratio:.2f}x "
            f"floor={floor:.2f}x (tol-bytes={tol_bytes})")
        ok &= good
    return ok, lines


def perturb_report(report: dict, slowdown: float) -> dict:
    """A copy of ``report`` with every mode slowed by ``slowdown``
    (e.g. 0.25 = 25% fewer rounds/s) — the red-canary input."""
    out = json.loads(json.dumps(report))
    # every mode slows equally, so the C2 speedup ratio survives — the
    # canary exercises the absolute C3 check, which is the point
    for mode in out.get("modes", {}):
        out["modes"][mode]["rounds_per_s"] *= (1.0 - slowdown)
    return out


# --------------------------------------------------------------- main

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed throughput baseline JSON")
    ap.add_argument("--roofline", default="",
                    help="committed roofline_fusion.json to audit the "
                         "fusion bytes ratio against (C4); empty = skip")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative rounds/s tolerance for C3 "
                         "(default 0.15 = red at >15%% slowdown)")
    ap.add_argument("--tol-speedup", type=float, default=0.5,
                    help="relative tolerance on the fusion speedup "
                         "ratio for C2")
    ap.add_argument("--tol-bytes", type=float, default=0.25,
                    help="relative tolerance on the fusion bytes "
                         "ratio for C4")
    ap.add_argument("--full", action="store_true",
                    help="measure at full scale instead of smoke")
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-measure at FULL scale and rewrite "
                         "--baseline instead of gating")
    ap.add_argument("--selftest-regression", type=float, default=0.0,
                    metavar="FRAC",
                    help="red-canary: perturb a fresh measurement by "
                         "this slowdown fraction and require the gate "
                         "to go RED (exit 0 iff it does)")
    args = ap.parse_args(argv)

    if args.update_baseline:
        report = measure_throughput(smoke=False)
        tmp = args.baseline + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
        os.replace(tmp, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"bench_gate: baseline not found: {args.baseline}",
              file=sys.stderr)
        return 2

    with open(args.baseline) as fh:
        base = json.load(fh)
    fresh = measure_throughput(smoke=not args.full)

    if args.selftest_regression > 0.0:
        # compare the perturbed fresh report against the UNPERTURBED
        # fresh one — machine and config match by construction, so the
        # absolute check C3 is live and must trip
        hurt = perturb_report(fresh, args.selftest_regression)
        ok, lines = compare_reports(hurt, fresh, tol=args.tol,
                                    tol_speedup=args.tol_speedup)
        print(f"bench_gate self-test (injected "
              f"{100 * args.selftest_regression:.0f}% slowdown):")
        print("\n".join("  " + ln for ln in lines))
        if ok:
            print("SELF-TEST FAILED: the gate stayed green on an "
                  "injected regression — it cannot catch real ones",
                  file=sys.stderr)
            return 1
        print("self-test ok: gate goes red on injected regression")
        return 0

    ok, lines = compare_reports(fresh, base, tol=args.tol,
                                tol_speedup=args.tol_speedup)
    if args.roofline:
        if not os.path.exists(args.roofline):
            print(f"bench_gate: roofline baseline not found: "
                  f"{args.roofline}", file=sys.stderr)
            return 2
        with open(args.roofline) as fh:
            roof_base = json.load(fh)
        roof_fresh = measure_fusion_audit(smoke=True)
        ok4, lines4 = compare_fusion(roof_fresh, roof_base,
                                     tol_bytes=args.tol_bytes)
        ok &= ok4
        lines += lines4

    print(f"bench_gate vs {os.path.relpath(args.baseline, REPO)}:")
    print("\n".join("  " + ln for ln in lines))
    if not ok:
        print("\nPERF REGRESSION: one or more checks failed. If the "
              "slowdown is intended (e.g. a correctness fix), refresh "
              "the baseline with: python tools/bench_gate.py "
              "--update-baseline", file=sys.stderr)
        return 1
    print("bench_gate: green")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
