#!/usr/bin/env python
"""Run-summary report from a telemetry trace directory.

Usage: python tools/report_run.py <trace_dir> [--csv metrics.csv]

Reads the ``trace.json`` + ``counters.json`` a ``--trace-dir`` run of
``repro.launch.train`` exported (docs/observability.md) and prints:

* the counter/gauge snapshot (wire bytes, cohort size, DP epsilon, ...);
* per-span aggregates (count / total / mean / max ms) from the trace,
  host spans and trace-time ("trace/...") spans separated;
* derived ratios: ``host_blocked_frac`` (consumer wait over traced
  wall) and producer utilization;
* resilience/privacy families when present (``faults/*``, ``dp/*``,
  ``watchdog/*``, quorum skips) with derived rejection-rate and
  quorum-skip-rate;
* a pointer to the flight-recorder ledger when one sits next to the
  trace (or via ``--ledger``) — drill in with tools/ledger_report.py;
* optionally, the final rows of the run's metrics CSV.

Stdlib only — usable on any box that has the artifacts, no jax needed.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import sys
from typing import Any, Dict, List


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if abs(x) >= 1e6 or 0 < abs(x) < 1e-3:
        return f"{x:.3e}"
    return f"{x:,.3f}".rstrip("0").rstrip(".")


def _table(rows: List[List[str]], headers: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in rows + [headers])
              for i in range(len(headers))]
    def line(vals):  # noqa: E306
        return "  ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def span_aggregates(events: List[Dict[str, Any]]) -> Dict[str, Dict]:
    agg: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        st = agg.setdefault(ev["name"],
                            {"count": 0, "total_ms": 0.0, "max_ms": 0.0})
        d = ev.get("dur", 0.0) / 1e3
        st["count"] += 1
        st["total_ms"] += d
        st["max_ms"] = max(st["max_ms"], d)
    return agg


#: counter families surfaced in the resilience/privacy section when any
#: member is present in the snapshot (names from telemetry.registry
#: CANONICAL_METRICS — see docs/observability.md)
RESILIENCE_FAMILIES = (
    "faults/injected", "faults/rejected_uploads",
    "rounds/quorum_skipped", "watchdog/rollbacks", "dp/epsilon",
)


def resilience_section(counters: Dict[str, float]) -> List[str]:
    """Lines for the faults/DP/watchdog families, with derived rates;
    empty when none of the families were emitted by the run."""
    present = [k for k in RESILIENCE_FAMILIES if k in counters]
    if not present:
        return []
    rows = [[k, _fmt(counters[k])] for k in present]
    rounds = counters.get("rounds/completed", 0.0)
    cohort = counters.get("round/cohort_size", 0.0)
    uploads = rounds * cohort
    if uploads > 0 and "faults/rejected_uploads" in counters:
        rows.append(["rejection_rate",
                     _fmt(counters["faults/rejected_uploads"] / uploads)])
    if rounds > 0 and "rounds/quorum_skipped" in counters:
        rows.append(["quorum_skip_rate",
                     _fmt(counters["rounds/quorum_skipped"] / rounds)])
    return ["## resilience / privacy",
            _table(rows, ["name", "value"]), ""]


def report(trace_dir: str, csv_path: str = "",
           ledger_dir: str = "") -> str:
    out: List[str] = [f"# run report: {trace_dir}", ""]
    counters_path = os.path.join(trace_dir, "counters.json")
    trace_path = os.path.join(trace_dir, "trace.json")

    counters: Dict[str, float] = {}
    if os.path.exists(counters_path):
        with open(counters_path) as fh:
            counters = json.load(fh)
        out.append("## counters")
        out.append(_table([[k, _fmt(v)] for k, v in sorted(counters.items())],
                          ["name", "value"]))
        out.append("")
        out.extend(resilience_section(counters))

    if os.path.exists(trace_path):
        with open(trace_path) as fh:
            doc = json.load(fh)
        events = doc.get("traceEvents", [])
        spans = [e for e in events if e.get("ph") == "X"]
        agg = span_aggregates(spans)
        rows = [[name, int(st["count"]), _fmt(st["total_ms"]),
                 _fmt(st["total_ms"] / st["count"]), _fmt(st["max_ms"])]
                for name, st in sorted(
                    agg.items(), key=lambda kv: -kv[1]["total_ms"])]
        out.append("## spans")
        out.append(_table(rows, ["span", "count", "total_ms", "mean_ms",
                                 "max_ms"]))
        out.append("")
        if spans:
            wall_ms = max(e["ts"] + e.get("dur", 0) for e in spans) / 1e3
            wait_ms = counters.get("prefetch/wait_s", 0.0) * 1e3
            produce_ms = counters.get("prefetch/produce_s", 0.0) * 1e3
            out.append("## derived")
            out.append(_table([
                ["traced_wall_ms", _fmt(wall_ms)],
                ["host_blocked_frac", _fmt(wait_ms / max(wall_ms, 1e-9))],
                ["producer_util", _fmt(produce_ms / max(wall_ms, 1e-9))],
            ], ["quantity", "value"]))
            out.append("")
        out.append(f"open {trace_path} in https://ui.perfetto.dev "
                   "or chrome://tracing")
        out.append("")

    # flight recorder: link the ledger if one sits in --ledger or next
    # to the trace (train.py exports it at the same shutdown boundary)
    for cand in filter(None, (ledger_dir, trace_dir)):
        manifest_path = os.path.join(cand, "ledger_manifest.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as fh:
                man = json.load(fh)
            out.append("## flight recorder")
            out.append(_table([
                ["ledger_dir", cand],
                ["rounds_recorded", str(man.get("rounds_recorded", 0))],
                ["clients_per_round", str(man.get("clients_per_round", 0))],
                ["wire_bytes_per_client",
                 _fmt(float(man.get("wire_bytes_per_client", 0)))],
            ], ["name", "value"]))
            out.append(f"per-client attribution: python "
                       f"tools/ledger_report.py {cand}")
            out.append("")
            break

    if csv_path and os.path.exists(csv_path):
        with open(csv_path, newline="") as fh:
            rows = list(csv.reader(fh))
        if len(rows) > 1:
            out.append("## metrics csv (last 5 rows)")
            out.append(_table(rows[-5:], rows[0]))
            out.append("")
    return "\n".join(out)


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace_dir", help="directory a --trace-dir run wrote")
    ap.add_argument("--csv", default="", help="run metrics CSV to append")
    ap.add_argument("--ledger", default="",
                    help="flight-recorder dir (defaults to trace_dir)")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.trace_dir):
        print(f"not a directory: {args.trace_dir}", file=sys.stderr)
        return 2
    print(report(args.trace_dir, args.csv, ledger_dir=args.ledger))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
