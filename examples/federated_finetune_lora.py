"""Federated LoRA fine-tuning (the paper's RoBERTa+LoRA GLUE setting).

    PYTHONPATH=src python examples/federated_finetune_lora.py

Freezes a pretrained-style base model and federates ONLY the LoRA
adapters with FedAdamW — the uploads are the LoRA deltas plus the O(B)
block means of their second moments.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import (get_algorithm, init_server_state, make_round_fn,
                        upload_bytes)
from repro.core.partition import build_block_specs
from repro.data import make_task, round_batches, sample_clients
from repro.lora import build_lora_model
from repro.models import build_model


def main():
    cfg = reduced_variant(get_arch("roberta-base-fl"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    base = model.init(jax.random.key(0))  # stands in for pretrained weights

    lm = build_lora_model(model, base)
    lora = lm.init(jax.random.key(1), rank=8, alpha=16.0)

    fed = FedConfig(algorithm="fedadamw", num_clients=8,
                    clients_per_round=4, local_steps=8, lr=1e-3)
    specs = build_block_specs(lora, cfg, fed)
    alg = get_algorithm(fed)
    sstate = init_server_state(alg, lora, specs, fed)

    n_base = sum(p.size for p in jax.tree.leaves(base))
    n_lora = sum(p.size for p in jax.tree.leaves(lora))
    up = jax.eval_shape(lambda: alg.upload(
        lora, alg.init_client(lora, sstate, fed, specs=specs), specs, fed))
    print(f"base params {n_base/1e6:.1f}M (frozen), "
          f"LoRA params {n_lora/1e3:.1f}k (federated), "
          f"upload {upload_bytes(up)/1e3:.1f} kB/client/round")

    task = make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=32,
                     num_samples=2048, num_clients=fed.num_clients,
                     dirichlet_alpha=0.3, seed=0)
    round_fn = jax.jit(make_round_fn(lm, fed, specs, alg=alg))
    rng = np.random.default_rng(2)
    for r in range(8):
        cids = sample_clients(fed.num_clients, fed.clients_per_round, rng)
        batches = round_batches(task, cids, fed.local_steps, 16, rng)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        lora, sstate, m = round_fn(lora, sstate, batches,
                                   jnp.asarray(cids), jnp.asarray(r))
        print(f"round {r}  loss {float(m['loss_mean']):.4f}")


if __name__ == "__main__":
    main()
