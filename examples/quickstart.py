"""Quickstart: 10 rounds of FedAdamW on a synthetic non-iid task.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's ViT-Tiny analogue, partitions a synthetic
classification task across 8 clients with Dirichlet(0.3) label skew, and
runs FedAdamW (block-mean v aggregation + global-update correction +
decoupled weight decay) for 10 communication rounds.

``QUICKSTART_ROUNDS`` / ``QUICKSTART_STEPS`` shrink the run (the CI
examples-smoke job executes this file at reduced size so the example
cannot drift from the library).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "10"))
LOCAL_STEPS = int(os.environ.get("QUICKSTART_STEPS", "8"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import build_fed_state, make_round_fn, total_blocks
from repro.core.partition import partition_report
from repro.data import make_task, round_batches, sample_clients
from repro.models import build_model


def main():
    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    fed = FedConfig(algorithm="fedadamw", num_clients=8,
                    clients_per_round=4, local_steps=LOCAL_STEPS, lr=1e-3,
                    weight_decay=0.01, alpha=0.5)

    task = make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=32,
                     num_samples=2048, num_clients=fed.num_clients,
                     dirichlet_alpha=0.3, seed=0)

    params, specs, alg, sstate = build_fed_state(model, fed,
                                                 jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.2f}M  "
          f"hessian blocks={total_blocks(specs)} "
          f"(v upload is {total_blocks(specs)} floats, not {n_params})")
    print(partition_report(specs))

    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(1)
    for r in range(ROUNDS):
        cids = sample_clients(fed.num_clients, fed.clients_per_round, rng)
        batches = round_batches(task, cids, fed.local_steps, 16, rng)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        params, sstate, m = round_fn(params, sstate, batches,
                                     jnp.asarray(cids), jnp.asarray(r))
        print(f"round {r:2d}  train loss {float(m['loss_mean']):.4f}")

    test = {k: jnp.asarray(v) for k, v in task.test_batch(256).items()}
    loss, metrics = jax.jit(model.loss)(params, test)
    print(f"test loss {float(loss):.4f}  "
          f"test acc {float(metrics['accuracy']):.3f}")


if __name__ == "__main__":
    main()
