"""Serving example: batched greedy decoding from an attention-free SSM
(Mamba2 family) — O(1) decode state, the architecture class behind the
``long_500k`` input shape.

    PYTHONPATH=src python examples/serve_ssm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch
from repro.config.model_config import reduced_variant
from repro.core.serve import make_serve_step
from repro.models import build_model


def main():
    cfg = reduced_variant(get_arch("mamba2-780m"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.key(0))

    batch, prompt_len, new_tokens = 4, 12, 24
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

    cache = model.init_cache(batch, prompt_len + new_tokens)
    step = jax.jit(make_serve_step(model))

    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"arch={cfg.name}: decode state {state_bytes/1e3:.0f} kB "
          f"(constant in context length — a KV cache at 524288 tokens "
          f"would be ~GBs)")

    tok = prompt[:, :1]
    for i in range(prompt_len):
        tok, _, cache = step(params, prompt[:, i:i + 1], cache)
    t0 = time.perf_counter()
    out = [tok]
    for _ in range(new_tokens - 1):
        tok, _, cache = step(params, out[-1], cache)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / (new_tokens - 1)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decoded {new_tokens} tokens/request x {batch} requests, "
          f"{1e3*dt:.1f} ms/token on CPU")
    print("first request:", gen[0].tolist())


if __name__ == "__main__":
    main()
