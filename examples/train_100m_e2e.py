"""End-to-end driver (deliverable b): federated training of a ~100M-param
dense Transformer for a few hundred local steps.

    PYTHONPATH=src python examples/train_100m_e2e.py [--rounds 20]

The model is a 12L/d768 decoder (~110M params incl. embeddings) — the
largest thing this CPU container trains in reasonable wall time. 20 rounds
x 4 clients x 5 local steps = 400 optimizer steps. Use --rounds to extend.
Checkpoints every 5 rounds; restores and resumes if a checkpoint exists.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.config import FedConfig, get_arch
from repro.core import build_fed_state, make_round_fn
from repro.data import make_task, round_batches, sample_clients
from repro.metrics import Meter
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = get_arch("roberta-base-fl")  # 12L d768: ~110M params
    model = build_model(cfg, compute_dtype=jnp.bfloat16)
    fed = FedConfig(algorithm="fedadamw", num_clients=8,
                    clients_per_round=4, local_steps=5, lr=3e-4,
                    weight_decay=0.01, alpha=0.5)
    task = make_task("lm", vocab_size=1024, seq_len=128, num_samples=4096,
                     num_clients=fed.num_clients, dirichlet_alpha=0.3,
                     seed=0)
    # the task vocab is a subset of the model's padded vocab: fine for LM

    params, specs, alg, sstate = build_fed_state(model, fed,
                                                 jax.random.key(0))
    start = 0
    if os.path.exists(os.path.join(args.ckpt_dir, "latest")):
        params, sstate, start = restore_checkpoint(
            args.ckpt_dir, params_template=params, state_template=sstate)
        print(f"resumed from round {start}")

    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n/1e6:.0f}M params, "
          f"{fed.num_clients} clients, K={fed.local_steps}")

    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg,
                                     cosine_total_rounds=args.rounds))
    rng = np.random.default_rng(start + 1)
    meter = Meter()
    for r in range(start, args.rounds):
        t0 = time.perf_counter()
        cids = sample_clients(fed.num_clients, fed.clients_per_round, rng)
        batches = round_batches(task, cids, fed.local_steps, 8, rng)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        params, sstate, m = round_fn(params, sstate, batches,
                                     jnp.asarray(cids), jnp.asarray(r))
        loss = float(m["loss_mean"])
        meter.update(loss)
        print(f"round {r:3d}  loss {loss:.4f} (ema {meter.value:.4f})  "
              f"{time.perf_counter()-t0:.1f}s")
        if (r + 1) % 5 == 0:
            save_checkpoint(args.ckpt_dir, r + 1, params=params,
                            server_state=sstate)
            print(f"  checkpointed @ {r + 1}")


if __name__ == "__main__":
    main()
