"""Beyond-paper extensions: FedLAMB, FedLion, int8 uploads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state, make_round_fn
from repro.core.extensions import fake_quant_int8, wire_bytes


def _run_rounds(algorithm, rounds=4, lr=1e-3):
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm=algorithm, num_clients=4, clients_per_round=4,
                    local_steps=6, lr=lr)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 6, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    losses = []
    for r in range(rounds):
        params, sstate, m = round_fn(params, sstate, batch,
                                     jnp.arange(4, dtype=jnp.int32),
                                     jnp.asarray(r))
        losses.append(float(m["loss_mean"]))
    assert all(np.isfinite(losses))
    return losses, params


@pytest.mark.parametrize("algorithm,lr",
                         [("fedlamb", 1e-3), ("fedlion", 3e-4),
                          ("fedadamw+int8", 1e-3), ("fedlion+int8", 3e-4)])
def test_extension_algorithms_train(algorithm, lr):
    losses, params = _run_rounds(algorithm, lr=lr)
    assert losses[-1] < losses[0], (algorithm, losses)
    for p in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(p)))


def test_fake_quant_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q = fake_quant_int8(x)
    max_err = float(jnp.max(jnp.abs(q - x)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert max_err <= scale * 0.5 + 1e-7


def test_fake_quant_levels():
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5], jnp.float32)
    q = fake_quant_int8(x)
    # at most 255 levels, symmetric, preserves extremes exactly
    np.testing.assert_allclose(float(q[1]), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(q[2]), -1.0, rtol=1e-6)


def test_wire_bytes_accounting():
    up = {"delta": {"w": jnp.zeros((100,), jnp.float32)},
          "v_mean": jnp.zeros((10,), jnp.float32)}
    full = wire_bytes(up, delta_int8=False)
    q = wire_bytes(up, delta_int8=True)
    assert full == 100 * 4 + 10 * 4
    assert q == 100 + 4 + 10 * 4


def test_int8_quality_close_to_fp32():
    """int8 uploads must not materially change the training trajectory."""
    l_fp, _ = _run_rounds("fedadamw")
    l_q, _ = _run_rounds("fedadamw+int8")
    assert abs(l_fp[-1] - l_q[-1]) < 0.15 * abs(l_fp[-1]), (l_fp, l_q)
