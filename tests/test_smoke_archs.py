"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned architecture family (<=2 layers, d_model <= 512, <=4 experts) runs
one forward/train step + one decode step on CPU, asserting output shapes
and no NaNs. FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import build_fed_state, make_round_fn
from repro.models import build_model

ASSIGNED = [
    "olmo-1b", "olmo-1b-swa", "stablelm-12b", "qwen2-72b", "qwen3-32b",
    "qwen2-vl-2b", "mixtral-8x7b", "zamba2-2.7b",
    "llama4-maverick-400b-a17b", "seamless-m4t-large-v2", "mamba2-780m",
    "vit-tiny-fl", "roberta-base-fl",
]


def _smoke_batch(cfg, rng, b=2, s=32, k=None, clients=None):
    shape = tuple(x for x in (clients, k, b, s) if x is not None)
    toks = rng.integers(0, cfg.vocab_size, shape)
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        fshape = shape[:-1] + (cfg.frontend_tokens_per_sample,
                               cfg.frontend_embed_dim)
        batch["frontend_feats"] = jnp.asarray(
            rng.normal(size=fshape), jnp.float32)
    return batch


def test_reduced_variants_respect_limits():
    for arch in ASSIGNED:
        red = reduced_variant(get_arch(arch))
        assert red.num_layers <= 2, arch
        assert red.d_model <= 512, arch
        if red.moe is not None:
            assert red.moe.num_experts <= 4, arch
        red.validate()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = reduced_variant(get_arch(arch))
    model = build_model(cfg, compute_dtype=jnp.float32)
    rng = np.random.default_rng(0)

    fed = FedConfig(algorithm="fedadamw", num_clients=2,
                    clients_per_round=2, local_steps=1, lr=1e-3,
                    layout="client_parallel")
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    batch = _smoke_batch(cfg, rng, b=2, s=32, k=1, clients=2)
    new_params, sstate, m = round_fn(
        params, sstate, batch, jnp.arange(2, dtype=jnp.int32),
        jnp.asarray(0))
    assert np.isfinite(float(m["loss_mean"])), arch
    changed = 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        assert bool(jnp.all(jnp.isfinite(b))), arch
        changed += int(not bool(jnp.array_equal(a, b)))
    assert changed > 0, f"{arch}: no parameter moved"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch):
    cfg = reduced_variant(get_arch(arch))
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    b = 2
    kw = {}
    if cfg.family == "audio":
        feats = jnp.asarray(rng.normal(size=(
            b, cfg.frontend_tokens_per_sample, cfg.frontend_embed_dim)),
            jnp.float32)
        kw["memory"] = model.encode(params, feats)
    cache = model.init_cache(b, 16)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache = model.decode_step(params, tok, cache, **kw)
    from repro.models.layers import padded_vocab
    assert logits.shape == (b, 1, padded_vocab(cfg.vocab_size)), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch
