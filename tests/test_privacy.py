"""Differential-privacy subsystem: RDP accountant math, clip/noise
mechanism, engine integration (both layouts, eager + fused), config
validation, and the DP-disabled bit-exactness guarantee.

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state, make_local_phase
from repro.core.rounds import trace_round_jaxpr
from repro.data import RoundBatchGenerator, make_task
from repro.launch.pipeline import HostPrefetcher, RoundEngine, plan_round_blocks
from repro.metrics import MetricsSpool
from repro.privacy import (RDPAccountant, calibrate_noise_multiplier,
                           clip_tree_by_l2, dp_enabled, epsilon,
                           gaussian_epsilon_closed_form, l2_sq_norm,
                           released_entry_count, resolve_dp_noise)

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

ROUNDS, EVERY = 4, 2


def _task(cfg, num_clients=4, seed=0):
    return make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=16,
                     num_samples=256, num_clients=num_clients,
                     dirichlet_alpha=0.6, seed=seed)


def _gen(task, fed, seed=7, batch_size=2):
    return RoundBatchGenerator(
        task, num_clients=fed.num_clients,
        clients_per_round=fed.clients_per_round,
        local_steps=fed.local_steps, batch_size=batch_size, rng=seed)


def _drive(engine, params, sstate, gen, blocks, depth=0):
    pre = HostPrefetcher(gen, blocks, depth=depth, stacked=engine.stacked)
    spool = MetricsSpool()
    for start, size, batches, cids in pre:
        params, sstate, m = engine.run_block(params, sstate, batches, cids,
                                             start, size)
        spool.append(start, m, size)
    return [m["loss_mean"] for _, m in spool.flush()], params, sstate


# ------------------------------------------------------------ accountant

def test_epsilon_monotonic_in_rounds():
    es = [epsilon(1.0, q=0.1, rounds=r) for r in (1, 10, 50, 200)]
    assert all(a < b for a, b in zip(es, es[1:])), es


def test_epsilon_monotonic_in_sampling_rate():
    es = [epsilon(1.0, q=q, rounds=50) for q in (0.01, 0.05, 0.2, 1.0)]
    assert all(a < b for a, b in zip(es, es[1:])), es


def test_epsilon_decreases_with_noise_multiplier():
    es = [epsilon(s, q=0.1, rounds=50) for s in (0.5, 1.0, 2.0, 8.0)]
    assert all(a > b for a, b in zip(es, es[1:])), es


def test_gaussian_closed_form_fixture():
    """q=1, one round, integer-order RDP conversion must sit within the
    order-grid discretization of the continuous-alpha closed form
    eps = 1/(2 sigma^2) + sqrt(2 log(1/delta))/sigma."""
    for sigma in (0.8, 1.0, 2.0, 5.0):
        got = epsilon(sigma, q=1.0, rounds=1, delta=1e-5)
        want = gaussian_epsilon_closed_form(sigma, 1e-5)
        assert want <= got <= 1.01 * want, (sigma, got, want)
    # hand-checked value: sigma=1, delta=1e-5 -> 0.5 + sqrt(2 ln 1e5)
    assert gaussian_epsilon_closed_form(1.0, 1e-5) == pytest.approx(
        0.5 + math.sqrt(2 * math.log(1e5)), rel=1e-12)


def test_subsampling_amplification():
    # over a real training horizon, sampling 5% of clients per round
    # costs a small fraction of full participation's budget
    assert epsilon(1.0, q=0.05, rounds=100) < 0.2 * epsilon(
        1.0, q=1.0, rounds=100)


def test_accountant_composes_actual_cohorts():
    acc = RDPAccountant(1.0, 100, delta=1e-5)
    assert acc.epsilon() == 0.0                 # nothing spent yet
    acc.step(10, rounds=5)
    acc.step(25, rounds=5)
    lo = epsilon(1.0, q=0.10, rounds=10)
    hi = epsilon(1.0, q=0.25, rounds=10)
    assert lo < acc.epsilon() < hi
    assert acc.rounds == 10
    with pytest.raises(ValueError, match="cohort_size"):
        acc.step(101)


def test_accountant_zero_noise_is_infinite():
    acc = RDPAccountant(0.0, 100)
    acc.step(10)
    assert acc.epsilon(1e-5) == math.inf
    assert epsilon(0.0, q=0.1, rounds=1) == math.inf


def test_released_entries_penalty():
    one = epsilon(1.0, q=0.1, rounds=50, released_entries=1)
    two = epsilon(1.0, q=0.1, rounds=50, released_entries=2)
    assert two > one
    # E entries at sigma == one entry at sigma/sqrt(E)
    assert two == pytest.approx(
        epsilon(1.0 / math.sqrt(2.0), q=0.1, rounds=50), rel=1e-9)


def test_calibration_roundtrip_is_tight():
    sigma = calibrate_noise_multiplier(2.0, q=0.25, rounds=100, delta=1e-5)
    assert epsilon(sigma, q=0.25, rounds=100) <= 2.0
    # within ~5%: slightly less noise must blow the budget
    assert epsilon(0.95 * sigma, q=0.25, rounds=100) > 2.0


def test_calibration_unreachable_is_actionable():
    with pytest.raises(ValueError, match="unreachable"):
        calibrate_noise_multiplier(1e-9, q=1.0, rounds=10000,
                                   sigma_max=10.0)
    with pytest.raises(ValueError, match="target_epsilon"):
        calibrate_noise_multiplier(0.0, q=0.1, rounds=10)


# ------------------------------------------------------------- mechanism

def test_clip_tree_bounds_joint_norm():
    tree = {"a": jnp.full((8, 4), 3.0), "b": jnp.arange(5, dtype=jnp.float32)}
    clipped = clip_tree_by_l2(tree, 0.7)
    norm = float(jnp.sqrt(l2_sq_norm(clipped)))
    assert norm == pytest.approx(0.7, rel=1e-5)
    # within-bound trees pass through unchanged (factor is exactly 1.0)
    small = {"a": jnp.asarray([1e-3, -2e-3])}
    out = clip_tree_by_l2(small, 1.0)
    assert jnp.array_equal(out["a"], small["a"])


def test_local_phase_uploads_are_clipped():
    """Every aggregated upload entry of a DP client must come back with
    joint L2 norm <= dp_clip — delta AND the block-mean v."""
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=3,
                    lr=1e-2, dp_clip=1e-3)
    _, specs, alg, sstate = build_fed_state(model, fed, jax.random.key(0),
                                            cfg=cfg)
    task = _task(cfg)
    batches, _ = _gen(task, fed).next_round()
    one = jax.tree.map(lambda x: jnp.asarray(x[0]), batches)
    up, _ = make_local_phase(model.loss, alg, fed, specs)(
        params, sstate, one, jnp.ones(()))
    for name, entry in up.items():
        norm = float(jnp.sqrt(l2_sq_norm(entry)))
        assert norm <= fed.dp_clip * (1 + 1e-5), (name, norm)


def test_scaffold_dc_clipped_post_commit():
    """SCAFFOLD's commit-introduced dc entry is clipped per client
    before aggregation (the commit-hook clip path)."""
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(algorithm="scaffold", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-2,
                    dp_clip=1e-4)
    _, specs, alg, sstate = build_fed_state(model, fed, jax.random.key(0),
                                            cfg=cfg)
    task = _task(cfg)
    batches, cids = _gen(task, fed).next_round()
    local_phase = make_local_phase(model.loss, alg, fed, specs)
    uploads, _ = jax.vmap(
        local_phase, in_axes=(None, None, 0, None, 0), out_axes=0)(
        params, sstate, jax.tree.map(jnp.asarray, batches),
        jnp.ones(()), jnp.asarray(cids))
    from repro.core.rounds import _clip_commit_entries
    pre = set(uploads)
    sstate, uploads = alg.commit(sstate, uploads, jnp.asarray(cids),
                                 specs, fed)
    uploads = _clip_commit_entries(uploads, pre, fed.dp_clip, stacked=True)
    assert "dc" in uploads
    for s in range(2):
        client_dc = jax.tree.map(lambda x: x[s], uploads["dc"])
        norm = float(jnp.sqrt(l2_sq_norm(client_dc)))
        assert norm <= fed.dp_clip * (1 + 1e-5), norm


def test_released_entry_count_skips_comm_state():
    from repro.comm.error_feedback import EF_KEY
    assert released_entry_count({"delta": 0, "v_mean": 0}) == 2
    assert released_entry_count({"delta": 0, EF_KEY: 0}) == 1


# ------------------------------------------------------- config handling

def test_fedconfig_validates_dp_fields():
    cases = [
        (dict(dp_clip=-1.0), "dp_clip"),
        (dict(dp_clip=1.0, dp_noise_multiplier=-0.5), "dp_noise"),
        (dict(dp_noise_multiplier=1.0), "require dp_clip"),
        (dict(target_epsilon=2.0), "require dp_clip"),
        (dict(dp_clip=1.0, dp_noise_multiplier=1.0, target_epsilon=2.0),
         "not both"),
        (dict(dp_clip=1.0, dp_delta=0.0), "dp_delta"),
        (dict(dp_clip=1.0, dp_delta=1.5), "dp_delta"),
        (dict(dp_clip=1.0, agg_weighting="data_size"), "UNIFORM"),
        (dict(use_pallas_clipacc=True), "requires dp_clip"),
        (dict(dp_clip=1.0, use_pallas_clipacc=True,
              layout="client_sequential"), "client_parallel"),
        (dict(dp_clip=1.0, use_pallas_clipacc=True,
              algorithm="fedadamw+int8"), "BEFORE codec"),
    ]
    for overrides, match in cases:
        fed = FedConfig(num_clients=4, clients_per_round=2, **overrides)
        with pytest.raises(ValueError, match=match):
            fed.validate()
    good = FedConfig(num_clients=4, clients_per_round=2, dp_clip=1.0,
                     dp_noise_multiplier=1.0)
    good.validate()
    assert good.dp_enabled() and not FedConfig().dp_enabled()


def test_resolve_dp_noise_hits_target():
    fed = FedConfig(num_clients=40, clients_per_round=8, rounds=30,
                    dp_clip=1.0, target_epsilon=4.0)
    fed.validate()
    resolved = resolve_dp_noise(fed, released_entries=2)
    assert resolved.dp_noise_multiplier > 0
    assert resolved.target_epsilon == 0.0
    assert epsilon(resolved.dp_noise_multiplier, q=8 / 40, rounds=30,
                   delta=fed.dp_delta, released_entries=2) <= 4.0
    # no-ops: DP off, or sigma already chosen
    off = FedConfig()
    assert resolve_dp_noise(off) is off
    explicit = FedConfig(dp_clip=1.0, dp_noise_multiplier=2.0)
    assert resolve_dp_noise(explicit).dp_noise_multiplier == 2.0
    assert not dp_enabled(FedConfig())


# ------------------------------------------------ engine-level behavior

@pytest.mark.parametrize("algorithm", ["fedadamw", "scaffold"])
@pytest.mark.parametrize("layout", LAYOUTS)
def test_dp_disabled_bit_exact(algorithm, layout):
    """A config with the DP fields at their disabled values must trace
    the exact pre-privacy program. Structural check FIRST: the off-config
    jaxpr is byte-identical to the default config's, single-round AND
    rounds_per_call-fused (gate-parity, docs/analysis.md — IR diffing
    where this test used to drive three full trajectories). One eager
    trajectory pair stays as the end-to-end backstop."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    base = FedConfig(algorithm=algorithm, num_clients=4,
                     clients_per_round=2, local_steps=2, lr=1e-3,
                     layout=layout, sequential_clients=2)
    off = dataclasses.replace(base, dp_clip=0.0, dp_noise_multiplier=0.0,
                              dp_seed=123)

    assert str(trace_round_jaxpr(model, off, cfg=cfg)[0]) == \
        str(trace_round_jaxpr(model, base, cfg=cfg)[0])
    assert str(trace_round_jaxpr(
        model, dataclasses.replace(off, rounds_per_call=2), cfg=cfg,
        multi_rounds=2)[0]) == \
        str(trace_round_jaxpr(
            model, dataclasses.replace(base, rounds_per_call=2), cfg=cfg,
            multi_rounds=2)[0])

    params, specs, alg, sstate = build_fed_state(
        model, base, jax.random.key(0), cfg=cfg)
    single = plan_round_blocks(ROUNDS, EVERY, 1)
    ref_engine = RoundEngine(model, base, specs, alg=alg,
                             cosine_total_rounds=ROUNDS, donate=False)
    l_ref, p_ref, _ = _drive(ref_engine, params, sstate, _gen(task, base),
                             single)
    off_engine = RoundEngine(model, off, specs, alg=alg,
                             cosine_total_rounds=ROUNDS, donate=False)
    l_off, p_off, _ = _drive(off_engine, params, sstate, _gen(task, off),
                             single)
    assert l_ref == l_off, (l_ref, l_off)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_off)):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_dp_enabled_bit_exact_across_execution_modes(layout):
    """With DP ON, eager and prefetched+fused execution must still be
    bit-identical: the noise key is a pure function of (dp_seed, round
    index, leaf), never of trace structure."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=2,
                    lr=1e-3, layout=layout, sequential_clients=2,
                    dp_clip=0.05, dp_noise_multiplier=0.8, dp_seed=11)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg,
                         cosine_total_rounds=ROUNDS, donate=False)
    fused_engine = RoundEngine(
        model, dataclasses.replace(fed, rounds_per_call=2), specs, alg=alg,
        cosine_total_rounds=ROUNDS, donate=False)
    l_e, p_e, _ = _drive(engine, params, sstate, _gen(task, fed),
                         plan_round_blocks(ROUNDS, EVERY, 1), depth=0)
    l_f, p_f, _ = _drive(fused_engine, params, sstate, _gen(task, fed),
                         plan_round_blocks(ROUNDS, EVERY, 2), depth=2)
    assert l_e == l_f, (l_e, l_f)
    for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_f)):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("algorithm", ["fedadamw", "scaffold"])
def test_dp_layout_parity(algorithm):
    """Clip + noise must produce matching trajectories under both
    placement layouts (same data, same noise keys)."""
    if _ENV_LAYOUT:
        pytest.skip("layout pinned by REPRO_LAYOUT")
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    results = {}
    for layout in ("client_parallel", "client_sequential"):
        fed = FedConfig(algorithm=algorithm, num_clients=4,
                        clients_per_round=2, local_steps=2, lr=1e-3,
                        layout=layout, sequential_clients=2,
                        dp_clip=0.05, dp_noise_multiplier=0.5, dp_seed=3)
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
        results[layout] = _drive(engine, params, sstate, _gen(task, fed),
                                 plan_round_blocks(3, 3, 1))
    l_p, p_p, _ = results["client_parallel"]
    l_s, p_s, _ = results["client_sequential"]
    np.testing.assert_allclose(l_p, l_s, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_p), jax.tree.leaves(p_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_dp_noise_deterministic_and_seed_sensitive(layout):
    """Same (config, data) -> bit-identical noised trajectory; changing
    only dp_seed changes it."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=2,
                    lr=1e-3, layout=layout, sequential_clients=2,
                    dp_clip=0.05, dp_noise_multiplier=1.0, dp_seed=0)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    blocks = plan_round_blocks(2, 2, 1)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    l1, p1, _ = _drive(engine, params, sstate, _gen(task, fed), blocks)
    l2, p2, _ = _drive(engine, params, sstate, _gen(task, fed), blocks)
    assert l1 == l2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert jnp.array_equal(a, b)
    reseeded = dataclasses.replace(fed, dp_seed=99)
    engine2 = RoundEngine(model, reseeded, specs, alg=alg, donate=False)
    l3, _, _ = _drive(engine2, params, sstate, _gen(task, reseeded), blocks)
    assert l1 != l3


def test_v_bar_stays_nonnegative_under_noise():
    """Noise on the aggregated block-mean v could push entries negative
    (NaN in the next round's sqrt); the post-noise clamp keeps the
    second-moment entries >= 0."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=2,
                    lr=1e-3, dp_clip=1.0, dp_noise_multiplier=50.0)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    losses, _, sstate = _drive(engine, params, sstate, _gen(task, fed),
                               plan_round_blocks(2, 2, 1))
    assert all(np.isfinite(v) for v in losses), losses
    for leaf in jax.tree.leaves(sstate["v_bar"]):
        assert float(jnp.min(leaf)) >= 0.0


@pytest.mark.parametrize("layout", LAYOUTS)
def test_dp_composes_with_lossy_codec_and_error_feedback(layout):
    """DP + int8 codec + error feedback: residuals fold pre-clip in the
    comm wrapper, the run stays finite, and the wire payload shape (and
    therefore wire bytes) is unchanged by clipping."""
    from repro.comm import codec_for, upload_wire_bytes
    from repro.core import upload_shape_spec
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(algorithm="fedadamw+int8", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    layout=layout, sequential_clients=2,
                    dp_clip=0.05, dp_noise_multiplier=0.3)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    losses, _, _ = _drive(engine, params, sstate, _gen(task, fed),
                          plan_round_blocks(2, 2, 1))
    assert all(np.isfinite(v) for v in losses), losses
    nodp = dataclasses.replace(fed, dp_clip=0.0, dp_noise_multiplier=0.0)
    spec = upload_shape_spec(alg, params, sstate, specs, fed)
    spec_nodp = upload_shape_spec(alg, params, sstate, specs, nodp)
    codec = codec_for(fed.algorithm)
    assert upload_wire_bytes(spec, codec) == \
        upload_wire_bytes(spec_nodp, codec)
    # the DECODED delta — what the server aggregates — must respect the
    # clip bound even though quantization error lands post-clip (the
    # wrapper re-clips the decoded values)
    batches, cids = _gen(task, fed).next_round()
    one = jax.tree.map(lambda x: jnp.asarray(x[0]), batches)
    up, _ = make_local_phase(model.loss, alg, fed, specs)(
        params, sstate, one, jnp.ones(()), jnp.asarray(cids[0]))
    norm = float(jnp.sqrt(l2_sq_norm(up["delta"])))
    assert norm <= fed.dp_clip * (1 + 1e-5), norm


def test_clipacc_engine_matches_jnp_path():
    """The fused clip-accumulate kernel path must reproduce the jnp
    clip+mean trajectory (same math, fused association)."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=2,
                    lr=1e-3, dp_clip=0.02, dp_noise_multiplier=0.5)
    fused = dataclasses.replace(fed, use_pallas_clipacc=True)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    blocks = plan_round_blocks(2, 2, 1)
    l_j, p_j, _ = _drive(RoundEngine(model, fed, specs, alg=alg,
                                     donate=False),
                         params, sstate, _gen(task, fed), blocks)
    l_k, p_k, _ = _drive(RoundEngine(model, fused, specs, alg=alg,
                                     donate=False),
                         params, sstate, _gen(task, fused), blocks)
    np.testing.assert_allclose(l_j, l_k, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_j), jax.tree.leaves(p_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


# --------------------------------------------------------------- driver

def test_run_training_reports_epsilon():
    """run_training with DP on: epsilon lands in history (monotone over
    eval rounds) and in the CSV columns; target_epsilon resolves into a
    noise multiplier that respects the budget."""
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
              num_clients=4, clients_per_round=2, local_steps=2,
              batch_size=4, eval_every=2, seed=3)
    h = run_training(**kw, dp_clip=0.5, dp_noise_multiplier=1.0,
                     prefetch_depth=2, rounds_per_call=2)
    assert len(h["epsilon"]) == 2
    assert 0 < h["epsilon"][0] < h["epsilon"][1] < math.inf
    assert h["engine"]["dp"]["released_entries"] == 2  # delta + v_mean
    h2 = run_training(**kw, dp_clip=0.5, target_epsilon=8.0)
    assert h2["engine"]["dp"]["noise_multiplier"] > 0
    assert h2["epsilon"][-1] <= 8.0


def test_run_training_dp_csv_columns(tmp_path):
    from repro.launch.train import run_training
    log = tmp_path / "dp.csv"
    run_training(arch="vit-tiny-fl", algorithm="fedadamw", rounds=2,
                 num_clients=4, clients_per_round=2, local_steps=2,
                 batch_size=4, eval_every=2, seed=3, log_path=str(log),
                 dp_clip=0.5, dp_noise_multiplier=1.0)
    header = log.read_text().splitlines()[0].split(",")
    assert "epsilon" in header
