"""Hessian-block partitioning: unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import partition


def _specs_for(family, fed=None):
    cfg, model, params = build_tiny(family)
    fed = fed or FedConfig()
    return params, partition.build_block_specs(params, cfg, fed)


@pytest.mark.parametrize("family",
                         ["dense", "moe", "ssm", "hybrid", "vlm", "audio"])
def test_roundtrip_shapes(family):
    params, specs = _specs_for(family)
    means = partition.tree_block_means(params, specs)
    back = partition.tree_broadcast_means(means, specs)
    for p, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert p.shape == b.shape


def test_constant_tensor_roundtrips_exactly():
    """broadcast(mean(x)) == x when x is block-constant."""
    params, specs = _specs_for("dense")
    const = jax.tree.map(lambda p: jnp.full(p.shape, 2.5, jnp.float32),
                         params)
    means = partition.tree_block_means(const, specs)
    back = partition.tree_broadcast_means(means, specs)
    for b in jax.tree.leaves(back):
        np.testing.assert_allclose(np.asarray(b), 2.5, rtol=1e-6)


def test_broadcast_preserves_block_means():
    """mean(broadcast(mean(x))) == mean(x): idempotence of the projection."""
    params, specs = _specs_for("moe")
    means = partition.tree_block_means(params, specs)
    back = partition.tree_broadcast_means(means, specs)
    means2 = partition.tree_block_means(back, specs)
    for a, b in zip(jax.tree.leaves(means), jax.tree.leaves(means2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_global_mean_preserved():
    """The projection preserves each tensor's global mean exactly."""
    params, specs = _specs_for("dense")
    back = partition.tree_broadcast_means(
        partition.tree_block_means(params, specs), specs)
    for p, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_allclose(float(jnp.mean(p)), float(jnp.mean(b)),
                                   rtol=1e-4, atol=1e-5)


def test_communication_is_o_b_not_o_d():
    """paper Table 7: the block-mean upload must be orders smaller than d."""
    params, specs = _specs_for("dense")
    d = sum(p.size for p in jax.tree.leaves(params))
    b = partition.total_blocks(specs)
    assert b < d / 20, (b, d)


def test_qk_blocked_per_head():
    cfg, _, params = (lambda t: (t[0], t[1], t[2]))(build_tiny("dense"))
    fed = FedConfig(min_block_size=1)  # disable merging to see raw classes
    specs = partition.build_block_specs(params, cfg, fed)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    seen = {}
    for kp, spec in flat:
        name = kp[-1].key if hasattr(kp[-1], "key") else str(kp[-1])
        seen[name] = spec
    # stacked (L, D, H, hd): qk per head -> L*H blocks
    assert seen["attn_wq"].cls == "qk_per_head"
    assert seen["attn_wq"].n_blocks == 2 * 4  # layers * heads
    assert seen["attn_wv"].cls == "value_per_neuron"
    assert seen["embed_tokens"].cls == "embed_per_token"


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 48),
    min_block=st.sampled_from([1, 8, 64, 512]),
    max_blocks=st.sampled_from([4, 64, 65536]),
    kept=st.sampled_from([(), (0,), (1,), (0, 1)]),
)
def test_make_spec_invariants(rows, cols, min_block, max_blocks, kept):
    """Structural invariants of the block-spec builder, any shape:
    groups divide their axes; n_blocks <= max_blocks (or collapses to 1 per
    axis); mean->broadcast roundtrip preserves shape and block means."""
    shape = (rows, cols)
    spec = partition._make_spec(shape, kept, "t", min_block, max_blocks)
    for g, a in zip(spec.groups, spec.kept):
        assert shape[a] % g == 0
    assert spec.n_blocks <= max(max_blocks, 1) or all(
        g == 1 for g in spec.groups)
    x = jnp.arange(rows * cols, dtype=jnp.float32).reshape(shape)
    m = partition.block_means(x, spec)
    assert m.shape == (spec.n_blocks,)
    y = partition.broadcast_means(m, spec)
    assert y.shape == shape
    m2 = partition.block_means(y, spec)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m2),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_projection_reduces_variance(seed):
    """block-mean projection is an averaging operator: it can never
    increase the L2 norm (Jensen)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    spec = partition._make_spec((16, 24), (1,), "t", 1, 65536)
    y = partition.broadcast_means(partition.block_means(x, spec), spec)
    assert float(jnp.sum(y * y)) <= float(jnp.sum(x * x)) + 1e-4
