"""Fault-injection + graceful-degradation layer (repro.faults,
docs/faults.md): seeded schedules, upload validation, robust
aggregation, quorum rounds, watchdog rollback, and the chaos sweep.

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state, make_round_fn
from repro.core.rounds import make_multi_round_fn, trace_round_jaxpr
from repro.faults import (FAULT_DROP_KEY, FAULT_MULT_KEY, FaultModel,
                          NaNWatchdog, WatchdogRollback, parse_robust_agg,
                          robust_aggregate, upload_validity)

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])


# ------------------------------------------------------------- schedules

def test_schedule_deterministic_and_subset_invariant():
    """The fault realization of (seed, round, client) is a pure function:
    re-draws are identical, and sampling a SUBSET of clients sees exactly
    the full population's values at those ids — so any two execution
    modes (or cohort compositions) agree on who faulted."""
    fm = FaultModel(16, p_drop=0.3, p_nan=0.2, p_scale=0.2, seed=11)
    sub = np.array([1, 4, 9])
    d1, m1 = fm.round_faults(5, sub)
    d2, m2 = fm.round_faults(5, sub)
    assert np.array_equal(d1, d2)
    assert np.array_equal(m1, m2, equal_nan=True)
    full_d, full_m = fm.round_faults(5, np.arange(16))
    assert np.array_equal(d1, full_d[sub])
    assert np.array_equal(m1, full_m[sub], equal_nan=True)
    # different rounds draw independently
    d3, m3 = fm.round_faults(6, sub)
    assert not (np.array_equal(d1, d3)
                and np.array_equal(m1, m3, equal_nan=True))


def test_inactive_model_emits_no_payload():
    assert FaultModel(8).round_payload(0, np.arange(4)) == {}
    assert FaultModel.from_fed(FedConfig()) is None


def test_payload_rides_reserved_keys():
    fm = FaultModel(8, p_nan=0.5, seed=3)
    pay = fm.round_payload(2, np.arange(8))
    assert set(pay) == {FAULT_DROP_KEY, FAULT_MULT_KEY}
    assert pay[FAULT_DROP_KEY].dtype == np.bool_
    assert pay[FAULT_MULT_KEY].dtype == np.float32


def test_generator_attaches_fault_payload_identically_all_modes():
    """The payload comes from its own seeded rng, not the data stream:
    attaching it changes neither tokens nor cids, and the prefetched
    stream matches eager assembly."""
    from repro.data import RoundBatchGenerator, make_task
    task = make_task("class_lm", vocab_size=64, seq_len=16,
                     num_samples=256, num_clients=4, seed=0)

    def gen(faults):
        return RoundBatchGenerator(
            task, num_clients=4, clients_per_round=4, local_steps=2,
            batch_size=2, rng=np.random.default_rng(7), faults=faults)

    g0, g1 = gen(None), gen(FaultModel(4, p_nan=0.4, seed=5))
    for r in range(3):
        b0, c0 = g0.next_round()
        b1, c1 = g1.next_round()
        assert np.array_equal(c0, c1)
        assert np.array_equal(b0["tokens"], b1["tokens"])
        assert FAULT_MULT_KEY in b1 and FAULT_MULT_KEY not in b0
        want_d, want_m = FaultModel(4, p_nan=0.4, seed=5).round_faults(r, c1)
        assert np.array_equal(b1[FAULT_DROP_KEY], want_d)
        assert np.array_equal(b1[FAULT_MULT_KEY], want_m, equal_nan=True)


# ----------------------------------------------------- parse + constraints

def test_parse_robust_agg_specs():
    assert parse_robust_agg("none") == ("none", 0.0)
    assert parse_robust_agg("mean") == ("mean", 0.0)
    assert parse_robust_agg("trimmed0.1") == ("trimmed", 0.1)
    assert parse_robust_agg("coordinate_median") == ("coordinate_median",
                                                     0.0)
    assert parse_robust_agg("norm_filter") == ("norm_filter", 0.0)
    for bad in ("trimmed", "trimmed0.5", "trimmed-0.1", "median", ""):
        with pytest.raises(ValueError):
            parse_robust_agg(bad)


def test_constraints_reject_invalid_fault_configs():
    base = dict(num_clients=8, clients_per_round=4, sequential_clients=4)
    with pytest.raises(ValueError, match="fault_nan"):
        FedConfig(fault_nan=1.5, **base).validate()
    with pytest.raises(ValueError, match="min_quorum"):
        FedConfig(min_quorum=5, robust_agg="mean", **base).validate()
    with pytest.raises(ValueError, match="survivors"):
        FedConfig(min_quorum=2, **base).validate()  # quorum needs defense
    with pytest.raises(ValueError, match="client_parallel"):
        FedConfig(layout="client_sequential", robust_agg="trimmed0.1",
                  **base).validate()
    with pytest.raises(ValueError, match="rank"):
        FedConfig(robust_agg="coordinate_median", dp_clip=1.0,
                  dp_noise_multiplier=1.0, **base).validate()
    with pytest.raises(ValueError, match="clipacc"):
        FedConfig(use_pallas_clipacc=True, dp_clip=1.0,
                  dp_noise_multiplier=1.0, fault_nan=0.1,
                  **base).validate()
    # the sanctioned combos pass
    FedConfig(fault_nan=0.1, robust_agg="norm_filter", min_quorum=2,
              **base).validate()
    FedConfig(layout="client_sequential", fault_drop=0.2,
              robust_agg="mean", **base).validate()


# ----------------------------------------------------- validator/aggregate

def _uploads(vals):
    """(S,) list of scalars -> stacked upload dict with a (S, 2) leaf."""
    arr = jnp.asarray([[v, v] for v in vals], jnp.float32)
    return {"delta": {"w": arr}}


def test_upload_validity_screens_nonfinite_and_outliers():
    ups = _uploads([1.0, np.nan, 1.0, np.inf, 1.0, 100.0])
    valid = upload_validity(ups, arrived=None, kind="mean", norm_mult=0.0)
    assert list(np.asarray(valid)) == [True, False, True, False, True,
                                       True]
    # norm screen: 100.0 is way past 5x the median norm
    valid = upload_validity(ups, arrived=None, kind="norm_filter",
                            norm_mult=5.0)
    assert list(np.asarray(valid)) == [True, False, True, False, True,
                                       False]
    # arrived mask composes
    arrived = jnp.asarray([False, True, True, True, True, True])
    valid = upload_validity(ups, arrived=arrived, kind="mean",
                            norm_mult=0.0)
    assert list(np.asarray(valid)) == [False, False, True, False, True,
                                       True]


def test_robust_aggregators_match_numpy_reference():
    vals = [3.0, -1.0, 7.0, np.nan, 5.0, 2.0]
    ups = _uploads(vals)
    valid = upload_validity(ups, arrived=None, kind="mean", norm_mult=0.0)
    ok = np.asarray([v for v in vals if np.isfinite(v)])

    mean_up, nv = robust_aggregate(ups, valid, None, kind="mean")
    assert int(nv) == 5
    np.testing.assert_allclose(np.asarray(mean_up["delta"]["w"])[0],
                               ok.mean(), rtol=1e-6)

    med_up, _ = robust_aggregate(ups, valid, None,
                                 kind="coordinate_median")
    np.testing.assert_allclose(np.asarray(med_up["delta"]["w"])[0],
                               np.median(ok), rtol=1e-6)

    tr_up, _ = robust_aggregate(ups, valid, None, kind="trimmed",
                                trim_frac=0.25)
    k = int(0.25 * 5)                       # 1 trimmed per side
    ref = np.sort(ok)[k:len(ok) - k].mean()
    np.testing.assert_allclose(np.asarray(tr_up["delta"]["w"])[0], ref,
                               rtol=1e-6)


def test_aggregate_zero_survivors_is_zero_update():
    """No valid upload: every aggregator must produce a FINITE (zero)
    mean, never the +inf sort sentinel — quorum then freezes the round."""
    ups = _uploads([np.nan, np.inf, np.nan])
    valid = jnp.zeros(3, bool)
    for kind, tf in (("mean", 0.0), ("trimmed", 0.2),
                     ("coordinate_median", 0.0), ("norm_filter", 0.0)):
        mu, nv = robust_aggregate(ups, valid, None, kind=kind,
                                  trim_frac=tf)
        assert int(nv) == 0
        assert np.all(np.asarray(mu["delta"]["w"]) == 0.0), kind


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["mean", "trimmed", "coordinate_median",
                             "norm_filter"]),
       vneg=st.floats(-10.0, -0.1), seed=st.integers(0, 5))
def test_vbar_stays_nonnegative_under_every_aggregator(kind, vneg, seed):
    """Second-moment entries must come out >= 0 from every registry
    entry: the next round sqrt()s them, and a weighted combination of
    screened values (or DP noise upstream) must never leak a negative
    through (satellite 3)."""
    rng = np.random.default_rng(seed)
    s = 5
    ups = {
        "delta": {"w": jnp.asarray(rng.normal(size=(s, 3)), jnp.float32)},
        "v_mean": {"w": jnp.asarray(
            np.concatenate([[vneg], rng.uniform(0, 2, s - 1)])[:, None],
            jnp.float32)},
    }
    valid = jnp.ones(s, bool)
    weights = jnp.asarray(rng.uniform(0.1, 1.0, s), jnp.float32)
    tf = 0.2 if kind == "trimmed" else 0.0
    w = None if kind in ("trimmed", "coordinate_median") else weights
    mu, _ = robust_aggregate(ups, valid, w, kind=kind, trim_frac=tf)
    assert np.all(np.asarray(mu["v_mean"]["w"]) >= 0.0)


# ------------------------------------------------- engine: gating + chaos

def _batch(cfg, s, k, b, seq, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (s, k, b, seq))
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}


def _base_fed(layout, **kw):
    return FedConfig(algorithm="fedadamw", num_clients=4,
                     clients_per_round=4, local_steps=2, lr=1e-3,
                     layout=layout, sequential_clients=4, **kw)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_faults_off_bit_exact_and_jaxpr_parity(layout):
    """Disabled faults/defense must not perturb the engine: the traced
    program is byte-identical (structural gating) AND one eager round
    gives bit-identical parameters even with inert knobs moved."""
    cfg, model, _ = build_tiny("dense")
    base = _base_fed(layout)
    shifted = dataclasses.replace(base, fault_seed=123,
                                  robust_norm_mult=9.0)
    j0, _ = trace_round_jaxpr(model, base, cfg=cfg, with_faults=False)
    j1, _ = trace_round_jaxpr(model, shifted, cfg=cfg, with_faults=False)
    assert str(j0) == str(j1)

    batch = _batch(cfg, 4, 2, 2, 16)
    cids = jnp.arange(4, dtype=jnp.int32)

    def run(fed):
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        rf = jax.jit(make_round_fn(model, fed, specs, alg=alg))
        return rf(params, sstate, batch, cids, jnp.asarray(0))[0]

    for a, b in zip(jax.tree.leaves(run(base)),
                    jax.tree.leaves(run(shifted))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("layout", LAYOUTS)
def test_chaos_sweep_defended_rounds_stay_finite(layout):
    """(p_drop, p_nan, p_scale) grid x layouts x eager/fused: with the
    mean defense, every committed round is finite and the survivor count
    matches the host-side schedule — in both layouts and both engines
    (the schedule rides the batch pytree, so invariance is structural)."""
    cfg, model, _ = build_tiny("dense")
    fed = _base_fed(layout, fault_drop=0.1, fault_nan=0.1,
                    fault_scale=0.1, robust_agg="mean")
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    rf = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    grid = [(0.5, 0.0, 0.0), (0.0, 0.5, 0.0), (0.0, 0.0, 0.5),
            (0.3, 0.3, 0.3)]
    cids = jnp.arange(4, dtype=jnp.int32)
    survivors = {}
    for p_drop, p_nan, p_scale in grid:
        fm = FaultModel(4, p_drop=p_drop, p_nan=p_nan, p_scale=p_scale,
                        seed=13)
        batch = _batch(cfg, 4, 2, 2, 16)
        batch.update(jax.tree.map(jnp.asarray,
                                  fm.round_payload(0, np.arange(4))))
        p, s, m = rf(params, sstate, batch, cids, jnp.asarray(0))
        assert np.isfinite(float(m["loss_mean"]))
        for leaf in jax.tree.leaves(p):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        drop, mult = fm.round_faults(0, np.arange(4))
        want = int(np.sum(~drop & np.isfinite(mult)))
        assert int(m["agg_survivors"]) == want
        survivors[(p_drop, p_nan, p_scale)] = int(m["agg_survivors"])
        # same schedule realized for a different client subset agrees
        d2, m2 = fm.round_faults(0, np.array([0, 2]))
        assert np.array_equal(d2, drop[[0, 2]])
    assert survivors[(0.3, 0.3, 0.3)] <= 4


def test_fused_engine_matches_eager_under_faults():
    """M fused faulty rounds == M eager faulty rounds, bit-for-bit: the
    fault keys scan apart with the data axes."""
    cfg, model, _ = build_tiny("dense")
    fed = _base_fed("client_parallel", fault_nan=0.4,
                    robust_agg="mean", min_quorum=1)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    rf = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    mrf = jax.jit(make_multi_round_fn(model, fed, specs, alg=alg))
    fm = FaultModel(4, p_nan=0.4, seed=9)
    cids = jnp.arange(4, dtype=jnp.int32)
    per_round = []
    for r in range(3):
        b = _batch(cfg, 4, 2, 2, 16, seed=r)
        b.update(jax.tree.map(jnp.asarray,
                              fm.round_payload(r, np.arange(4))))
        per_round.append(b)
    p_e, s_e = params, sstate
    for r, b in enumerate(per_round):
        p_e, s_e, _ = rf(p_e, s_e, b, cids, jnp.asarray(r))
    stacked = {k: jnp.stack([b[k] for b in per_round])
               for k in per_round[0]}
    p_f, s_f, m_f = mrf(params, sstate, stacked,
                        jnp.stack([cids] * 3), jnp.asarray(0))
    for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_f)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert m_f["agg_survivors"].shape == (3,)


def test_quorum_freezes_round_but_advances_schedule():
    """All uploads rejected: params AND server state bit-match their
    pre-round values; the next round (different schedule draw) moves."""
    cfg, model, _ = build_tiny("dense")
    fed = _base_fed("client_parallel", fault_nan=0.999999,
                    robust_agg="mean", min_quorum=1)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    rf = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    fm = FaultModel(4, p_nan=0.999999, seed=0)
    b = _batch(cfg, 4, 2, 2, 16)
    b.update(jax.tree.map(jnp.asarray, fm.round_payload(0, np.arange(4))))
    p, s, m = rf(params, sstate, b, jnp.arange(4, dtype=jnp.int32),
                 jnp.asarray(0))
    assert float(m["quorum_ok"]) == 0.0 and int(m["agg_survivors"]) == 0
    for a, c in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
        assert np.array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(jax.tree.leaves(s), jax.tree.leaves(sstate)):
        assert np.array_equal(np.asarray(a), np.asarray(c))


def test_undefended_nan_fault_poisons_params():
    """The divergence half of the acceptance demo: without a defense a
    NaN upload reaches the global params in one round."""
    cfg, model, _ = build_tiny("dense")
    fed = _base_fed("client_parallel", fault_nan=0.999999)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    rf = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    fm = FaultModel(4, p_nan=0.999999, seed=0)
    b = _batch(cfg, 4, 2, 2, 16)
    b.update(jax.tree.map(jnp.asarray, fm.round_payload(0, np.arange(4))))
    p, _, _ = rf(params, sstate, b, jnp.arange(4, dtype=jnp.int32),
                 jnp.asarray(0))
    assert not all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree.leaves(p))


# ------------------------------------------------------- DP interaction

def test_dp_noise_scales_to_surviving_cohort():
    """sigma*C/S_valid: with the same (seed, round) the noise drawn for
    a 2-survivor cohort is exactly S/2 times the full-cohort noise."""
    from repro.privacy.dp import add_round_noise
    fed = _base_fed("client_parallel", dp_clip=1.0,
                    dp_noise_multiplier=1.0)
    x = {"delta": {"w": jnp.zeros((4, 4), jnp.float32)}}
    full = add_round_noise(x, fed, 0)["delta"]["w"]
    half = add_round_noise(x, fed, 0,
                           cohort_size=jnp.asarray(2.0))["delta"]["w"]
    np.testing.assert_allclose(np.asarray(half), 2.0 * np.asarray(full),
                               rtol=1e-6)
    # cohort_size floors at 1 instead of dividing by zero
    zero = add_round_noise(x, fed, 0,
                           cohort_size=jnp.asarray(0.0))["delta"]["w"]
    assert np.all(np.isfinite(np.asarray(zero)))


# ----------------------------------------------------------- watchdog

def test_watchdog_detects_and_rollback_bitmatches_checkpoint(tmp_path):
    """Round-trip: save a clean checkpoint, poison the live state, the
    watchdog raises, the restore bit-matches the saved trees."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    wd = NaNWatchdog(max_rollbacks=1)
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    sstate = {"m": jnp.ones((4,), jnp.float32), "t": jnp.zeros((), jnp.int32)}
    assert wd.healthy(params, sstate)
    save_checkpoint(str(tmp_path), 7, params=params, server_state=sstate)
    poisoned = {"w": params["w"].at[0, 0].set(jnp.nan)}
    assert wd.bad_leaves(poisoned, sstate) == 1
    with pytest.raises(WatchdogRollback) as ei:
        wd.check(7, poisoned, sstate)
    assert ei.value.round_index == 7
    rp, rs, step = restore_checkpoint(str(tmp_path),
                                      params_template=params,
                                      state_template=sstate)
    assert step == 7
    assert np.array_equal(np.asarray(rp["w"]), np.asarray(params["w"]))
    assert np.array_equal(np.asarray(rs["m"]), np.asarray(sstate["m"]))
    assert wd.healthy(rp, rs)


def test_watchdog_driver_rolls_back_then_aborts_cleanly(tmp_path):
    """Driver loop: fault_seed=15 first corrupts round 2 — AFTER the
    round-2 checkpoint. The deterministic replay re-corrupts, so the
    budget burns down and the run aborts with a clean RuntimeError (not
    a NaN trajectory, not a hang)."""
    from repro.launch.train import run_training
    with pytest.raises(RuntimeError, match="budget exhausted"):
        run_training(rounds=4, num_clients=4, clients_per_round=4,
                     local_steps=2, batch_size=4, eval_every=2,
                     seq_len=16, fault_nan=0.3, fault_seed=15,
                     watchdog=True, watchdog_max_rollbacks=2,
                     ckpt_dir=str(tmp_path), ckpt_every=2)


def test_driver_defended_run_finite_with_history_columns():
    from repro.launch.train import run_training
    h = run_training(rounds=4, num_clients=4, clients_per_round=4,
                     local_steps=2, batch_size=4, eval_every=2,
                     seq_len=16, fault_nan=0.3, robust_agg="norm_filter",
                     min_quorum=1, watchdog=True)
    assert all(np.isfinite(h["train_loss"]))
    assert len(h["agg_survivors"]) == 4
    assert len(h["quorum_ok"]) == 4
    assert h["engine"]["watchdog_rollbacks"] == 0
