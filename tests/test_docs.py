"""Docs stay honest: module doctests run, markdown links resolve.

The CI ``docs`` job runs the same two checks standalone
(``python -m doctest`` + ``tools/check_links.py``); running them inside
tier-1 as well means a broken docstring example or dead link fails fast
locally too.
"""
import doctest
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

# public-API modules whose docstrings carry runnable usage examples
# (the PR-1..4 docstring pass); extend when adding examples elsewhere
DOCTEST_MODULES = [
    "repro.comm.codecs",
    "repro.state.store",
    "repro.launch.pipeline",
    "repro.metrics.deferred",
    "repro.data.sampler",
    "repro.privacy.accountant",
    "repro.telemetry.registry",
    "repro.faults.injection",
    "repro.faults.defense",
    "repro.faults.watchdog",
]


@pytest.mark.parametrize("modname", DOCTEST_MODULES)
def test_module_doctests(modname):
    mod = __import__(modname, fromlist=["_"])
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{modname} lost its doctest examples"
    assert result.failed == 0, f"{modname}: {result.failed} doctest failures"


def _markdown_files():
    docs = sorted((REPO / "docs").glob("*.md"))
    assert docs, "docs/ must contain markdown pages"
    return [REPO / "README.md", REPO / "CHANGES.md", *docs]


def test_markdown_links_resolve():
    files = [str(p) for p in _markdown_files()]
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_links.py"), *files],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr or proc.stdout


def test_docs_cover_required_pages():
    for page in ("architecture.md", "paper_map.md", "scenarios.md",
                 "privacy.md", "observability.md", "faults.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"
    # the README §Scenarios / §Privacy / §Observability / §Fault
    # tolerance sections must link into docs/
    readme = (REPO / "README.md").read_text()
    assert "docs/scenarios.md" in readme
    assert "docs/privacy.md" in readme
    assert "docs/observability.md" in readme
    assert "docs/faults.md" in readme
