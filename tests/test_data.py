"""Data pipeline: Dirichlet partitioning properties + batch assembly."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data import make_task, round_batches, sample_clients
from repro.data.synthetic import dirichlet_label_partition


def _label_skew(labels, parts, num_classes):
    """Mean total-variation distance between client label dists and global."""
    global_p = np.bincount(labels, minlength=num_classes) / len(labels)
    tv = []
    for idx in parts:
        if len(idx) == 0:
            continue
        p = np.bincount(labels[idx], minlength=num_classes) / len(idx)
        tv.append(0.5 * np.abs(p - global_p).sum())
    return float(np.mean(tv))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_partition_covers_everything(seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 500)
    parts = dirichlet_label_partition(labels, 8, 0.5, rng)
    allidx = np.concatenate(parts)
    assert set(allidx.tolist()) <= set(range(500))
    # every sample assigned at least once (padding may duplicate a few)
    assert len(set(allidx.tolist())) >= 490


def test_lower_alpha_is_more_heterogeneous():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 4000)
    skews = {}
    for alpha in (0.1, 10.0):
        r = np.random.default_rng(1)
        parts = dirichlet_label_partition(labels, 16, alpha, r)
        skews[alpha] = _label_skew(labels, parts, 10)
    assert skews[0.1] > 2 * skews[10.0], skews


@pytest.mark.parametrize("kind", ["class_lm", "lm"])
def test_task_shapes(kind):
    task = make_task(kind, vocab_size=64, seq_len=16, num_samples=512,
                     num_clients=8, seed=0)
    rng = np.random.default_rng(0)
    b = task.client_batch(3, 5, rng)
    assert b["tokens"].shape == (5, 16)
    assert b["labels"].shape == (5, 16)
    assert b["tokens"].max() < 64
    tb = task.test_batch(7)
    assert tb["tokens"].shape[1] == 16


def test_class_lm_labels_masked_except_last():
    task = make_task("class_lm", vocab_size=64, seq_len=16, num_samples=128,
                     num_clients=4, seed=1)
    rng = np.random.default_rng(0)
    b = task.client_batch(0, 8, rng)
    assert (b["labels"][:, :-1] == -1).all()
    assert (b["labels"][:, -1] >= 64 - task.num_classes).all()


def test_round_batches_layout():
    task = make_task("class_lm", vocab_size=64, seq_len=16, num_samples=256,
                     num_clients=8, seed=0)
    rng = np.random.default_rng(0)
    cids = sample_clients(8, 4, rng)
    assert len(set(cids.tolist())) == 4
    rb = round_batches(task, cids, 3, 5, rng)
    assert rb["tokens"].shape == (4, 3, 5, 16)
    assert rb["labels"].shape == (4, 3, 5, 16)
