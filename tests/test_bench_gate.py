"""tools/bench_gate.py comparison logic on fabricated reports: green on
a matching baseline, red on a same-machine slowdown or parity break,
machine/config-mismatch skips, and the perturbation helper the CI
red-canary uses. Pure dict plumbing — no measurement, no jax."""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_gate", os.path.join(os.path.dirname(__file__), "..",
                               "tools", "bench_gate.py"))
bench_gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_gate)


def _report(machine="box-a", smoke=False, rps=(10.0, 30.0, 40.0),
            parity=True):
    modes = dict(zip(bench_gate.MODES, rps))
    return {
        "machine": {"host": machine, "cpu": "x86"},
        "config": {"smoke": smoke, "rounds": 32},
        "parity_bitexact": parity,
        "speedup_pipelined_fused_vs_eager": rps[2] / rps[0],
        "modes": {m: {"rounds_per_s": v} for m, v in modes.items()},
    }


def test_gate_green_on_identical_reports():
    base = _report()
    ok, lines = bench_gate.compare_reports(_report(), base)
    assert ok
    assert sum(ln.startswith("PASS") for ln in lines) == 5  # C1+C2+3xC3
    assert not any(ln.startswith("FAIL") for ln in lines)


def test_gate_red_on_same_machine_slowdown():
    base = _report()
    slow = bench_gate.perturb_report(_report(), 0.25)
    ok, lines = bench_gate.compare_reports(slow, base, tol=0.15)
    assert not ok
    fails = [ln for ln in lines if ln.startswith("FAIL")]
    # every mode slowed 25% > 15% tolerance — all three C3 rows trip,
    # and each diff line names its mode with the percentage
    assert len(fails) == 3
    for mode in bench_gate.MODES:
        assert any(f" C3 {mode}: " in ln and "-25.0%" in ln
                   for ln in fails), fails


def test_gate_red_within_but_speedup_regression():
    base = _report()
    fresh = _report(rps=(10.0, 30.0, 15.0))   # fusion speedup 4x -> 1.5x
    ok, lines = bench_gate.compare_reports(fresh, base, tol_speedup=0.5)
    assert not ok
    assert any(ln.startswith("FAIL") and " C2 " in ln for ln in lines)


def test_gate_skips_absolute_check_on_machine_mismatch():
    base = _report(machine="box-a")
    slow = bench_gate.perturb_report(_report(machine="box-b"), 0.5)
    ok, lines = bench_gate.compare_reports(slow, base)
    # a 50% "slowdown" on different hardware is not evidence — C3 must
    # SKIP (explaining why), and the gate stays green on parity+speedup
    assert ok
    assert any(ln.startswith("SKIP") and "C3" in ln and "machine" in ln
               for ln in lines)


def test_gate_skips_relative_checks_on_config_mismatch():
    base = _report(smoke=False)
    fresh = _report(smoke=True)
    ok, lines = bench_gate.compare_reports(fresh, base)
    assert ok
    assert any(ln.startswith("SKIP") and "C2" in ln for ln in lines)
    assert any(ln.startswith("SKIP") and "C3" in ln for ln in lines)


def test_gate_parity_break_always_fails():
    """Trajectory parity is machine-independent: it fails the gate even
    when every throughput check is skipped."""
    base = _report(machine="box-a")
    fresh = _report(machine="box-b", smoke=True, parity=False)
    ok, lines = bench_gate.compare_reports(fresh, base)
    assert not ok
    assert lines[0].startswith("FAIL") and "parity" in lines[0]


def test_perturb_report_scales_all_modes_and_copies():
    orig = _report()
    hurt = bench_gate.perturb_report(orig, 0.25)
    for m in bench_gate.MODES:
        assert hurt["modes"][m]["rounds_per_s"] == pytest.approx(
            0.75 * orig["modes"][m]["rounds_per_s"])
    # deep copy — the original must be untouched
    assert orig["modes"]["eager"]["rounds_per_s"] == 10.0
    json.dumps(hurt)  # still plain JSON


def test_fusion_check_red_on_ratio_collapse():
    base = {"separate_over_fused": 5.77}
    good = {"fused_interface_bytes": 2.3e6, "separate_pass_bytes": 1.3e7}
    ok, _ = bench_gate.compare_fusion(good, base, tol_bytes=0.25)
    assert ok
    collapsed = {"fused_interface_bytes": 1.0e7,
                 "separate_pass_bytes": 1.3e7}   # ratio 1.3x < 4.3x floor
    ok, lines = bench_gate.compare_fusion(collapsed, base, tol_bytes=0.25)
    assert not ok
    assert any(ln.startswith("FAIL") and "ratio" in ln for ln in lines)
    inverted = {"fused_interface_bytes": 2.0e7,
                "separate_pass_bytes": 1.3e7}    # fused GREW past separate
    ok, lines = bench_gate.compare_fusion(inverted, base)
    assert not ok
    assert any(ln.startswith("FAIL") and "invariant" in ln
               for ln in lines)
