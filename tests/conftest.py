import os
import sys

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); make sure no stray XLA_FLAGS leak in.
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (AttentionConfig, ModelConfig, MoEConfig,
                          SSMConfig)
from repro.models import build_model

jax.config.update("jax_enable_x64", False)


def tiny_config(family: str = "dense", **kw) -> ModelConfig:
    base = dict(
        name=f"tiny-{family}", family=family, num_layers=2, d_model=64,
        d_ff=128, vocab_size=64,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2))
    if family == "moe":
        base["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff_expert=96)
    if family in ("ssm", "hybrid"):
        base["ssm"] = SSMConfig(state_dim=16, head_dim=32, chunk_size=16)
    if family == "hybrid":
        base["hybrid_attn_every"] = 2
    if family == "audio":
        base["encoder_layers"] = 2
        base["frontend_embed_dim"] = 48
        base["frontend_tokens_per_sample"] = 8
    if family == "vlm":
        base["frontend_embed_dim"] = 48
        base["frontend_tokens_per_sample"] = 8
    base.update(kw)
    cfg = ModelConfig(**base)
    cfg.validate()
    return cfg


def tiny_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }
    if cfg.family in ("vlm", "audio"):
        out["frontend_feats"] = jnp.asarray(rng.normal(size=(
            batch, cfg.frontend_tokens_per_sample,
            cfg.frontend_embed_dim)), jnp.float32)
    return out


@pytest.fixture(scope="session")
def families():
    return ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


def build_tiny(family: str, **kw):
    cfg = tiny_config(family, **kw)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    return cfg, model, params
