"""Client-state store: policy round-trips, memory, sharding, and the
layout parity that the store unlocks (SCAFFOLD / error feedback under
``client_sequential``).

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.comm import EF_KEY
from repro.config import FedConfig
from repro.core import build_fed_state, make_round_fn
from repro.core.fedadamw import get_algorithm
from repro.core.partition import build_block_specs
from repro.state import ClientStateStore, specs_like, store_for, table_pspecs

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])
POLICIES = ["dense", "blockmean", "int8"]


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(24,)), jnp.float32)}


def _store(policy, num_clients=6, tree=None):
    tree = tree if tree is not None else _tree()
    return ClientStateStore(num_clients=num_clients, policy=policy,
                            specs=specs_like(tree)), tree


# ---------------------------------------------------------------------------
# store unit behavior
# ---------------------------------------------------------------------------

def test_dense_scatter_gather_exact_scalar_and_batched():
    store, v = _store("dense")
    table = store.init()
    # scalar cid
    table = store.scatter(table, jnp.asarray(3), v)
    got = store.gather(table, jnp.asarray(3))
    for k in v:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v[k]))
    # batched cids, rows carry a leading axis
    cids = jnp.asarray([0, 4])
    stacked = jax.tree.map(lambda x: jnp.stack([x, 2 * x]), v)
    table = store.scatter(table, cids, stacked)
    got = store.gather(table, cids)
    for k in v:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(stacked[k]))
    # untouched rows stay zero
    rest = store.gather(table, jnp.asarray(5))
    assert all(float(jnp.abs(x).max()) == 0 for x in jax.tree.leaves(rest))


def test_blockmean_stores_block_means():
    v = _tree()
    # trivial one-block specs: gather returns the per-tensor mean
    store, _ = _store("blockmean", tree=v)
    table = store.scatter(store.init(), jnp.asarray(1), v)
    got = store.gather(table, jnp.asarray(1))
    for k in v:
        np.testing.assert_allclose(
            np.asarray(got[k]),
            np.full(v[k].shape, float(jnp.mean(v[k]))), rtol=1e-6)


def test_int8_roundtrip_error_bound():
    store, v = _store("int8")
    table = store.scatter(store.init(), jnp.asarray(0), v)
    got = store.gather(table, jnp.asarray(0))
    for k in v:
        scale = float(jnp.max(jnp.abs(v[k]))) / 127.0
        err = float(jnp.max(jnp.abs(got[k] - v[k])))
        assert err <= 0.5 * scale + 1e-7, (k, err, scale)


@pytest.mark.parametrize("policy", POLICIES)
def test_batched_scatter_equals_scalar_loop(policy):
    store, v = _store(policy)
    rows = jax.tree.map(lambda x: jnp.stack([x, -x, 0.5 * x]), v)
    cids = jnp.asarray([1, 2, 5])
    t_batched = store.scatter(store.init(), cids, rows)
    t_loop = store.init()
    for i in range(3):
        t_loop = store.scatter(t_loop, cids[i],
                               jax.tree.map(lambda r: r[i], rows))
    for a, b in zip(jax.tree.leaves(t_batched), jax.tree.leaves(t_loop)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    ga = store.gather(t_batched, cids)
    gb = store.gather(t_loop, cids)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        ClientStateStore(num_clients=2, policy="float16",
                         specs=specs_like(_tree()))
    with pytest.raises(ValueError):
        FedConfig(client_state_policy="bogus").validate()


def test_int8_table_memory_reduction():
    """Acceptance: int8 store >= 3.5x smaller than dense on a real model's
    param tree; blockmean orders of magnitude smaller still."""
    cfg, _, params = build_tiny("dense")
    fed = FedConfig(num_clients=16)
    specs = build_block_specs(params, cfg, fed)
    sizes = {p: store_for(fed, specs, policy=p).table_bytes()
             for p in POLICIES}
    assert sizes["dense"] / sizes["int8"] >= 3.5, sizes
    assert sizes["blockmean"] < sizes["int8"], sizes


# ---------------------------------------------------------------------------
# sharding: the table distributes over the client mesh axes
# ---------------------------------------------------------------------------

class MeshStub:
    """Duck-typed Mesh: spec rules only read axis_names and shape."""

    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)


@pytest.mark.parametrize("policy", POLICIES)
def test_table_pspecs_shard_client_axis(policy):
    from jax.sharding import PartitionSpec as P
    mesh = MeshStub({"pod": 2, "data": 16, "model": 16})
    store, _ = _store(policy, num_clients=64)
    table = jax.eval_shape(store.init)
    pspecs = table_pspecs(table, mesh, 64)
    flat_t = jax.tree.leaves(table)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_t) == len(flat_s)
    # 64 % (2*16) == 0: every table leaf's client axis is sharded
    for leaf, spec in zip(flat_t, flat_s):
        assert spec[0] == ("pod", "data"), (leaf.shape, spec)
        assert all(s is None for s in spec[1:])


def test_table_pspecs_fall_back_when_indivisible():
    from jax.sharding import PartitionSpec as P
    mesh = MeshStub({"pod": 2, "data": 16, "model": 16})
    store, _ = _store("dense", num_clients=7)  # 7 % 32 != 0
    table = jax.eval_shape(store.init)
    for spec in jax.tree.leaves(table_pspecs(table, mesh, 7),
                                is_leaf=lambda x: isinstance(x, P)):
        assert all(s is None for s in spec)


def test_state_pspecs_shard_scaffold_table():
    from jax.sharding import PartitionSpec as P

    from repro.sharding import specs as shspecs
    mesh = MeshStub({"pod": 2, "data": 16, "model": 16})
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(algorithm="scaffold", num_clients=64,
                    clients_per_round=4)
    specs = build_block_specs(params, cfg, fed)
    alg = get_algorithm(fed)
    sstate = jax.eval_shape(lambda: alg.init_server(params, specs, fed))
    param_ps = shspecs.param_pspecs(params, cfg, mesh, fed)
    state_ps = shspecs.state_pspecs(sstate, param_ps, params, cfg, mesh, fed)
    table_specs = jax.tree.leaves(state_ps["c_all"],
                                  is_leaf=lambda x: isinstance(x, P))
    assert table_specs
    for s in table_specs:
        assert s[0] == ("pod", "data"), s
    # the global control variate c stays param-sharded/replicated, never
    # client-sharded
    for s in jax.tree.leaves(state_ps["c"],
                             is_leaf=lambda x: isinstance(x, P)):
        assert s[0] != ("pod", "data")


# ---------------------------------------------------------------------------
# layout parity: the bug this PR fixes — SCAFFOLD / EF in BOTH layouts
# ---------------------------------------------------------------------------

def _run_rounds(algorithm, layout, policy="dense", rounds=3, num_clients=4):
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm=algorithm, num_clients=num_clients,
                    clients_per_round=num_clients, local_steps=3, lr=1e-3,
                    layout=layout, client_state_policy=policy,
                    sequential_clients=num_clients)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (num_clients, 3, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    cids = jnp.arange(num_clients, dtype=jnp.int32)
    losses = []
    for r in range(rounds):
        params, sstate, m = round_fn(params, sstate, batch, cids,
                                     jnp.asarray(r))
        losses.append(float(m["loss_mean"]))
    return params, sstate, losses


@pytest.mark.parametrize("algorithm", ["scaffold", "fedadamw+int4"])
def test_parallel_sequential_parity_stateful_algorithms(algorithm):
    """The satellite/acceptance parity: SCAFFOLD and fedadamw+int4 (EF on)
    must produce the same multi-round trajectory under both layouts —
    previously client_sequential raised NotImplementedError for scaffold
    and SILENTLY dropped error feedback for lossy codecs."""
    p_par, s_par, l_par = _run_rounds(algorithm, "client_parallel")
    p_seq, s_seq, l_seq = _run_rounds(algorithm, "client_sequential")
    np.testing.assert_allclose(l_par, l_seq, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_par), jax.tree.leaves(p_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=5e-4)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_error_feedback_applied_in_layout(layout):
    """Regression for the silent-state bug: get_algorithm must keep error
    feedback in EVERY layout (it used to drop it under client_sequential
    without warning), and the residual table must actually accumulate."""
    fed = FedConfig(algorithm="fedadamw+int4", layout=layout,
                    num_clients=4, clients_per_round=4)
    alg = get_algorithm(fed)
    assert alg.needs_client_ids and alg.commit is not None
    _, sstate, losses = _run_rounds("fedadamw+int4", layout)
    assert EF_KEY in sstate
    resid = sum(float(jnp.sum(jnp.abs(t)))
                for t in jax.tree.leaves(sstate[EF_KEY]))
    assert resid > 0.0 and np.isfinite(resid)
    assert all(np.isfinite(losses))


@functools.lru_cache(maxsize=None)
def _losses(algorithm, layout, policy):
    return tuple(_run_rounds(algorithm, layout, policy)[2])


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("algorithm", ["scaffold", "fedadamw+int4"])
@pytest.mark.parametrize("policy", ["blockmean", "int8"])
def test_lossy_policies_track_dense(policy, algorithm, layout):
    """blockmean/int8 store policies stay within tolerance of dense."""
    l_dense = _losses(algorithm, layout, "dense")
    l_pol = _losses(algorithm, layout, policy)
    assert all(np.isfinite(l_pol))
    assert abs(l_pol[-1] - l_dense[-1]) < 0.1 * abs(l_dense[-1]), \
        (policy, l_dense, l_pol)


def test_scaffold_sequential_updates_control_variates():
    """c and c_all must move under the sequential layout too (the
    NotImplementedError is gone for real, not just bypassed)."""
    _, sstate, _ = _run_rounds("scaffold", "client_sequential")
    c_norm = sum(float(jnp.sum(jnp.abs(c)))
                 for c in jax.tree.leaves(sstate["c"]))
    table_norm = sum(float(jnp.sum(jnp.abs(t)))
                     for t in jax.tree.leaves(sstate["c_all"]))
    assert c_norm > 0.0 and table_norm > 0.0
