"""Algorithm-level unit tests for FedAdamW and the seven baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.comm import upload_wire_bytes
from repro.core import (build_fed_state, get_algorithm, init_server_state,
                        make_round_fn)
from repro.core.partition import build_block_specs


def _one_round(model, cfg, fed, *, seed=0, rounds=1, batches_seed=0,
               fixed_batch=False):
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(seed), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(batches_seed)
    s, k, b, seq = fed.clients_per_round, fed.local_steps, 4, 16
    toks = rng.integers(0, cfg.vocab_size, (s, k, b, seq))
    losses = []
    for r in range(rounds):
        if not fixed_batch and r > 0:
            toks = rng.integers(0, cfg.vocab_size, (s, k, b, seq))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
        cids = jnp.arange(s, dtype=jnp.int32)
        params, sstate, m = round_fn(params, sstate, batch, cids,
                                     jnp.asarray(r))
        losses.append(float(m["loss_mean"]))
    m = dict(m)
    m["losses"] = losses
    return params, sstate, m


ALGOS = ["fedadamw", "fedavg", "scaffold", "fedcm", "fedadam", "fedlada",
         "local_adam", "local_adamw"]


@pytest.mark.parametrize("algorithm", ALGOS)
def test_every_algorithm_round_is_finite(algorithm):
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm=algorithm, num_clients=4, clients_per_round=2,
                    local_steps=3, lr=1e-3)
    params, sstate, m = _one_round(model, cfg, fed)
    assert np.isfinite(float(m["loss_mean"]))
    for p in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(p)))


def test_loss_decreases_over_rounds():
    """On a fixed (memorizable) batch, FedAdamW's round losses must fall."""
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm="fedadamw", num_clients=4, clients_per_round=4,
                    local_steps=8, lr=3e-3)
    _, _, m = _one_round(model, cfg, fed, rounds=5, fixed_batch=True)
    assert m["losses"][-1] < m["losses"][0]


def test_fedadamw_v_warm_start_progresses_t():
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm="fedadamw", num_clients=2, clients_per_round=2,
                    local_steps=3, lr=1e-3)
    _, sstate, _ = _one_round(model, cfg, fed, rounds=2)
    assert int(sstate["t"]) == 2 * fed.local_steps
    # v_bar must be non-zero after training (second moments accumulated)
    vb = jnp.concatenate([v.reshape(-1) for v in
                          jax.tree.leaves(sstate["v_bar"])])
    assert float(jnp.max(vb)) > 0.0


def test_upload_bytes_ordering_matches_table7():
    """mean_v (ours) uploads O(d + B); full_v O(2d); full_vm O(3d)."""
    cfg, model, _ = build_tiny("dense")
    sizes = {}
    for agg in ("none", "mean_v", "full_v", "full_vm"):
        fed = FedConfig(algorithm="fedadamw", v_aggregation=agg,
                        num_clients=2, clients_per_round=2, local_steps=1)
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        up = jax.eval_shape(
            lambda: alg.upload(params,
                               alg.init_client(params, sstate, fed,
                                               specs=specs), specs, fed))
        sizes[agg] = upload_wire_bytes(up)
    d_bytes = sizes["none"]
    assert sizes["none"] < sizes["mean_v"] < 1.1 * d_bytes
    assert sizes["full_v"] > 1.8 * d_bytes
    assert sizes["full_vm"] > 2.7 * d_bytes


def test_alpha_zero_disables_global_correction():
    """alpha=0 + no v aggregation + local bias correction == Local AdamW:
    one round from the same init must produce identical parameters."""
    cfg, model, _ = build_tiny("dense")
    fed_a = FedConfig(algorithm="fedadamw", alpha=0.0, v_aggregation="none",
                      global_t_bias_correction=False, num_clients=2,
                      clients_per_round=2, local_steps=3, lr=1e-3)
    fed_b = dataclasses.replace(fed_a, algorithm="local_adamw")
    pa, _, _ = _one_round(model, cfg, fed_a)
    pb, _, _ = _one_round(model, cfg, fed_b)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_fedavg_single_client_equals_sgd():
    """With S=1 client and gamma=1, FedAvg is exactly K SGD steps."""
    cfg, model, params0 = build_tiny("dense")
    fed = FedConfig(algorithm="fedavg", num_clients=1, clients_per_round=1,
                    local_steps=4, lr=1e-2, weight_decay=0.0)
    specs = build_block_specs(params0, cfg, fed)
    alg = get_algorithm(fed)
    sstate = init_server_state(alg, params0, specs, fed)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 4, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    p_fed, _, _ = round_fn(params0, sstate, batch,
                           jnp.zeros((1,), jnp.int32), jnp.asarray(0))

    p_sgd = params0
    for k in range(4):
        step_batch = {kk: v[0, k] for kk, v in batch.items()}
        (_, _), g = jax.value_and_grad(model.loss, has_aux=True)(
            p_sgd, step_batch)
        p_sgd = jax.tree.map(lambda p, gi: p - 1e-2 * gi, p_sgd, g)
    for a, b in zip(jax.tree.leaves(p_fed), jax.tree.leaves(p_sgd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_scaffold_control_variates_update():
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm="scaffold", num_clients=4, clients_per_round=2,
                    local_steps=3, lr=1e-2)
    _, sstate, _ = _one_round(model, cfg, fed)
    c_norm = sum(float(jnp.sum(jnp.abs(c)))
                 for c in jax.tree.leaves(sstate["c"]))
    assert c_norm > 0.0  # server control variate moved


def test_weight_decay_shrinks_weights():
    """Decoupled decay with zero-ish gradients must shrink parameters."""
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(algorithm="fedadamw", weight_decay=0.1, alpha=0.0,
                    v_aggregation="none", num_clients=1,
                    clients_per_round=1, local_steps=5, lr=1e-2)
    alg = get_algorithm(fed)
    specs = build_block_specs(params, cfg, fed)
    sstate = init_server_state(alg, params, specs, fed)
    cstate = alg.init_client(params, sstate, fed, specs=specs)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p = params
    for _ in range(3):
        p, cstate = alg.local_step(p, zeros, cstate, sstate, fed, 1.0)
    n0 = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(params))
    n1 = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(p))
    assert n1 < n0


def test_delta_g_is_negative_mean_delta_over_k_eta():
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm="fedadamw", num_clients=2, clients_per_round=2,
                    local_steps=2, lr=1e-3)
    params, specs, alg, sstate0 = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 2, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    p1, sstate1, _ = round_fn(params, sstate0, batch,
                              jnp.arange(2, dtype=jnp.int32), jnp.asarray(0))
    # server: x1 = x0 + gamma * mean_delta  =>  mean_delta = x1 - x0
    # delta_g = -mean_delta / (K * eta)
    scale = -1.0 / (fed.local_steps * fed.lr)
    for dg, a, b in zip(jax.tree.leaves(sstate1["delta_g"]),
                        jax.tree.leaves(p1), jax.tree.leaves(params)):
        np.testing.assert_allclose(
            np.asarray(dg), scale * (np.asarray(a) - np.asarray(b)),
            rtol=2e-3, atol=2e-4)


def test_scaffold_upload_uses_scaled_lr():
    """c_i+ must divide delta by the eta the local steps ACTUALLY used:
    under cosine decay lr_scale != 1 and pricing with the unscaled
    fed.lr would mis-scale the control variates."""
    from repro.core.tree_util import tree_sub
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(algorithm="scaffold", num_clients=2, clients_per_round=2,
                    local_steps=1, lr=1e-2, weight_decay=0.0)
    alg = get_algorithm(fed)
    specs = build_block_specs(params, cfg, fed)
    sstate = init_server_state(alg, params, specs, fed)
    cstate = alg.init_client(params, sstate, fed, specs=specs,
                             client_id=jnp.asarray(0, jnp.int32))
    g = jax.tree.map(jnp.ones_like, params)
    p1, cstate = alg.local_step(params, g, cstate, sstate, fed,
                                jnp.asarray(0.5, jnp.float32))
    delta = tree_sub(p1, params)
    up = alg.upload(delta, cstate, specs, fed)
    # c_i = 0, c = 0: c_new_minus_c == -delta / (K * lr * lr_scale)
    scale = -1.0 / (fed.local_steps * fed.lr * 0.5)
    for got, d in zip(jax.tree.leaves(up["c_new_minus_c"]),
                      jax.tree.leaves(delta)):
        np.testing.assert_allclose(np.asarray(got),
                                   scale * np.asarray(d, np.float32),
                                   rtol=1e-5, atol=1e-7)
