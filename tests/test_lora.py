"""LoRA adapter tests (the paper's RoBERTa+LoRA federated setting)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import build_tiny, tiny_batch
from repro.config import FedConfig
from repro.core import get_algorithm, init_server_state, make_round_fn
from repro.core.partition import build_block_specs
from repro.lora import build_lora_model, init_lora, merge_lora


def test_zero_B_is_identity():
    """Fresh LoRA (B=0) must not change the model function."""
    cfg, model, params = build_tiny("dense")
    lora = init_lora(params, jax.random.key(1), rank=4)
    merged = merge_lora(params, lora)
    batch = tiny_batch(cfg)
    l1, _ = model.loss(params, batch)
    l2, _ = model.loss(merged, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_lora_delta_rank():
    cfg, model, params = build_tiny("dense")
    lora = init_lora(params, jax.random.key(1), rank=2)
    # poke B so the delta is non-zero
    for v in lora["lora"].values():
        v["B"] = jnp.ones_like(v["B"])
    merged = merge_lora(params, lora)
    key = [k for k in lora["lora"]][0]
    names = key.split("\x1f")
    orig = params
    new = merged
    for n in names:
        orig, new = orig[n], new[n]
    delta = np.asarray(new - orig, np.float64).reshape(orig.shape[0], -1)
    rank = np.linalg.matrix_rank(delta, tol=1e-5)
    assert rank <= 2, rank


def test_federated_lora_trains_and_freezes_base():
    cfg, model, base = build_tiny("dense")
    lm = build_lora_model(model, base)
    lora = lm.init(jax.random.key(2), rank=4)
    fed = FedConfig(algorithm="fedadamw", num_clients=2,
                    clients_per_round=2, local_steps=3, lr=1e-2)
    specs = build_block_specs(lora, cfg, fed)
    alg = get_algorithm(fed)
    sstate = init_server_state(alg, lora, specs, fed)
    round_fn = jax.jit(make_round_fn(lm, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 3, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    lora2, _, m = round_fn(lora, sstate, batch,
                           jnp.arange(2, dtype=jnp.int32), jnp.asarray(0))
    assert np.isfinite(float(m["loss_mean"]))
    moved = any(
        not bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree.leaves(lora), jax.tree.leaves(lora2)))
    assert moved
    # base params untouched by construction (closure), loss still works
    l, _ = lm.loss(lora2, {k: v[0, 0] for k, v in batch.items()})
    assert jnp.isfinite(l)
