"""Pipelined round engine: block planning, prefetch/fusion parity,
donation safety, deferred metrics, and the exact full-split eval."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state
from repro.data import RoundBatchGenerator, make_task
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,
                                   eval_boundaries, plan_round_blocks)
from repro.metrics import MetricsSpool

ROUNDS, EVERY = 6, 3


def _task(cfg, num_clients=4, seq_len=16, num_samples=256, seed=0):
    return make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=seq_len,
                     num_samples=num_samples, num_clients=num_clients,
                     dirichlet_alpha=0.6, seed=seed)


def _gen(task, seed=7, local_steps=2, batch_size=2):
    return RoundBatchGenerator(task, num_clients=task.num_clients,
                               clients_per_round=2, local_steps=local_steps,
                               batch_size=batch_size, rng=seed)


# ---------------------------------------------------------------- planning

@pytest.mark.parametrize("rounds,every,rpc", [
    (10, 4, 3), (10, 1, 4), (7, 100, 3), (5, 5, 1), (1, 1, 8), (12, 3, 3),
])
def test_plan_round_blocks_covers_and_respects_eval(rounds, every, rpc):
    blocks = plan_round_blocks(rounds, every, rpc)
    # exact cover, in order
    covered = [r for start, size in blocks for r in range(start, start + size)]
    assert covered == list(range(rounds))
    ends = set(eval_boundaries(rounds, every))
    for start, size in blocks:
        assert 1 <= size <= rpc
        # a block never straddles an eval boundary: no eval round strictly
        # inside [start, start+size-1)
        assert not any(r in ends for r in range(start, start + size - 1))
    assert rounds - 1 in ends


def test_plan_round_blocks_rejects_bad_rpc():
    with pytest.raises(ValueError):
        plan_round_blocks(4, 2, 0)


# ---------------------------------------------------------- data generator

def test_generator_stacked_matches_per_round():
    cfg, _, _ = build_tiny("dense")
    task = _task(cfg)
    a, b = _gen(task, seed=3), _gen(task, seed=3)
    singles = [a.next_round() for _ in range(4)]
    stacked_b, cids_b = b.next_rounds(4)
    for k in stacked_b:
        np.testing.assert_array_equal(
            stacked_b[k], np.stack([s[0][k] for s in singles]))
    np.testing.assert_array_equal(cids_b, np.stack([s[1] for s in singles]))


def test_prefetcher_depth0_matches_background():
    cfg, _, _ = build_tiny("dense")
    task = _task(cfg)
    blocks = plan_round_blocks(ROUNDS, EVERY, 1)
    out = {}
    for depth in (0, 2):
        items = list(HostPrefetcher(_gen(task), blocks, depth=depth,
                                    to_device=False))
        out[depth] = items
        assert [(s, z) for s, z, _, _ in items] == blocks
    for (s0, z0, b0, c0), (s1, z1, b1, c1) in zip(out[0], out[2]):
        assert jnp.array_equal(c0, c1)
        for k in b0:
            assert jnp.array_equal(b0[k], b1[k])


def test_prefetcher_propagates_producer_error():
    class Boom:
        def next_round(self):
            raise RuntimeError("producer exploded")

    pre = HostPrefetcher(Boom(), [(0, 1)], depth=1, stacked=False,
                         to_device=False)
    with pytest.raises(RuntimeError, match="producer exploded"):
        list(pre)


def test_producer_raise_midrun_cannot_deadlock_shutdown():
    """A producer that fills the bounded queue and THEN raises, with a
    consumer that never drains (it crashed elsewhere), must not wedge:
    the exception put honors the stop flag, and close() returns within
    its deadline with the thread gone."""
    import time as _time

    class FillThenBoom:
        def __init__(self):
            self.calls = 0

        def next_round(self):
            self.calls += 1
            if self.calls > 1:
                raise RuntimeError("mid-run explosion")
            return {"tokens": np.zeros((1, 1, 2, 4), np.int32),
                    "labels": np.zeros((1, 1, 2, 4), np.int32)}, \
                np.zeros((1,), np.int32)

    pre = HostPrefetcher(FillThenBoom(), [(0, 1), (1, 1)], depth=1,
                         stacked=False, to_device=False)
    it = iter(pre)
    next(it)                      # start the thread, take one item
    # queue now holds the exception (or the producer is retrying the
    # put); shut down WITHOUT draining it
    t0 = _time.monotonic()
    pre.close(timeout=5.0)
    assert _time.monotonic() - t0 < 5.5
    assert pre._thread is None


def test_close_deadline_abandons_wedged_producer():
    """A producer stuck inside _produce (hung staging, generator bug)
    must not hang close(): past the deadline the daemon thread is
    abandoned and the call returns."""
    import threading as _threading
    import time as _time
    release = _threading.Event()

    class Wedged:
        def next_round(self):
            release.wait(30.0)    # simulates a hung device_put
            return {"tokens": np.zeros((1, 1, 2, 4), np.int32),
                    "labels": np.zeros((1, 1, 2, 4), np.int32)}, \
                np.zeros((1,), np.int32)

    import queue as _queue
    pre = HostPrefetcher(Wedged(), [(0, 1)], depth=1, stacked=False,
                         to_device=False)
    # start the producer the way __iter__ does, without the consumer
    # blocking on the (never-filled) queue
    pre._queue = _queue.Queue(maxsize=1)
    pre._thread = _threading.Thread(target=pre._producer_loop,
                                    daemon=True)
    pre._thread.start()
    _time.sleep(0.2)              # let it wedge inside _produce
    t0 = _time.monotonic()
    pre.close(timeout=0.5)
    took = _time.monotonic() - t0
    release.set()                 # let the daemon thread die
    assert took < 3.0
    assert pre._thread is None


# -------------------------------------------------------------- metrics

def test_metrics_spool_scalar_and_stacked():
    spool = MetricsSpool()
    spool.append(0, {"loss_mean": jnp.asarray(1.5)})
    spool.append(1, {"loss_mean": jnp.asarray([2.5, 3.5])}, num_rounds=2)
    assert len(spool) == 3
    rows = spool.flush()
    assert rows == [(0, {"loss_mean": 1.5}), (1, {"loss_mean": 2.5}),
                    (2, {"loss_mean": 3.5})]
    assert spool.flush() == []  # drained


# ------------------------------------------------- trajectory parity (tiny)

def _drive(engine, params, sstate, gen, blocks, depth):
    """Run all blocks through the engine; returns (losses, params)."""
    pre = HostPrefetcher(gen, blocks, depth=depth, stacked=engine.stacked)
    spool = MetricsSpool()
    for start, size, batches, cids in pre:
        params, sstate, m = engine.run_block(params, sstate, batches, cids,
                                             start, size)
        spool.append(start, m, size)
    return [m["loss_mean"] for _, m in spool.flush()], params, sstate


@pytest.mark.parametrize("algorithm", ["fedadamw", "scaffold"])
@pytest.mark.parametrize("layout", ["client_parallel", "client_sequential"])
def test_modes_bit_exact(algorithm, layout):
    """Eager loop, prefetched loop, and rounds_per_call>1 must produce
    BIT-identical loss trajectories and final params for algorithms with
    and without per-client server state, in both placement layouts."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    base = FedConfig(algorithm=algorithm, num_clients=4, clients_per_round=2,
                     local_steps=2, lr=1e-3, layout=layout,
                     sequential_clients=2)
    params, specs, alg, sstate = build_fed_state(
        model, base, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, base, specs, alg=alg,
                         cosine_total_rounds=ROUNDS, donate=False)
    fused_fed = dataclasses.replace(base, rounds_per_call=3)
    fused_engine = RoundEngine(model, fused_fed, specs, alg=alg,
                               cosine_total_rounds=ROUNDS, donate=False)

    single_blocks = plan_round_blocks(ROUNDS, EVERY, 1)
    fused_blocks = plan_round_blocks(ROUNDS, EVERY, 3)
    l_eager, p_eager, s_eager = _drive(
        engine, params, sstate, _gen(task), single_blocks, depth=0)
    l_pre, p_pre, _ = _drive(
        engine, params, sstate, _gen(task), single_blocks, depth=2)
    l_fused, p_fused, s_fused = _drive(
        fused_engine, params, sstate, _gen(task), fused_blocks, depth=2)

    assert l_eager == l_pre == l_fused, (l_eager, l_pre, l_fused)
    for a, b, c in zip(jax.tree.leaves(p_eager), jax.tree.leaves(p_pre),
                       jax.tree.leaves(p_fused)):
        assert jnp.array_equal(a, b) and jnp.array_equal(a, c)
    # per-client server state (SCAFFOLD control variates) must match too
    for a, b in zip(jax.tree.leaves(s_eager), jax.tree.leaves(s_fused)):
        assert jnp.array_equal(a, b)


# ------------------------------------------------------------- donation

def test_donation_consumes_inputs_without_stale_reuse():
    """donate_argnums=(0,1) must (a) leave the trajectory bit-identical
    to the undonated engine and (b) actually consume the donated buffers
    — no silent reuse of stale params/sstate after round_fn returns."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(algorithm="fedadamw", num_clients=4, clients_per_round=2,
                    local_steps=2, lr=1e-3)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    plain = RoundEngine(model, fed, specs, alg=alg, donate=False)
    donating = RoundEngine(model, fed, specs, alg=alg, donate=True)
    blocks = plan_round_blocks(4, 4, 1)

    l_ref, p_ref, _ = _drive(plain, params, sstate, _gen(task), blocks, 0)

    p = jax.tree.map(jnp.copy, params)
    s = jax.tree.map(jnp.copy, sstate)
    first_leaf = jax.tree.leaves(p)[0]
    losses = []
    for start, size, batches, cids in HostPrefetcher(
            _gen(task), blocks, depth=0):
        p, s, m = donating.run_block(p, s, batches, cids, start, size)
        losses.append(float(m["loss_mean"]))
    assert losses == l_ref
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
        assert jnp.array_equal(a, b)
    # the donated input buffer is gone — reading it must raise, not
    # silently serve stale data
    assert first_leaf.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(first_leaf)
    # originals (never passed to the donating engine) are untouched
    assert not jax.tree.leaves(params)[0].is_deleted()


# ---------------------------------------------------------- full-split eval

def test_evaluate_full_split_exact():
    """evaluate() must equal the masked mean over the WHOLE test split —
    including when the split does not divide the eval batch size (padding
    rows are fully masked, so they carry zero weight)."""
    from repro.launch.train import evaluate, make_eval_fn
    cfg, model, params = build_tiny("dense")
    task = _task(cfg, num_samples=200)  # test split: 30 samples
    bs = 8  # 30 % 8 != 0 -> padded final batch
    got = evaluate(model, params, task, batch_size=bs,
                   eval_fn=make_eval_fn(model))

    whole = {"tokens": jnp.asarray(task.test_tokens),
             "labels": jnp.asarray(task.test_labels)}
    loss, metrics = model.loss(params, whole)
    assert got["test_loss"] == pytest.approx(float(loss), rel=1e-5)
    assert got["test_acc"] == pytest.approx(float(metrics["accuracy"]),
                                            rel=1e-5)


# ------------------------------------------------------- end-to-end driver

def test_run_training_mode_parity_and_history():
    """run_training trajectories are identical across eager / prefetched /
    fused execution, train_loss records EVERY round, and eval rounds
    carry the full-split metrics."""
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
              num_clients=4, clients_per_round=2, local_steps=2,
              batch_size=4, eval_every=2, seed=3)
    h_eager = run_training(**kw, prefetch_depth=0, rounds_per_call=1,
                           donate=False)
    h_pre = run_training(**kw, prefetch_depth=2, rounds_per_call=1)
    h_fused = run_training(**kw, prefetch_depth=2, rounds_per_call=2)
    assert h_eager["train_loss"] == h_pre["train_loss"] == \
        h_fused["train_loss"]
    assert h_eager["test_acc"] == h_pre["test_acc"] == h_fused["test_acc"]
    assert h_eager["test_loss"] == h_fused["test_loss"]
    assert len(h_eager["train_loss"]) == 4      # every round recorded
    assert h_eager["round"] == [1, 3]           # eval rounds only
    assert len(h_eager["test_acc"]) == 2
    assert all(np.isfinite(v) for v in h_eager["train_loss"])
    assert h_fused["engine"]["rounds_per_call"] == 2
