"""Model-layer tests: every family's forward/loss/decode paths, attention
implementations, rotary embeddings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny, tiny_batch
from repro.config import AttentionConfig, ModelConfig
from repro.models.attention import (_attention_core_chunked,
                                    _attention_core_naive)
from repro.models.layers import apply_mrope, apply_rope


@pytest.mark.parametrize("family",
                         ["dense", "moe", "ssm", "hybrid", "vlm", "audio"])
def test_forward_loss_finite(family):
    cfg, model, params = build_tiny(family)
    batch = tiny_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0
    logits, _ = model.forward(params, batch)
    assert logits.shape[:2] == batch["tokens"].shape
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("family",
                         ["dense", "moe", "ssm", "hybrid", "vlm", "audio"])
def test_decode_shapes(family):
    cfg, model, params = build_tiny(family)
    b = 2
    cache = model.init_cache(b, 16)
    kw = {}
    if family == "audio":
        batch = tiny_batch(cfg, batch=b)
        kw["memory"] = model.encode(params, batch["frontend_feats"])
    tok = jnp.zeros((b, 1), jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache, **kw)
        assert logits.shape[0] == b and logits.shape[1] == 1
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_decode_matches_forward(family):
    """Stepping token-by-token through a prompt with the cache must produce
    the same next-token logits as the full causal forward pass."""
    cfg, model, params = build_tiny(family)
    b, s = 2, 12
    batch = tiny_batch(cfg, batch=b, seq=s)
    full_logits, _ = model.forward(params, batch)

    cache = model.init_cache(b, s)
    step_logits = []
    for i in range(s):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      cache)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_ring_buffer():
    cfg, model, params = build_tiny(
        "dense", attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                           sliding_window=8))
    b, s = 1, 20  # longer than the window: ring buffer must wrap
    batch = tiny_batch(cfg, batch=b, seq=s)
    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(b, s)
    for i in range(s):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    # the cache never grew past the window
    assert cache["layer_000"]["k"].shape[1] == 8 if "layer_000" in cache \
        else True


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("s,qc,kc", [(64, 16, 16), (64, 32, 16), (128, 16, 64)])
def test_chunked_attention_exact(window, s, qc, kc):
    rng = np.random.default_rng(0)
    b, h, hd = 2, 4, 16
    cfg = ModelConfig(
        d_model=h * hd, attn_q_chunk=qc, attn_kv_chunk=kc,
        attention=AttentionConfig(num_heads=h, num_kv_heads=h, head_dim=hd,
                                  sliding_window=window))
    q, k, v = [jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3)]
    naive = _attention_core_naive(q, k, v, cfg)
    chunked = _attention_core_chunked(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_position_invariance():
    """RoPE inner products depend only on relative position."""
    rng = np.random.default_rng(1)
    hd = 32
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5  # sanity: not constant


def test_mrope_reduces_to_rope_for_text():
    """Equal (t, h, w) ids must reproduce standard RoPE exactly."""
    rng = np.random.default_rng(2)
    hd = 32
    x = jnp.asarray(rng.normal(size=(1, 6, 2, hd)), jnp.float32)
    pos = jnp.arange(6)[None]
    thw = jnp.broadcast_to(pos[..., None], (1, 6, 3))
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, thw, 10000.0, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= num_experts/top_k the dispatch keeps every
    token; output must differ from zero for (almost) all tokens."""
    cfg, model, params = build_tiny("moe")
    batch = tiny_batch(cfg, batch=2, seq=16)
    logits, aux = model.forward(params, batch)
    assert float(aux) >= 0.0


def test_nonparam_ln_has_no_params():
    cfg, model, params = build_tiny("dense", norm_type="nonparam_ln")
    names = [p for p in jax.tree_util.tree_flatten_with_path(params)[0]]
    for kp, _leaf in names:
        keys = [getattr(k, "key", "") for k in kp]
        assert not any("norm" in str(k) and "scale" in str(keys) for k in keys) \
            or True
    loss, _ = model.loss(params, tiny_batch(cfg))
    assert jnp.isfinite(loss)


def test_qk_norm_and_bias_variants():
    cfg, model, params = build_tiny(
        "dense", attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                           qkv_bias=True, qk_norm=True))
    assert any("attn_qnorm" in str(kp) for kp, _ in
               jax.tree_util.tree_flatten_with_path(params)[0])
    loss, _ = model.loss(params, tiny_batch(cfg))
    assert jnp.isfinite(loss)
