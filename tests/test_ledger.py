"""Per-client flight recorder (repro.telemetry.ledger): bit-identical
stats across execution modes and layouts, ledger-off jaxpr byte-parity,
ledger-on trajectory non-perturbation, run_training export schema
(wire-bytes accounting, crash salvage), and the compile/memory
observability counters."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro import telemetry
from repro.config import FedConfig
from repro.core import build_fed_state
from repro.core.rounds import trace_round_jaxpr
from repro.data import RoundBatchGenerator, make_task
from repro.faults.defense import INJECTED_CODES, VERDICT_CODES
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,
                                   plan_round_blocks,
                                   sample_memory_gauges)
from repro.metrics import MetricsSpool
from repro.telemetry.ledger import (LEDGER_COLUMNS, LEDGER_MANIFEST,
                                    LEDGER_METRIC_KEY, LEDGER_NPZ,
                                    FlightRecorder, load_ledger)

# honor the CI layout matrix (same pattern as test_telemetry.py)
_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT", "")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

ROUNDS, EVERY = 6, 3
_COL = {name: i for i, name in enumerate(LEDGER_COLUMNS)}


def _task(cfg, num_clients=8, seq_len=16, num_samples=256, seed=0):
    return make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=seq_len,
                     num_samples=num_samples, num_clients=num_clients,
                     dirichlet_alpha=0.6, seed=seed)


def _gen(task, seed=7, local_steps=2, batch_size=2, sample=4):
    return RoundBatchGenerator(task, num_clients=task.num_clients,
                               clients_per_round=sample,
                               local_steps=local_steps,
                               batch_size=batch_size, rng=seed)


def _active_fed(layout, **over):
    """Every ledger column live at once: stragglers vary the step
    counts, faults + defense produce verdicts, DP produces clip bits."""
    kw = dict(algorithm="fedadamw", num_clients=8, clients_per_round=4,
              local_steps=2, lr=1e-3, layout=layout,
              sequential_clients=4, straggler_frac=0.5,
              fault_drop=0.25, fault_nan=0.25, robust_agg="mean",
              dp_clip=1.0, dp_noise_multiplier=0.5,
              telemetry_ledger=True)
    kw.update(over)
    return FedConfig(**kw)


def _drive_blocks(engine, params, sstate, gen, blocks, depth):
    pre = HostPrefetcher(gen, blocks, depth=depth, stacked=engine.stacked)
    spool = MetricsSpool(array_ndim={LEDGER_METRIC_KEY: 2})
    for start, size, batches, cids in pre:
        params, sstate, m = engine.run_block(params, sstate, batches, cids,
                                             start, size)
        spool.append(start, m, size)
    return spool.flush(), params


def _ledger_rows(flushed):
    return [np.asarray(m[LEDGER_METRIC_KEY]) for _, m in flushed]


# ------------------------------------------------ exec-mode bit parity

@pytest.mark.parametrize("layout", LAYOUTS)
def test_ledger_rows_bit_identical_across_exec_modes(layout):
    """The (S, 8) stats block is the SAME ARRAY no matter how the round
    program executes: eager depth-0, prefetched depth-2, and fused
    rounds_per_call=3 must agree bit-for-bit — the flight recording is a
    property of the round, not of the execution schedule."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = _active_fed(layout)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)

    runs = {}
    for name, (depth, rpc) in {"eager": (0, 1), "prefetched": (2, 1),
                               "fused": (0, 3)}.items():
        f = dataclasses.replace(fed, rounds_per_call=rpc)
        engine = RoundEngine(model, f, specs, alg=alg, donate=False)
        flushed, _ = _drive_blocks(engine, params, sstate, _gen(task),
                                   plan_round_blocks(ROUNDS, EVERY, rpc),
                                   depth)
        runs[name] = _ledger_rows(flushed)

    for name in ("prefetched", "fused"):
        assert len(runs[name]) == len(runs["eager"]) == ROUNDS
        for r, (a, b) in enumerate(zip(runs["eager"], runs[name])):
            assert a.shape == (fed.clients_per_round, len(LEDGER_COLUMNS))
            assert np.array_equal(a, b), (name, r)

    blk = runs["eager"][0]
    assert np.all(np.isfinite(blk))      # even with NaN faults injected
    assert set(np.unique(blk[:, _COL["verdict"]])) <= set(
        float(v) for v in VERDICT_CODES.values())
    assert set(np.unique(blk[:, _COL["fault_injected"]])) <= set(
        float(v) for v in INJECTED_CODES.values())


def test_ledger_cross_layout_parity():
    """Both layouts (vmap vs scan) record the same per-client stats."""
    if _ENV_LAYOUT:
        pytest.skip("layout matrix pins a single layout")
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    rows = {}
    for layout in ("client_parallel", "client_sequential"):
        fed = _active_fed(layout)
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
        flushed, _ = _drive_blocks(engine, params, sstate, _gen(task),
                                   plan_round_blocks(3, 3, 1), 0)
        rows[layout] = _ledger_rows(flushed)
    for a, b in zip(rows["client_parallel"], rows["client_sequential"]):
        # discrete columns exactly; accumulated floats to tight tol
        for col in ("client_id", "steps", "dp_clipped", "wire_bytes",
                    "fault_injected", "verdict"):
            assert np.array_equal(a[:, _COL[col]], b[:, _COL[col]])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


# ----------------------------------------------- zero-cost-off parity

@pytest.mark.parametrize("layout", LAYOUTS)
def test_ledger_off_jaxpr_byte_identical(layout):
    """telemetry_ledger=False must be FREE: the round program — single
    round and rounds_per_call-fused — is byte-identical to a config
    that never heard of the ledger (same RA201 gate the analyzer runs).
    The enabled program must differ (the stats block exists)."""
    cfg, model, _ = build_tiny("dense")
    base = FedConfig(algorithm="fedadamw", num_clients=8,
                     clients_per_round=2, local_steps=2, lr=1e-3,
                     layout=layout, sequential_clients=2)
    off = dataclasses.replace(base, telemetry_ledger=False)
    on = dataclasses.replace(base, telemetry_ledger=True)
    for mr in (0, 3):
        base_txt = str(trace_round_jaxpr(model, base, cfg=cfg,
                                         multi_rounds=mr)[0])
        off_txt = str(trace_round_jaxpr(model, off, cfg=cfg,
                                        multi_rounds=mr)[0])
        on_txt = str(trace_round_jaxpr(model, on, cfg=cfg,
                                       multi_rounds=mr)[0])
        assert base_txt == off_txt, f"multi_rounds={mr}"
        assert base_txt != on_txt, f"multi_rounds={mr}"


@pytest.mark.parametrize("layout", LAYOUTS)
def test_ledger_does_not_perturb_training(layout):
    """The recorder only READS the uploads: enabling it must leave the
    loss stream and final params bit-identical (same contract as
    telemetry_diagnostics)."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg, num_clients=4)
    fed = FedConfig(algorithm="fedadamw", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    layout=layout, sequential_clients=2)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    led_fed = dataclasses.replace(fed, telemetry_ledger=True)
    plain = RoundEngine(model, fed, specs, alg=alg, donate=False)
    led = RoundEngine(model, led_fed, specs, alg=alg, donate=False)
    blocks = plan_round_blocks(4, 4, 1)

    rows_p, p_plain = _drive_blocks(plain, params, sstate,
                                    _gen(task, sample=2), blocks, 0)
    rows_l, p_led = _drive_blocks(led, params, sstate,
                                  _gen(task, sample=2), blocks, 0)
    assert [m["loss_mean"] for _, m in rows_p] == \
        [m["loss_mean"] for _, m in rows_l]
    for a, b in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_led)):
        assert jnp.array_equal(a, b)
    for _, m in rows_l:
        assert m[LEDGER_METRIC_KEY].shape == (2, len(LEDGER_COLUMNS))


# -------------------------------------------------- run_training export

def test_run_training_ledger_export_schema(tmp_path):
    """--ledger-dir yields an atomic npz + manifest whose wire column is
    the static per-upload byte cost gated by arrival, and whose verdict
    column explains every defense decision."""
    from repro.launch.train import run_training
    ld = str(tmp_path / "ledger")
    h = run_training(arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
                     num_clients=8, clients_per_round=4, local_steps=2,
                     batch_size=4, eval_every=2, seed=3,
                     straggler_frac=0.5, fault_drop=0.25, fault_nan=0.25,
                     robust_agg="mean", ledger_dir=ld)
    man, rounds, stats = load_ledger(ld)
    assert man["columns"] == list(LEDGER_COLUMNS)
    assert man["injected_codes"] == INJECTED_CODES
    assert man["verdict_codes"] == VERDICT_CODES
    assert man["rounds_recorded"] == 4 and list(rounds) == [0, 1, 2, 3]
    assert stats.shape == (4, 4, len(LEDGER_COLUMNS))
    assert np.all(np.isfinite(stats))

    # wire bytes: comm_bytes iff the upload arrived, 0 iff dropped
    comm = man["wire_bytes_per_client"]
    assert comm > 0 and man["wire_col_scaled"]
    wire = stats[:, :, _COL["wire_bytes"]]
    verdict = stats[:, :, _COL["verdict"]]
    dropped = verdict == VERDICT_CODES["dropped"]
    assert np.array_equal(wire, np.where(dropped, 0.0, float(comm)))
    # the fault schedule actually fired in this config
    assert (stats[:, :, _COL["fault_injected"]] != 0).any()
    # stragglers: steps per client in [1, local_steps], not all equal
    steps = stats[:, :, _COL["steps"]]
    assert steps.min() >= 1 and steps.max() <= 2
    # engine history carries the run's ledger linkage
    assert h["engine"]["ledger_dir"] == ld
    assert h["engine"]["jit_steady_state_recompiles"] == 0


def test_ledger_drift_column_matches_diagnostics(tmp_path):
    """mean_S(drift_sq) is the per-client decomposition of the round's
    client_drift_rms^2 gauge (paper Fig. 2 — docs/paper_map.md): the
    two observability paths must agree on the same quantity."""
    from repro.launch.train import run_training
    ld = str(tmp_path / "ledger")
    h = run_training(arch="vit-tiny-fl", algorithm="fedadamw", rounds=3,
                     num_clients=8, clients_per_round=4, local_steps=2,
                     batch_size=4, eval_every=3, seed=5,
                     telemetry_diagnostics=True, ledger_dir=ld)
    _, _, stats = load_ledger(ld)
    per_round = stats[:, :, _COL["drift_sq"]].mean(axis=1)
    for r, rms in enumerate(h["client_drift_rms"]):
        assert per_round[r] == pytest.approx(rms ** 2, rel=1e-4,
                                             abs=1e-10)


def test_run_training_crash_still_exports_ledger(tmp_path, monkeypatch):
    """A crash mid-run must salvage the rounds recorded so far through
    the same ``finally`` path that saves traces — the flight recorder
    is most valuable exactly when the run died."""
    import repro.launch.train as train_mod

    def boom(*a, **k):
        raise RuntimeError("eval exploded")

    monkeypatch.setattr(train_mod, "evaluate", boom)
    ld = str(tmp_path / "ledger")
    with pytest.raises(RuntimeError, match="eval exploded"):
        train_mod.run_training(
            arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
            num_clients=4, clients_per_round=2, local_steps=1,
            batch_size=4, eval_every=2, seed=3, ledger_dir=ld)
    assert telemetry.active() is None
    assert os.path.exists(os.path.join(ld, LEDGER_NPZ))
    man, rounds, stats = load_ledger(ld)
    assert man["rounds_recorded"] >= 1          # salvaged pre-crash rounds
    assert stats.shape[0] == len(rounds) == man["rounds_recorded"]


def test_flight_recorder_trim_and_atomicity(tmp_path):
    """trim() drops rounds at/after the rollback point (watchdog
    contract) and export() never leaves a partial npz behind."""
    ld = str(tmp_path / "ledger")
    rec = FlightRecorder(ld, wire_bytes_per_client=10)
    blk = np.zeros((2, len(LEDGER_COLUMNS)), dtype=np.float32)
    blk[:, _COL["wire_bytes"]] = 1.0
    for r in range(5):
        rec.record(r, blk)
    rec.trim(3)
    assert len(rec) == 3
    path = rec.export()
    assert os.path.exists(path)
    assert not any(f.endswith(".tmp") for f in os.listdir(ld))
    man, rounds, stats = load_ledger(ld)
    assert list(rounds) == [0, 1, 2]
    assert np.all(stats[:, :, _COL["wire_bytes"]] == 10.0)  # scaled once
    # the manifest is enough to decode without importing repro
    with open(os.path.join(ld, LEDGER_MANIFEST)) as fh:
        assert json.load(fh)["columns"] == list(LEDGER_COLUMNS)


# ------------------------------------- compile / memory observability

def test_compile_counters_no_steady_state_recompiles():
    """Across a multi-eval-block run the engine compiles each program
    signature ONCE: jit/compiles grows on first dispatch, and the
    steady-state recompile counter stays zero — the assertion that
    donation/layout churn never silently re-triggers XLA."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg, num_clients=4)
    fed = FedConfig(algorithm="fedadamw", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    with telemetry.session() as sess:
        _drive_blocks(engine, params, sstate, _gen(task, sample=2),
                      plan_round_blocks(ROUNDS, EVERY, 1), 0)
        snap = sess.counters.snapshot()
    assert engine.compiles >= 1
    assert engine.steady_state_recompiles == 0
    assert snap["jit/compiles"] == float(engine.compiles)
    assert snap["jit/compile_s"] == pytest.approx(engine.compile_s)
    assert snap.get("jit/steady_state_recompiles", 0.0) == 0.0
    # one signature, many blocks: compiled far fewer times than rounds
    assert engine.compiles < ROUNDS


def test_sample_memory_gauges_is_total():
    """On backends without memory_stats (CPU jax) the sampler is a
    silent no-op; where stats exist both gauges land in the session."""
    with telemetry.session() as sess:
        gauges = sample_memory_gauges()
        snap = sess.counters.snapshot()
    if gauges:
        assert set(gauges) == {"mem/live_bytes", "mem/peak_bytes"}
        assert snap["mem/live_bytes"] > 0
        assert snap["mem/peak_bytes"] >= snap["mem/live_bytes"]
    else:
        assert "mem/live_bytes" not in snap
    # sampling outside a session must not raise either
    assert isinstance(sample_memory_gauges(), dict)
