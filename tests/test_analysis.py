"""Static-analysis subsystem: per-rule lint fixtures (positive +
negative), distinct exit codes for deliberately-broken programs, inline
allow / baseline suppression mechanics, the declarative FedConfig
constraint table, and jaxpr gate-parity for the DP/diagnostics/scenario
off-gates in both client layouts (the structural replacement for the
trajectory-parity drives this PR migrated — see test_privacy.py /
test_telemetry.py backstops)."""
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (EXIT_CODES, Finding, exit_code_for,
                            load_baseline, save_baseline, split_baselined)
from repro.analysis.findings import inline_allows
from repro.analysis.jaxpr_audit import (audit_callbacks, audit_dtypes,
                                        audit_matrix, gate_parity_findings)
from repro.analysis.lint import lint_source
from repro.config import FedConfig
from repro.config.fed_config import CONSTRAINTS

# honor the CI layout matrix (same pattern as test_scenario.py)
_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT", "")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

CORE = "src/repro/core/somemod.py"   # a jit-feeding pseudo-path


def codes(findings):
    return sorted({f.code for f in findings})


def lint(src, path=CORE):
    return lint_source(textwrap.dedent(src), path)


# ------------------------------------------------- per-rule lint fixtures

def test_ra101_raw_prngkey_flagged_and_sanctioned_forms_pass():
    bad = lint("""\
        import jax
        key = jax.random.PRNGKey(0)
    """)
    assert codes(bad) == ["RA101"] and bad[0].line == 2
    # immediately folded, aliased import: sanctioned
    good = lint("""\
        import jax.random as jr
        key = jr.fold_in(jr.PRNGKey(0), 7)
    """)
    assert good == []
    # outside jit-feeding packages the rule does not apply
    assert lint("import jax\nk = jax.random.PRNGKey(0)\n",
                "benchmarks/somebench.py") == []
    # inline allow silences it
    assert lint("""\
        import jax
        key = jax.random.PRNGKey(0)  # ra: allow[RA101] test fixture
    """) == []


def test_ra102_key_reuse_flagged_fold_in_is_fine():
    bad = lint("""\
        import jax

        def f(shape):
            key = jax.random.fold_in(jax.random.PRNGKey(0), 1)
            a = jax.random.normal(key, shape)
            b = jax.random.uniform(key, shape)
            return a + b
    """)
    assert codes(bad) == ["RA102"]
    good = lint("""\
        import jax

        def f(shape):
            key = jax.random.fold_in(jax.random.PRNGKey(0), 1)
            a = jax.random.normal(jax.random.fold_in(key, 0), shape)
            b = jax.random.uniform(jax.random.fold_in(key, 1), shape)
            return a + b
    """)
    assert codes(good) == []
    # reassigned-per-draw (split idiom) is fine: two assignments
    assert lint("""\
        import jax

        def f(shape):
            key = jax.random.PRNGKey(0)  # ra: allow[RA101] fixture
            a = jax.random.normal(key, shape)
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, shape)
            return a + b
    """) == []


def test_ra103_reserved_key_literals_only_in_scenario():
    bad = lint('mask = batches["_step_mask"]\n', "src/repro/core/x.py")
    assert codes(bad) == ["RA103"]
    bad2 = lint('w = {"_agg_weights": 1}\n', "tests/test_x.py")
    assert codes(bad2) == ["RA103"]
    # the defining module itself is exempt
    assert lint('STEP_MASK_KEY = "_step_mask"\n',
                "src/repro/scenario/__init__.py") == []


def test_ra104_metric_name_catalog():
    bad = lint("""\
        from repro import telemetry
        telemetry.add("prefetch/wait_sec", 1.0)
    """, "src/repro/launch/somefile.py")
    assert codes(bad) == ["RA104"]
    assert "prefetch/wait_s" in bad[0].fixit   # difflib suggestion
    good = lint("""\
        from repro import telemetry
        telemetry.add("prefetch/wait_s", 1.0)
        telemetry.set_gauge("round/cohort_size", 4)
    """, "src/repro/launch/somefile.py")
    assert good == []
    # tests/ may invent scratch names freely
    assert lint('from repro import telemetry\ntelemetry.add("x", 1)\n',
                "tests/test_x.py") == []


def test_ra104_covers_compile_and_ledger_metrics():
    """The compile-observability and flight-recorder names are in the
    catalog: the canonical spelling lints clean, a near-miss is caught
    with the canonical name as the suggested fix."""
    good = lint("""\
        from repro import telemetry
        telemetry.add("jit/compiles", 1.0)
        telemetry.add("jit/compile_s", 0.5)
        telemetry.add("ledger/rounds_recorded", 1.0)
        telemetry.set_gauge("mem/peak_bytes", 2.0**30)
    """, "src/repro/launch/somefile.py")
    assert good == []
    bad = lint("""\
        from repro import telemetry
        telemetry.add("jit/compile_secs", 0.5)
    """, "src/repro/launch/somefile.py")
    assert codes(bad) == ["RA104"]
    assert "jit/compile_s" in bad[0].fixit     # difflib suggestion


def test_ra105_wallclock_and_global_randomness():
    bad = lint("""\
        import time
        import numpy as np
        t = time.time()
        x = np.random.normal(0, 1, (3,))
    """)
    assert codes(bad) == ["RA105"] and len(bad) == 2
    good = lint("""\
        import numpy as np
        rng = np.random.default_rng(7)
        x = rng.normal(0, 1, (3,))
    """)
    assert good == []
    # launch/ (host-side driver code) is out of scope
    assert lint("import time\nt = time.time()\n",
                "src/repro/launch/x.py") == []


def test_ra106_unused_imports():
    bad = lint("import os\nimport sys\nprint(sys.argv)\n")
    assert codes(bad) == ["RA106"] and "'os'" in bad[0].message
    # __all__ re-export counts as a use; __init__.py is exempt
    assert lint('import os\n__all__ = ["os"]\n') == []
    assert lint("import os\n", "src/repro/core/__init__.py") == []


# ------------------------------------------- exit codes / suppressions

def test_each_broken_fixture_gets_a_distinct_exit_code():
    """The acceptance matrix: reused key, counter typo, f64 leak, and a
    leaking gate each map to their own non-zero process exit code."""
    reused = lint("""\
        import jax

        def f(s):
            k = jax.random.fold_in(jax.random.PRNGKey(0), 1)
            return jax.random.normal(k, s) + jax.random.normal(k, s)
    """)
    typo = lint('from repro import telemetry\n'
                'telemetry.add("comm/wire_byte_total", 1)\n',
                "src/repro/comm/x.py")
    with jax.experimental.enable_x64(True):
        f64_jaxpr = jax.make_jaxpr(
            lambda x: x.astype("float64") * 2.0)(jnp.ones((2,)))
    f64 = audit_dtypes("fixture", f64_jaxpr)
    gate = gate_parity_findings(
        [c for c in audit_matrix(("client_parallel",))
         if c.name == "dp_off[client_parallel]"],
        {"dp_off[client_parallel]": "program A",
         "base[client_parallel]": "program B"})
    got = {exit_code_for(f) for f in (reused, typo, f64, gate)}
    assert got == {12, 14, 22, 21}      # RA102 RA104 RA202 RA201
    assert exit_code_for([]) == 0
    assert exit_code_for(reused + typo) == 1        # mixed -> 1
    assert len(set(EXIT_CODES.values())) == len(EXIT_CODES)


def test_inline_allow_covers_own_and_next_line():
    allows = inline_allows(["x = 1  # ra: allow[RA105] reason", "y = 2",
                            "z = 3"])
    assert allows == {1: {"RA105"}, 2: {"RA105"}}


def test_baseline_roundtrip_and_split(tmp_path):
    f1 = Finding(code="RA106", path="src/a.py", line=3, message="m",
                 text="import os")
    f2 = Finding(code="RA106", path="src/b.py", line=9, message="m",
                 text="import sys")
    path = str(tmp_path / "baseline.json")
    save_baseline([f1], path)
    doc = json.load(open(path))
    assert doc["suppressions"][0]["path"] == "src/a.py"
    new, old = split_baselined([f1, f2], load_baseline(path))
    assert old == [f1] and new == [f2]
    # fingerprint survives pure line drift, breaks on text change
    drifted = Finding(code="RA106", path="src/a.py", line=99, message="m",
                      text="  import os ")
    assert split_baselined([drifted], load_baseline(path))[1] == [drifted]


# --------------------------------------------- FedConfig constraint table

def test_constraint_table_names_unique_and_each_rule_fires():
    names = [c.name for c in CONSTRAINTS]
    assert len(names) == len(set(names))
    violating = {
        "rounds-per-call-min": dict(rounds_per_call=0),
        "sequential-clients-min": dict(layout="client_sequential",
                                       sequential_clients=0),
        "grad-microbatches-min": dict(grad_microbatches=0),
        "local-steps-min": dict(local_steps=0),
        "rounds-min": dict(rounds=0),
        "straggler-frac-range": dict(straggler_frac=2.0),
        "straggler-min-steps-range": dict(straggler_min_steps=99),
        "dp-clip-nonneg": dict(dp_clip=-1.0),
        "dp-noise-nonneg": dict(dp_noise_multiplier=-1.0),
        "dp-epsilon-nonneg": dict(target_epsilon=-1.0),
        "dp-delta-range": dict(dp_delta=2.0),
        "dp-noise-requires-clip": dict(dp_noise_multiplier=1.0),
        "dp-sigma-xor-epsilon": dict(dp_clip=1.0, dp_noise_multiplier=1.0,
                                     target_epsilon=2.0),
        "dp-uniform-weighting": dict(dp_clip=1.0,
                                     agg_weighting="data_size"),
        "clipacc-requires-dp": dict(use_pallas_clipacc=True),
        "clipacc-parallel-only": dict(use_pallas_clipacc=True, dp_clip=1.0,
                                      layout="client_sequential"),
        "clipacc-no-codec": dict(use_pallas_clipacc=True, dp_clip=1.0),
        "fault-prob-range": dict(fault_nan=1.5),
        "fault-scale-factor-positive": dict(fault_scale_factor=0.0),
        "min-quorum-range": dict(min_quorum=99),
        "quorum-requires-defense": dict(min_quorum=1),
        "robust-rank-parallel-only": dict(robust_agg="trimmed0.25",
                                          layout="client_sequential"),
        "robust-rank-uniform-weights": dict(robust_agg="coordinate_median",
                                            agg_weighting="data_size"),
        "dp-robust-mean-compatible": dict(dp_clip=1.0,
                                          robust_agg="trimmed0.25"),
        "clipacc-no-faults": dict(use_pallas_clipacc=True, dp_clip=1.0,
                                  fault_nan=0.1),
        "uploadfuse-codec-kind": dict(use_pallas_uploadfuse=True,
                                      algorithm="fedadamw+topk0.1"),
        "uploadfuse-xor-clipacc": dict(use_pallas_uploadfuse=True,
                                       use_pallas_clipacc=True,
                                       dp_clip=1.0),
        "uploadfuse-no-corruption": dict(use_pallas_uploadfuse=True,
                                         fault_nan=0.1),
        "uploadfuse-no-defense": dict(use_pallas_uploadfuse=True,
                                      robust_agg="trimmed0.25"),
        "uploadfuse-sequential-no-drop": dict(
            use_pallas_uploadfuse=True, layout="client_sequential",
            fault_drop=0.3),
    }
    assert set(violating) == set(names)   # every table row is exercised
    _CODEC_FOR = {"clipacc-no-codec": "int8",
                  "uploadfuse-codec-kind": "topk0.1"}
    base = FedConfig(num_clients=4, clients_per_round=2)
    for c in CONSTRAINTS:
        codec = _CODEC_FOR.get(c.name, "")
        bad = FedConfig(num_clients=4, clients_per_round=2,
                        **violating[c.name])
        assert c.check(bad, codec), c.name
        assert c.check(base, "") is None, c.name
        assert c.fields, c.name


def test_audit_matrix_configs_all_validate():
    for case in audit_matrix():
        case.fed.validate()


# ------------------------------------------------- jaxpr-level fixtures

def test_callback_inside_scan_flagged():
    def noisy_scan(xs):
        def body(c, x):
            y = jax.pure_callback(
                lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x)
            return c + y, y
        return jax.lax.scan(body, jnp.float32(0), xs)

    closed = jax.make_jaxpr(noisy_scan)(jnp.ones((4,), jnp.float32))
    found = audit_callbacks("fixture", closed)
    assert codes(found) == ["RA203"]
    clean = jax.make_jaxpr(
        lambda xs: jax.lax.scan(lambda c, x: (c + x, x), jnp.float32(0),
                                xs))(jnp.ones((4,), jnp.float32))
    assert audit_callbacks("fixture", clean) == []
    # outside a loop body a callback is legitimate (metrics spool drain)
    outside = jax.make_jaxpr(
        lambda x: jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x))(
                jnp.float32(1))
    assert audit_callbacks("fixture", outside) == []


def test_f64_leak_flagged_f32_program_clean():
    with jax.experimental.enable_x64(True):
        leak = jax.make_jaxpr(lambda x: x.astype("float64") + 1.0)(
            jnp.ones((2,), jnp.float32))
    assert codes(audit_dtypes("fixture", leak)) == ["RA202"]
    clean = jax.make_jaxpr(lambda x: x * 2 + 1)(jnp.ones((2,), jnp.float32))
    assert audit_dtypes("fixture", clean) == []


# ------------------------------------- gate-parity, both client layouts

@pytest.fixture(scope="module")
def traced_matrix():
    """Trace the audit matrix once per layout under test (abstract-only:
    zero FLOPs, ~1 s per trace)."""
    from repro.analysis.jaxpr_audit import tiny_model, trace_case
    model, cfg = tiny_model()
    out = {}
    for lay in LAYOUTS:
        cases = [c for c in audit_matrix((lay,))
                 if not c.name.startswith("multi_")]
        texts = {c.name: str(trace_case(model, cfg, c)[0]) for c in cases}
        out[lay] = (cases, texts)
    return out


@pytest.mark.parametrize("layout", LAYOUTS)
def test_gate_parity_dp_diag_scenario_off(layout, traced_matrix):
    """DP-off, diagnostics-off (traced under a LIVE host telemetry
    session), and scenario-off must trace the byte-identical program to
    the feature-free base; each feature ON must differ (non-vacuity).
    This is the structural check that replaced the trajectory-parity
    drives in test_privacy.py / test_telemetry.py."""
    cases, texts = traced_matrix[layout]
    assert gate_parity_findings(cases, texts) == []
    # and the audit raises when a gate leaks: corrupt one off-program
    broken = dict(texts)
    broken[f"dp_off[{layout}]"] += " leak"
    leaks = gate_parity_findings(cases, broken)
    assert codes(leaks) == ["RA201"]


def test_donation_alias_parser():
    from repro.roofline.hlo_counter import parse_input_output_alias
    hdr = ("HloModule jit_round_fn, is_scheduled=true, "
           "input_output_alias={ {0}: (0, {}, may-alias), "
           "{1}: (1, {}, may-alias), {12}: (12, {}, may-alias) }, "
           "frontend_attributes={foo=\"bar\"}")
    assert parse_input_output_alias(hdr) == {0: 0, 1: 1, 12: 12}
    assert parse_input_output_alias("HloModule nothing") == {}
