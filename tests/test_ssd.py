"""SSD (Mamba2) numerics: the closed-form cross-chunk recurrence must be
exactly the sequential scan (values AND gradients), across chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def _inputs(seed, b=2, s=64, h=4, p=8, g=1, n=8):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    init = jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
    return x, dt, A, B, C, init


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
@pytest.mark.parametrize("with_init", [False, True])
def test_closed_equals_scan(chunk, with_init):
    x, dt, A, B, C, init = _inputs(chunk)
    ini = init if with_init else None
    y1, f1 = ssd_chunked(x, dt, A, B, C, chunk, ini, cross_chunk="scan")
    y2, f2 = ssd_chunked(x, dt, A, B, C, chunk, ini, cross_chunk="closed")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=2e-4, atol=2e-5)


def test_chunk_size_invariance():
    """The output must not depend on the chunk decomposition at all."""
    x, dt, A, B, C, _ = _inputs(7)
    outs = [ssd_chunked(x, dt, A, B, C, c, cross_chunk="closed")[0]
            for c in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-5)


def test_gradients_match_and_finite():
    x, dt, A, B, C, _ = _inputs(3)

    def loss(kind):
        def f(xx):
            y, _ = ssd_chunked(xx, dt, A, B, C, 16, cross_chunk=kind)
            return jnp.sum(y * y)
        return jax.grad(f)(x)

    g1, g2 = loss("scan"), loss("closed")
    assert bool(jnp.all(jnp.isfinite(g2)))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-3, atol=2e-4)
