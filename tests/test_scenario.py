"""Participation engine: availability processes, sampler registry,
straggler masks, aggregation weights — and the degenerate-config
bit-exactness guarantee vs the scenario-free engine.

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state, make_local_phase
from repro.core.fedadamw import get_algorithm
from repro.data import RoundBatchGenerator, get_sampler, make_task
from repro.launch.pipeline import HostPrefetcher, RoundEngine, plan_round_blocks
from repro.metrics import MetricsSpool
from repro.scenario import (AGG_WEIGHTS_KEY, STEP_MASK_KEY,
                            Bernoulli, ParticipationScenario, Trace,
                            aggregation_weights, parse_availability,
                            step_validity_mask)
from repro.scenario.straggler import StragglerModel

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

ROUNDS, EVERY = 6, 3


def _task(cfg, num_clients=4, seed=0):
    return make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=16,
                     num_samples=256, num_clients=num_clients,
                     dirichlet_alpha=0.6, seed=seed)


def _gen(task, fed, seed=7, batch_size=2, scenario=None):
    return RoundBatchGenerator(
        task, num_clients=fed.num_clients,
        clients_per_round=fed.clients_per_round,
        local_steps=fed.local_steps, batch_size=batch_size, rng=seed,
        scenario=scenario)


# ---------------------------------------------------------- availability

def test_always_on_and_bernoulli_masks():
    assert parse_availability("always_on", 5).mask(3).all()
    b = Bernoulli(200, 0.7, seed=1)
    m0, m0b, m1 = b.mask(0), b.mask(0), b.mask(1)
    np.testing.assert_array_equal(m0, m0b)      # pure in round_index
    assert (m0 != m1).any()                     # fresh flips per round
    assert 0.5 < m0.mean() < 0.9                # ~rate


def test_bernoulli_skewed_rates_spread_across_clients():
    b = Bernoulli(64, 0.6, concentration=1.0, seed=0)
    assert b.rates.std() > 0.15                 # heavily spread
    assert b.rates.min() >= 0 and b.rates.max() <= 1
    # same seed -> same per-client rates (frozen at construction)
    np.testing.assert_array_equal(
        b.rates, Bernoulli(64, 0.6, concentration=1.0, seed=0).rates)


def test_trace_replays_and_cycles():
    sched = np.array([[1, 0, 1], [0, 1, 0]], dtype=bool)
    t = Trace(sched)
    np.testing.assert_array_equal(t.mask(0), sched[0])
    np.testing.assert_array_equal(t.mask(1), sched[1])
    np.testing.assert_array_equal(t.mask(2), sched[0])  # cycled
    with pytest.raises(ValueError, match="clients"):
        Trace(sched, num_clients=5)


def test_parse_availability_rejects_bad_specs():
    for bad in ("bernoulli", "bernoulli-0.5", "bernoullix", "nope"):
        with pytest.raises(ValueError):
            parse_availability(bad, 4)
    with pytest.raises(ValueError, match="schedule"):
        parse_availability("trace", 4)


# ------------------------------------------------------------- samplers

def test_available_sampler_prefers_available_and_tops_up():
    rng = np.random.default_rng(0)
    avail = np.zeros(8, bool)
    avail[[2, 5]] = True
    # enough available: stays inside the available set
    cids = get_sampler("available")(8, 2, rng, available=avail)
    assert set(cids.tolist()) == {2, 5}
    # not enough: all available + uniform top-up from the rest
    cids = get_sampler("available")(8, 4, rng, available=avail)
    assert {2, 5} <= set(cids.tolist())
    assert len(set(cids.tolist())) == 4


def test_weighted_sampler_follows_data_sizes():
    sizes = np.array([1, 1, 1, 1000])
    counts = np.zeros(4)
    rng = np.random.default_rng(0)
    for _ in range(200):
        cids = get_sampler("weighted")(4, 2, rng, data_sizes=sizes)
        counts[cids] += 1
        assert len(set(cids.tolist())) == 2
    assert counts[3] == 200                     # always picked
    with pytest.raises(ValueError, match="data size"):
        get_sampler("weighted")(4, 2, rng)


def test_unknown_sampler_is_actionable():
    with pytest.raises(ValueError, match="known:"):
        get_sampler("stratified")


# ------------------------------------------------- stragglers + weights

def test_straggler_model_deterministic_and_bounded():
    m = StragglerModel(16, 10, 0.5, min_steps=3, seed=2)
    assert m.is_straggler.sum() == 8
    cids = np.array([0, 3, 7, 12])
    k1, k2 = m.local_steps_for(4, cids), m.local_steps_for(4, cids)
    np.testing.assert_array_equal(k1, k2)       # pure in (round, cids)
    assert ((k1 >= 3) & (k1 <= 10)).all()
    # non-stragglers always run the full K
    ns = np.flatnonzero(~m.is_straggler)[:2]
    assert (m.local_steps_for(0, ns) == 10).all()
    # subset-invariance: K_i doesn't depend on who else was sampled
    np.testing.assert_array_equal(
        m.local_steps_for(4, cids[:2]), k1[:2])


def test_step_validity_mask_shape():
    mask = step_validity_mask(np.array([1, 3, 2]), 3)
    np.testing.assert_array_equal(
        mask, [[1, 0, 0], [1, 1, 1], [1, 1, 0]])


@pytest.mark.parametrize("scheme", ["uniform", "data_size", "inv_steps"])
def test_aggregation_weights_sum_to_one_under_stragglers(scheme):
    cids = np.array([0, 2, 5, 7])
    w = aggregation_weights(
        scheme, cids, data_sizes=np.array([10, 1, 5, 1, 1, 40, 1, 3]),
        local_steps_per_client=np.array([1, 10, 4, 10]))
    assert w.shape == (4,) and w.dtype == np.float32
    assert np.isclose(w.sum(), 1.0, atol=1e-6)
    if scheme == "inv_steps":                   # straggler upweighted
        assert w[0] == w.max() and w[1] == w.min()
    if scheme == "data_size":
        assert w[2] == w.max()                  # client 5 owns most data


def test_generator_payload_weights_sum_to_one_under_stragglers():
    cfg, _, _ = build_tiny("dense")
    task = _task(cfg, num_clients=8)
    fed = FedConfig(num_clients=8, clients_per_round=4, local_steps=3,
                    straggler_frac=0.75, agg_weighting="inv_steps")
    gen = _gen(task, fed, scenario=ParticipationScenario.from_fed(
        fed, task=task))
    for _ in range(5):
        b, cids = gen.next_round()
        w, mask = b[AGG_WEIGHTS_KEY], b[STEP_MASK_KEY]
        assert np.isclose(w.sum(), 1.0, atol=1e-6)
        k_i = mask.sum(axis=1)
        assert (k_i >= 1).all()
        # inv_steps: weights are proportional to 1/K_i
        np.testing.assert_allclose(w * k_i / (w * k_i)[0],
                                   np.ones(4), rtol=1e-5)


# ------------------------------------------ determinism across execution

def test_availability_payload_deterministic_eager_vs_prefetched():
    """The scenario's availability/straggler draws come from per-round
    seeded generators, so eager (depth 0) and background-prefetched
    (depth 2) assembly produce bit-identical cids, masks and weights."""
    cfg, _, _ = build_tiny("dense")
    task = _task(cfg, num_clients=8)
    fed = FedConfig(num_clients=8, clients_per_round=3, local_steps=2,
                    availability="bernoulli0.6:2", sampling="available",
                    straggler_frac=0.5, agg_weighting="inv_steps")
    blocks = plan_round_blocks(ROUNDS, EVERY, 1)
    out = {}
    for depth in (0, 2):
        sc = ParticipationScenario.from_fed(fed, task=task)
        items = list(HostPrefetcher(_gen(task, fed, scenario=sc), blocks,
                                    depth=depth, to_device=False))
        out[depth] = items
    for (s0, z0, b0, c0), (s1, z1, b1, c1) in zip(out[0], out[2]):
        assert jnp.array_equal(c0, c1)
        for k in b0:
            assert jnp.array_equal(b0[k], b1[k]), k


# -------------------------------------------- local-phase mask semantics

def test_masked_local_phase_equals_truncated_run():
    """A client masked to K_i steps must upload exactly what it would
    upload if its batch stack physically had K_i steps, and its metrics
    must ignore the masked tail."""
    cfg, model, params = build_tiny("dense")
    fed = FedConfig(num_clients=4, clients_per_round=2, local_steps=3,
                    lr=1e-3)
    _, specs, alg, sstate = build_fed_state(model, fed, jax.random.key(0),
                                            cfg=cfg)
    task = _task(cfg)
    gen = _gen(task, fed)
    batches, cids = gen.next_round()
    one = jax.tree.map(lambda x: jnp.asarray(x[0]), batches)  # (K, b, seq)
    local_phase = make_local_phase(model.loss, alg, fed, specs)

    k_i = 2
    mask = jnp.asarray(np.arange(3) < k_i)
    up_masked, m_masked = local_phase(params, sstate, one,
                                      jnp.ones(()), None, mask)

    fed_cut = dataclasses.replace(fed, local_steps=k_i)
    phase_cut = make_local_phase(model.loss, get_algorithm(fed_cut),
                                 fed_cut, specs)
    cut = jax.tree.map(lambda x: x[:k_i], one)
    up_cut, m_cut = phase_cut(params, sstate, cut, jnp.ones(()))

    # the two jitted programs differ in scan length, so XLA may fuse the
    # arithmetic differently — equality holds to last-ulp tolerance, not
    # bitwise (bitwise parity is only guaranteed for IDENTICAL programs,
    # which is what test_degenerate_scenario_bit_exact pins)
    for a, b in zip(jax.tree.leaves(up_masked["delta"]),
                    jax.tree.leaves(up_cut["delta"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-9)
    assert float(m_masked["loss_mean"]) == pytest.approx(
        float(m_cut["loss_mean"]), rel=1e-6)
    assert float(m_masked["loss_last"]) == pytest.approx(
        float(m_cut["loss_last"]), rel=1e-6)
    # K_i = 1: first == last == mean (only step 0 counts)
    up1, m1 = local_phase(params, sstate, one, jnp.ones(()), None,
                          jnp.asarray([True, False, False]))
    assert float(m1["loss_first"]) == float(m1["loss_last"]) \
        == float(m1["loss_mean"])


# ------------------------------------------------ engine-level behavior

def _drive(engine, params, sstate, gen, blocks, depth):
    pre = HostPrefetcher(gen, blocks, depth=depth, stacked=engine.stacked)
    spool = MetricsSpool()
    for start, size, batches, cids in pre:
        params, sstate, m = engine.run_block(params, sstate, batches, cids,
                                             start, size)
        spool.append(start, m, size)
    return [m["loss_mean"] for _, m in spool.flush()], params, sstate


@pytest.mark.parametrize("algorithm", ["fedadamw", "scaffold"])
@pytest.mark.parametrize("layout", LAYOUTS)
def test_degenerate_scenario_bit_exact(algorithm, layout):
    """The degenerate config (all available, uniform sampling/weights,
    K_i = K) must be BIT-exact with the scenario-free engine — in both
    layouts, prefetched and multi-round fused."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    base = FedConfig(algorithm=algorithm, num_clients=4, clients_per_round=2,
                     local_steps=2, lr=1e-3, layout=layout,
                     sequential_clients=2)
    degen = ParticipationScenario.from_fed(base, task=task)
    assert degen.is_degenerate and not degen.needs_payload
    params, specs, alg, sstate = build_fed_state(
        model, base, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, base, specs, alg=alg,
                         cosine_total_rounds=ROUNDS, donate=False)
    fused_fed = dataclasses.replace(base, rounds_per_call=3)
    fused_engine = RoundEngine(model, fused_fed, specs, alg=alg,
                               cosine_total_rounds=ROUNDS, donate=False)
    single = plan_round_blocks(ROUNDS, EVERY, 1)
    fused = plan_round_blocks(ROUNDS, EVERY, 3)

    l_ref, p_ref, s_ref = _drive(
        engine, params, sstate, _gen(task, base), single, depth=0)
    l_sc, p_sc, s_sc = _drive(
        engine, params, sstate, _gen(task, base, scenario=degen), single,
        depth=2)
    l_fu, p_fu, s_fu = _drive(
        fused_engine, params, sstate, _gen(task, base, scenario=degen),
        fused, depth=2)

    assert l_ref == l_sc == l_fu, (l_ref, l_sc, l_fu)
    for a, b, c in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sc),
                       jax.tree.leaves(p_fu)):
        assert jnp.array_equal(a, b) and jnp.array_equal(a, c)
    for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_fu)):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("algorithm", ["fedadamw", "scaffold"])
def test_active_scenario_layout_parity(algorithm):
    """Stragglers + weighted aggregation must produce matching
    trajectories under both placement layouts (same data, same masks)."""
    if _ENV_LAYOUT:
        pytest.skip("layout pinned by REPRO_LAYOUT")
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    results = {}
    for layout in ("client_parallel", "client_sequential"):
        fed = FedConfig(algorithm=algorithm, num_clients=4,
                        clients_per_round=2, local_steps=3, lr=1e-3,
                        layout=layout, sequential_clients=2,
                        straggler_frac=0.5, agg_weighting="inv_steps")
        sc = ParticipationScenario.from_fed(fed, task=task)
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
        blocks = plan_round_blocks(4, 4, 1)
        results[layout] = _drive(engine, params, sstate,
                                 _gen(task, fed, scenario=sc), blocks, 0)
    l_p, p_p, _ = results["client_parallel"]
    l_s, p_s, _ = results["client_sequential"]
    np.testing.assert_allclose(l_p, l_s, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_p), jax.tree.leaves(p_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_active_scenario_bit_exact_across_execution_modes(layout):
    """Even an ACTIVE scenario (masks + weights) must be bit-exact
    between eager and prefetched+fused execution — the weighted mean
    uses a fixed association order so XLA cannot round the reduction
    differently inside the fused scan body."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg, num_clients=8)
    fed = FedConfig(num_clients=8, clients_per_round=4, local_steps=3,
                    lr=1e-3, layout=layout, sequential_clients=4,
                    availability="bernoulli0.7:2", sampling="available",
                    straggler_frac=0.5, agg_weighting="inv_steps")
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg,
                         cosine_total_rounds=ROUNDS, donate=False)
    fused_engine = RoundEngine(
        model, dataclasses.replace(fed, rounds_per_call=3), specs, alg=alg,
        cosine_total_rounds=ROUNDS, donate=False)
    mk = lambda: _gen(task, fed,  # noqa: E731
                      scenario=ParticipationScenario.from_fed(fed, task=task))
    l_e, p_e, _ = _drive(engine, params, sstate, mk(),
                         plan_round_blocks(ROUNDS, EVERY, 1), depth=0)
    l_f, p_f, _ = _drive(fused_engine, params, sstate, mk(),
                         plan_round_blocks(ROUNDS, EVERY, 3), depth=2)
    assert l_e == l_f, (l_e, l_f)
    for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_f)):
        assert jnp.array_equal(a, b)


def test_straggler_scenario_changes_trajectory():
    """An active straggler mask must actually change the trajectory (the
    masked steps are dropped, not just re-weighted)."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg, num_clients=8)
    base = FedConfig(num_clients=8, clients_per_round=4, local_steps=4,
                     lr=1e-3)
    strag = dataclasses.replace(base, straggler_frac=1.0,
                                straggler_min_steps=1)
    params, specs, alg, sstate = build_fed_state(
        model, base, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, base, specs, alg=alg, donate=False)
    blocks = plan_round_blocks(3, 3, 1)
    l_ref, _, _ = _drive(engine, params, sstate, _gen(task, base), blocks, 0)
    sc = ParticipationScenario.from_fed(strag, task=task)
    l_sc, _, _ = _drive(engine, params, sstate,
                        _gen(task, strag, scenario=sc), blocks, 0)
    assert l_ref != l_sc


# ------------------------------------------------------- validation fix

def test_sample_clients_validation_is_actionable():
    from repro.data import sample_clients
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="clients_per_round=9 exceeds"):
        sample_clients(4, 9, rng)
    with pytest.raises(ValueError, match="at least one participant"):
        sample_clients(4, 0, rng)
    with pytest.raises(ValueError, match="num_clients"):
        sample_clients(0, 1, rng)


def test_generator_validates_participation_at_construction():
    cfg, _, _ = build_tiny("dense")
    task = _task(cfg)
    with pytest.raises(ValueError, match="exceeds"):
        RoundBatchGenerator(task, num_clients=4, clients_per_round=9,
                            local_steps=1, batch_size=1)


def test_fedconfig_validates_participation_and_scenario_fields():
    good = FedConfig(num_clients=4, clients_per_round=2)
    good.validate()
    cases = [
        (dict(clients_per_round=9), "exceeds"),
        (dict(clients_per_round=0), "at least one participant"),
        (dict(local_steps=0), "local_steps"),
        (dict(rounds=0), "rounds"),
        (dict(availability="bernoulli"), "rate"),
        (dict(availability="sometimes"), "unknown availability"),
        (dict(sampling="stratified"), "known:"),
        (dict(straggler_frac=1.5), "straggler_frac"),
        (dict(straggler_min_steps=0), "straggler_min_steps"),
        (dict(straggler_min_steps=99), "straggler_min_steps"),
        (dict(agg_weighting="loudest"), "agg_weighting"),
    ]
    for overrides, match in cases:
        kw = dict(num_clients=4, clients_per_round=2)
        kw.update(overrides)
        fed = FedConfig(**kw)
        with pytest.raises(ValueError, match=match):
            fed.validate()
