"""Sharding-rule tests: every sharded dim must divide its mesh axis size,
for every assigned architecture, on a stub of the production mesh."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, get_arch
from repro.launch import input_specs as ispecs
from repro.models import build_model
from repro.sharding import specs as shspecs


class MeshStub:
    """Duck-typed stand-in for jax.sharding.Mesh: the spec rules only read
    ``axis_names`` and ``shape`` (tests must not allocate 512 devices)."""

    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)


SINGLE = MeshStub({"data": 16, "model": 16})
MULTI = MeshStub({"pod": 2, "data": 16, "model": 16})

ASSIGNED = [
    "olmo-1b", "olmo-1b-swa", "stablelm-12b", "qwen2-72b", "qwen3-32b",
    "qwen2-vl-2b", "mixtral-8x7b", "zamba2-2.7b",
    "llama4-maverick-400b-a17b", "seamless-m4t-large-v2", "mamba2-780m",
]


def _axis_size(mesh, name):
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible(arch, mesh):
    cfg = get_arch(arch)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    for layout in ("client_parallel", "client_sequential"):
        fed = FedConfig(layout=layout)
        pspecs = shspecs.param_pspecs(params, cfg, mesh, fed)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (kp, leaf), spec in zip(flat_p, flat_s):
            for axis, name in enumerate(spec):
                if name is None:
                    continue
                size = _axis_size(mesh, name)
                assert leaf.shape[axis] % size == 0, (
                    arch, layout, [getattr(k, "key", k) for k in kp],
                    leaf.shape, tuple(spec))


@pytest.mark.parametrize("arch", ["qwen2-72b", "mixtral-8x7b",
                                  "mamba2-780m", "zamba2-2.7b"])
def test_cache_specs_divisible(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    cspecs = shspecs.cache_pspecs(cache, cfg, SINGLE)
    flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_s = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    for (kp, leaf), spec in zip(flat_c, flat_s):
        for axis, name in enumerate(spec):
            if name is None:
                continue
            assert leaf.shape[axis] % _axis_size(SINGLE, name) == 0, (
                arch, [getattr(k, "key", k) for k in kp], leaf.shape,
                tuple(spec))


def test_moe_expert_parallel_when_divisible():
    cfg = get_arch("llama4-maverick-400b-a17b")   # 128 experts % 16 == 0
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = shspecs.param_pspecs(params, cfg, SINGLE, FedConfig())
    flat = jax.tree_util.tree_flatten_with_path(pspecs)[0]
    for kp, spec in flat:
        name = getattr(kp[-1], "key", "")
        if str(name).startswith("moe_exp_"):
            assert spec[1] == "model", (name, spec)  # (L, E, ...) E sharded


def test_mixtral_falls_back_to_tensor_parallel():
    cfg = get_arch("mixtral-8x7b")                # 8 experts < 16 chips
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = shspecs.param_pspecs(params, cfg, SINGLE, FedConfig())
    flat = jax.tree_util.tree_flatten_with_path(pspecs)[0]
    for kp, spec in flat:
        name = str(getattr(kp[-1], "key", ""))
        if name.startswith("moe_exp_"):
            assert spec[1] != "model"             # E axis NOT sharded
            assert "model" in tuple(spec)         # F dim is


def test_batch_pspec_layouts():
    fed_p = FedConfig(layout="client_parallel")
    fed_s = FedConfig(layout="client_sequential")
    fed_s_mb = FedConfig(layout="client_sequential", grad_microbatches=4)
    assert shspecs.batch_pspec(SINGLE, fed_p, rank=4)[0] == "data"
    assert shspecs.batch_pspec(SINGLE, fed_s, rank=4)[2] == "data"
    assert shspecs.batch_pspec(SINGLE, fed_s_mb, rank=5)[3] == "data"
    assert shspecs.batch_pspec(MULTI, fed_p, rank=4)[0] == ("pod", "data")


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_exist_for_all_shapes(arch):
    """input_specs must produce weak-type-correct stand-ins for every
    (arch x shape) — no allocation, only ShapeDtypeStructs."""
    from repro.config import INPUT_SHAPES
    cfg = get_arch(arch)
    model = build_model(cfg)
    fed = FedConfig(layout=cfg.fl_layout)
    for sname, ishape in INPUT_SHAPES.items():
        if ishape.kind == "train":
            batch = ispecs.train_batch_specs(cfg, SINGLE, fed, ishape)
            assert batch["tokens"].shape[-1] == ishape.seq_len
        elif ishape.kind == "prefill":
            batch = ispecs.prefill_batch_specs(cfg, ishape)
            assert batch["tokens"].shape == (ishape.global_batch,
                                             ishape.seq_len)
        else:
            if (sname == "long_500k"
                    and not cfg.supports_long_context_decode):
                continue
            d = ispecs.decode_input_specs(model, cfg, ishape)
            assert d["tokens"].shape == (ishape.global_batch, 1)
            assert all(hasattr(leaf, "shape")
                       for leaf in jax.tree.leaves(d["cache"]))
