"""Telemetry subsystem: trace-export schema, counter registry
semantics, telemetry-off bit-exactness in both layouts (pipelined +
fused), diagnostics correctness against a direct recomputation, logger
lifecycle hardening, and the enabled-telemetry overhead bound."""
import csv
import dataclasses
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro import telemetry
from repro.config import FedConfig
from repro.core import build_fed_state
from repro.core.rounds import make_local_phase, trace_round_jaxpr
from repro.data import RoundBatchGenerator, make_task
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,
                                   plan_round_blocks)
from repro.metrics import CSVLogger, JSONLLogger, MetricsSpool

# honor the CI layout matrix (same pattern as test_scenario.py)
_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT", "")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

ROUNDS, EVERY = 6, 3


def _task(cfg, num_clients=4, seq_len=16, num_samples=256, seed=0):
    return make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=seq_len,
                     num_samples=num_samples, num_clients=num_clients,
                     dirichlet_alpha=0.6, seed=seed)


def _gen(task, seed=7, local_steps=2, batch_size=2):
    return RoundBatchGenerator(task, num_clients=task.num_clients,
                               clients_per_round=2, local_steps=local_steps,
                               batch_size=batch_size, rng=seed)


def _drive(engine, params, sstate, gen, blocks, depth):
    pre = HostPrefetcher(gen, blocks, depth=depth, stacked=engine.stacked)
    spool = MetricsSpool()
    for start, size, batches, cids in pre:
        params, sstate, m = engine.run_block(params, sstate, batches, cids,
                                             start, size)
        spool.append(start, m, size)
    return spool.flush(), params


# ------------------------------------------------------- tracer / registry

def test_tracer_records_matched_complete_events():
    tr = telemetry.Tracer()
    with tr.span("outer"):
        with tr.span("inner", "trace"):
            pass
    with pytest.raises(RuntimeError):
        with tr.span("raising"):
            raise RuntimeError("boom")
    evs = tr.events()
    # every span produced exactly one complete event — begin/end matched
    # by construction, including through the exception path
    assert [e["name"] for e in evs] == ["inner", "outer", "raising"]
    for e in evs:
        assert e["ph"] == "X"
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    # nesting: inner lies within outer on the same tid
    inner, outer = evs[0], evs[1]
    assert inner["tid"] == outer["tid"]
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_tracer_thread_metadata_and_export(tmp_path):
    tr = telemetry.Tracer()

    def worker():
        with tr.span("producer-work"):
            time.sleep(0.001)

    t = threading.Thread(target=worker, name="my-producer")
    with tr.span("main-work"):
        t.start()
        t.join()
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert len({e["tid"] for e in spans}) == 2
    names = {m["args"]["name"] for m in metas}
    assert "my-producer" in names


def test_registry_shares_and_snapshots():
    reg = telemetry.Registry()
    a = reg.counter("x")
    assert reg.counter("x") is a  # collision -> same accumulator
    a.add(1.5)
    reg.counter("x").add(1.0)
    reg.gauge("g").set(3.0)
    assert reg.snapshot() == {"x": 2.5, "g": 3.0}
    assert reg.value("missing", default=-1.0) == -1.0
    with pytest.raises(TypeError):
        reg.gauge("x")  # name already bound to a Counter


def test_session_module_functions_noop_without_session():
    assert telemetry.active() is None
    # shared no-op span, free-floating counters: no crash, no state
    with telemetry.span("nothing"):
        pass
    telemetry.add("c", 1.0)
    telemetry.set_gauge("g", 2.0)
    c = telemetry.counter("free")
    c.add(4.0)
    assert c.value == 4.0
    with telemetry.session() as tele:
        assert telemetry.active() is tele
        telemetry.add("c", 1.0)
        assert tele.counters.value("c") == 1.0
    assert telemetry.active() is None


# ------------------------------------------- bit-exactness, both layouts

@pytest.mark.parametrize("layout", LAYOUTS)
def test_disabled_telemetry_bit_exact(layout):
    """A live tracing session (host spans + counters) must not touch the
    device program. Structural check FIRST: the round program traced
    inside ``telemetry.session()`` is byte-identical to the no-session
    trace, single-round AND rounds_per_call-fused (jaxpr gate-parity,
    docs/analysis.md — milliseconds of IR diff where this test used to
    drive four full trajectories). One pipelined eager trajectory pair
    stays as the end-to-end backstop."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(algorithm="fedadamw", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    layout=layout, sequential_clients=2)

    base_txt = str(trace_round_jaxpr(model, fed, cfg=cfg)[0])
    with telemetry.session():
        live_txt = str(trace_round_jaxpr(model, fed, cfg=cfg)[0])
        live_fused = str(trace_round_jaxpr(model, fed, cfg=cfg,
                                           multi_rounds=3)[0])
    base_fused = str(trace_round_jaxpr(model, fed, cfg=cfg,
                                       multi_rounds=3)[0])
    assert base_txt == live_txt          # single-round program unchanged
    assert base_fused == live_fused      # fused scan program unchanged

    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg,
                         cosine_total_rounds=ROUNDS, donate=False)
    blocks1 = plan_round_blocks(ROUNDS, EVERY, 1)
    base, p_base = _drive(engine, params, sstate, _gen(task), blocks1, 2)
    with telemetry.session():
        traced, p_traced = _drive(engine, params, sstate, _gen(task),
                                  blocks1, 2)
    assert [m for _, m in base] == [m for _, m in traced]
    for a, b in zip(jax.tree.leaves(p_base), jax.tree.leaves(p_traced)):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_diagnostics_do_not_perturb_training(layout):
    """telemetry_diagnostics adds metric outputs but must leave the
    params/loss trajectory bit-identical: the gauges only READ the
    uploads, never feed back into the update."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(algorithm="fedadamw", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    layout=layout, sequential_clients=2)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    diag_fed = dataclasses.replace(fed, telemetry_diagnostics=True)
    plain = RoundEngine(model, fed, specs, alg=alg, donate=False)
    diag = RoundEngine(model, diag_fed, specs, alg=alg, donate=False)
    blocks = plan_round_blocks(4, 4, 1)

    rows_p, p_plain = _drive(plain, params, sstate, _gen(task), blocks, 0)
    rows_d, p_diag = _drive(diag, params, sstate, _gen(task), blocks, 0)
    assert [m["loss_mean"] for _, m in rows_p] == \
        [m["loss_mean"] for _, m in rows_d]
    for a, b in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_diag)):
        assert jnp.array_equal(a, b)
    for _, m in rows_d:
        assert "client_drift_rms" in m and "v_bar_variance" in m
        assert np.isfinite(m["client_drift_rms"])
        assert m["v_bar_variance"] >= 0.0


def test_diagnostics_layout_parity():
    """Both layouts compute the SAME gauges (vmap+mean vs online sum)."""
    if _ENV_LAYOUT:
        pytest.skip("layout matrix pins a single layout")
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    rows = {}
    for layout in ("client_parallel", "client_sequential"):
        fed = FedConfig(algorithm="fedadamw", num_clients=4,
                        clients_per_round=2, local_steps=2, lr=1e-3,
                        layout=layout, sequential_clients=2,
                        telemetry_diagnostics=True)
        params, specs, alg, sstate = build_fed_state(
            model, fed, jax.random.key(0), cfg=cfg)
        engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
        rows[layout], _ = _drive(engine, params, sstate, _gen(task),
                                 plan_round_blocks(3, 3, 1), 0)
    for (_, mp), (_, ms) in zip(rows["client_parallel"],
                                rows["client_sequential"]):
        assert mp["client_drift_rms"] == pytest.approx(
            ms["client_drift_rms"], rel=1e-4, abs=1e-7)
        assert mp["v_bar_variance"] == pytest.approx(
            ms["v_bar_variance"], rel=1e-4, abs=1e-12)


def test_diagnostics_match_direct_recomputation():
    """client_drift_rms from the round program equals the drift computed
    directly from the per-client uploads (E-decomposition identity)."""
    cfg, model, _ = build_tiny("dense")
    task = _task(cfg)
    fed = FedConfig(algorithm="fedadamw", num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    telemetry_diagnostics=True)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    gen = _gen(task)
    batches, cids = gen.next_round()
    batches = {k: jnp.asarray(v) for k, v in batches.items()}
    cids = jnp.asarray(cids)
    _, _, m = engine.run_block(params, sstate, batches, cids, 0, 1)

    # recompute from the SAME per-client uploads, straight vmap
    local = make_local_phase(model.loss, alg, fed, specs)
    uploads, _ = jax.vmap(local, in_axes=(None, None, 0, None, 0))(
        params, sstate, batches, jnp.ones((), jnp.float32), cids)
    deltas = [np.concatenate([np.ravel(leaf[i]) for leaf in
                              jax.tree.leaves(uploads["delta"])])
              for i in range(2)]
    dbar = np.mean(deltas, axis=0)
    drift_sq = np.mean([np.sum((d - dbar) ** 2) for d in deltas])
    assert float(m["client_drift_rms"]) == pytest.approx(
        np.sqrt(drift_sq), rel=1e-4)
    vs = [np.concatenate([np.ravel(leaf[i]) for leaf in
                          jax.tree.leaves(uploads["v_mean"])])
          for i in range(2)]
    vvar = np.mean((np.stack(vs) - np.mean(vs, axis=0)) ** 2)
    assert float(m["v_bar_variance"]) == pytest.approx(vvar, rel=1e-3,
                                                       abs=1e-15)


# --------------------------------------------------- end-to-end trace file

def test_run_training_trace_export_schema(tmp_path):
    """--trace-dir must yield valid Chrome-trace JSON: >= 6 distinct span
    types, every event complete with pid/tid, and producer-thread spans
    on their own tid named round-prefetcher."""
    from repro.launch.train import run_training
    td = str(tmp_path / "trace")
    h = run_training(arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
                     num_clients=4, clients_per_round=2, local_steps=2,
                     batch_size=4, eval_every=2, seed=3, prefetch_depth=2,
                     rounds_per_call=2, trace_dir=td,
                     telemetry_diagnostics=True,
                     log_path=str(tmp_path / "m.csv"))
    doc = json.load(open(os.path.join(td, "trace.json")))
    evs = doc["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    for e in spans:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid"}
        assert e["dur"] >= 0
    names = {e["name"] for e in spans}
    assert len(names) >= 6, names
    assert {"sample", "assemble", "stage", "dispatch", "eval",
            "flush"} <= names
    # producer-thread spans are distinguishable by tid + metadata
    tids = {e["tid"] for e in spans}
    assert len(tids) >= 2
    meta_names = {e["args"]["name"] for e in evs if e.get("ph") == "M"}
    assert "round-prefetcher" in meta_names
    producer_tid = next(e["tid"] for e in evs if e.get("ph") == "M"
                        and e["args"]["name"] == "round-prefetcher")
    assert {e["name"] for e in spans if e["tid"] == producer_tid} \
        >= {"assemble", "stage"}

    counters = json.load(open(os.path.join(td, "counters.json")))
    assert counters["rounds/completed"] == 4.0
    assert counters["prefetch/produce_s"] > 0.0
    assert counters["comm/wire_bytes_total"] > 0.0
    assert counters["round/cohort_size"] == 2.0
    # history carries the derived gauge rows
    assert len(h["host_blocked_frac"]) == 2      # one per eval round
    assert len(h["client_drift_rms"]) == 4       # every round
    assert all(v >= 0 for v in h["v_bar_variance"])

    # the CSV carries the new columns
    rows = list(csv.DictReader(open(tmp_path / "m.csv")))
    assert "host_blocked_frac" in rows[0]
    assert all(r["client_drift_rms"] != "" for r in rows)

    # tools/report_run.py renders the artifacts without jax
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "report_run", os.path.join(os.path.dirname(__file__), "..",
                                   "tools", "report_run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    text = mod.report(td, str(tmp_path / "m.csv"))
    assert "## counters" in text and "## spans" in text
    assert "dispatch" in text


def test_run_training_without_trace_dir_leaves_no_session(tmp_path):
    from repro.launch.train import run_training
    run_training(arch="vit-tiny-fl", algorithm="fedadamw", rounds=2,
                 num_clients=4, clients_per_round=2, local_steps=1,
                 batch_size=4, eval_every=2, seed=3)
    assert telemetry.active() is None
    assert not (tmp_path / "trace.json").exists()


def test_run_training_crash_exports_and_closes(tmp_path, monkeypatch):
    """A crash mid-run must still leave a flushed, closed CSV and the
    partial trace/counters export (the try/finally hardening)."""
    import repro.launch.train as train_mod

    def boom(*a, **k):
        raise RuntimeError("eval exploded")

    monkeypatch.setattr(train_mod, "evaluate", boom)
    td = str(tmp_path / "trace")
    csv_path = str(tmp_path / "m.csv")
    with pytest.raises(RuntimeError, match="eval exploded"):
        train_mod.run_training(
            arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
            num_clients=4, clients_per_round=2, local_steps=1,
            batch_size=4, eval_every=2, seed=3, trace_dir=td,
            log_path=csv_path)
    assert telemetry.active() is None            # session uninstalled
    assert os.path.exists(os.path.join(td, "trace.json"))
    assert os.path.exists(os.path.join(td, "counters.json"))
    rows = list(csv.DictReader(open(csv_path)))   # parseable, flushed
    assert len(rows) >= 1                        # salvaged train rows
    assert all(r["train_loss"] != "" for r in rows)


# ------------------------------------------------------------- loggers

def test_csv_logger_context_manager_idempotent_close(tmp_path):
    path = str(tmp_path / "x.csv")
    with CSVLogger(path, fieldnames=["a"]) as lg:
        lg.log({"a": 1})
    lg.close()  # second close is a no-op
    lg.close()
    assert list(csv.DictReader(open(path))) == [{"a": "1"}]


def test_jsonl_logger_context_manager_idempotent_close(tmp_path):
    path = str(tmp_path / "x.jsonl")
    with JSONLLogger(path) as lg:
        lg.log({"a": 1})
    lg.close()
    lg.close()
    with pytest.raises(ValueError, match="closed"):
        lg.log({"b": 2})
    assert [json.loads(s) for s in open(path)] == [{"a": 1}]


# ------------------------------------------------------------- overhead

def test_enabled_telemetry_overhead_under_5_percent():
    """Live tracing+counters must cost < 5% rounds/s on the
    round_throughput bench config (1-layer d32, fused dispatch)."""
    from repro.config import get_arch
    from repro.config.model_config import reduced_variant
    from repro.models import build_model
    cfg = reduced_variant(get_arch("vit-tiny-fl"), num_layers=1,
                          d_model=32)
    model = build_model(cfg, compute_dtype=jnp.float32)
    task = make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=8,
                     num_samples=512, num_clients=8, dirichlet_alpha=0.6,
                     seed=0)
    fed = FedConfig(algorithm="fedadamw", num_clients=8,
                    clients_per_round=2, local_steps=1, lr=3e-4,
                    rounds_per_call=8)
    params, specs, alg, sstate = build_fed_state(model, fed,
                                                 jax.random.key(0))
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    rounds = 48
    blocks = plan_round_blocks(rounds, rounds + 1, 8)

    def one_pass(traced: bool):
        gen = RoundBatchGenerator(task, num_clients=8, clients_per_round=2,
                                  local_steps=1, batch_size=2, rng=1)
        ctx = telemetry.session() if traced else None
        if ctx is not None:
            telemetry.install(ctx)
        try:
            pre = HostPrefetcher(gen, blocks, depth=2, stacked=True)
            p, s = params, sstate
            pending = []
            t0 = time.perf_counter()
            for start, size, batches, cids in pre:
                p, s, m = engine.run_block(p, s, batches, cids, start, size)
                pending.append(m["loss_mean"])
            jax.block_until_ready(pending)
            return time.perf_counter() - t0
        finally:
            if ctx is not None:
                telemetry.uninstall(ctx)

    one_pass(False), one_pass(True)  # compile + warm both paths
    best = {False: float("inf"), True: float("inf")}
    # interleaved min-of-reps: both variants sample the same noise
    for _ in range(5):
        for traced in (False, True):
            best[traced] = min(best[traced], one_pass(traced))
    overhead = best[True] / best[False] - 1.0
    assert overhead < 0.05, (
        f"enabled telemetry costs {overhead:.1%} rounds/s "
        f"(off={best[False]:.4f}s on={best[True]:.4f}s)")
