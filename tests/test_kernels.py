"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.blockmean.ops import block_means_2d
from repro.kernels.blockmean.ref import column_mean_ref
from repro.kernels.fused_adamw import ops as fops
from repro.kernels.fused_adamw.fused_adamw import (BLOCK_ROWS, LANES,
                                                   fused_adamw_2d)
from repro.kernels.fused_adamw.ref import fused_adamw_ref

SCALARS = jnp.asarray([0.9, 0.999, 0.1, 0.00799, 3e-4, 0.5, 0.01, 1e-8],
                      jnp.float32)


@pytest.mark.parametrize("rows", [BLOCK_ROWS, 2 * BLOCK_ROWS, 5 * BLOCK_ROWS])
def test_fused_adamw_tile_shapes(rows):
    rng = np.random.default_rng(rows)
    ops = [jnp.asarray(rng.normal(size=(rows, LANES)), jnp.float32)
           for _ in range(4)]
    v = jnp.asarray(rng.uniform(0.0, 1.0, size=(rows, LANES)), jnp.float32)
    got = fused_adamw_2d(ops[0], ops[1], ops[2], v, ops[3], SCALARS)
    want = fused_adamw_ref(ops[0], ops[1], ops[2], v, ops[3], SCALARS)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from([(7,), (130,), (13, 77), (3, 5, 9), (1,), (256,)]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 1000),
)
def test_fused_adamw_tree_sweep(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(rng.normal(size=shape), dtype)}
    g = {"w": jnp.asarray(rng.normal(size=shape), dtype)}
    m = {"w": jnp.zeros(shape, jnp.float32)}
    v = {"w": jnp.asarray(rng.uniform(0, 1, size=shape), jnp.float32)}
    dg = {"w": jnp.asarray(rng.normal(size=shape), jnp.float32)}
    p2, m2, v2 = fops.tree_fused_adamw_step(
        tree, g, m, v, dg, beta1=0.9, beta2=0.999, c1=0.1, c2=0.00799,
        lr=3e-4, alpha=0.5, lam=0.01, eps=1e-8)
    xr, mr, vr = fused_adamw_ref(tree["w"], g["w"], m["w"], v["w"], dg["w"],
                                 SCALARS)
    np.testing.assert_allclose(np.asarray(p2["w"], np.float32),
                               np.asarray(xr.astype(p2["w"].dtype),
                                          np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)
    np.testing.assert_allclose(np.asarray(m2["w"]), np.asarray(mr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v2["w"]), np.asarray(vr),
                               rtol=1e-4, atol=1e-5)


def test_fused_adamw_apply_only_variant():
    rng = np.random.default_rng(0)
    shape = (33, 9)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    m = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 1, size=shape), jnp.float32)
    dg = jnp.asarray(rng.normal(size=shape), jnp.float32)
    got = fops.tree_fused_adamw_apply(
        {"w": x}, {"w": m}, {"w": v}, {"w": dg},
        c1=0.1, c2=0.00799, lr=3e-4, alpha=0.5, lam=0.01, eps=1e-8)
    want = x - 3e-4 * ((m / 0.1) / (jnp.sqrt(v / 0.00799) + 1e-8)
                       + 0.5 * dg + 0.01 * x)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 700),
    cols=st.integers(1, 700),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_blockmean_sweep(rows, cols, dtype):
    rng = np.random.default_rng(rows * 1000 + cols)
    x = jnp.asarray(rng.normal(size=(rows, cols)), dtype)
    got = block_means_2d(x)
    want = column_mean_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


def test_blockmean_exact_fp32():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1000, 513)), jnp.float32)
    np.testing.assert_allclose(np.asarray(block_means_2d(x)),
                               np.asarray(column_mean_ref(x)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# quantpack: fused per-tensor scale + quantize-pack (upload codecs)
# ---------------------------------------------------------------------------

from repro.kernels.quantpack import (quantpack_int4_2d, quantpack_int8_2d,
                                     quantpack_leaf)
from repro.kernels.quantpack.quantpack import BLOCK_ROWS as QP_ROWS
from repro.kernels.quantpack.quantpack import LANES as QP_LANES
from repro.kernels.quantpack.ref import quantpack_int4_ref, quantpack_int8_ref


@pytest.mark.parametrize("tiles", [1, 2, 5])
def test_quantpack_int8_matches_ref_bit_exact(tiles):
    rng = np.random.default_rng(tiles)
    x = jnp.asarray(rng.normal(size=(tiles * QP_ROWS, QP_LANES)),
                    jnp.float32)
    q, s = quantpack_int8_2d(x)
    qr, sr = quantpack_int8_ref(x)
    # scale bit-exact, codes exact (deterministic round-to-nearest)
    assert np.asarray(s[0, 0]).tobytes() == np.asarray(sr).tobytes()
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


@pytest.mark.parametrize("tiles", [1, 3])
def test_quantpack_int4_matches_ref_bit_exact(tiles):
    rng = np.random.default_rng(100 + tiles)
    x = jnp.asarray(rng.normal(size=(tiles * QP_ROWS, QP_LANES)),
                    jnp.float32)
    u = jnp.asarray(rng.uniform(size=x.shape), jnp.float32)
    packed, s = quantpack_int4_2d(x, u)
    pr, sr = quantpack_int4_ref(x, u)
    assert packed.dtype == jnp.uint8 and packed.shape == (x.shape[0],
                                                          QP_LANES // 2)
    assert np.asarray(s[0, 0]).tobytes() == np.asarray(sr).tobytes()
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pr))


def test_quantpack_leaf_matches_jnp_codec():
    """The kernel path emits the exact wire payload of the jnp int8 codec
    (same scale formula, same packing) for arbitrary leaf shapes."""
    from repro.comm.codecs import _int8_encode_leaf
    rng = np.random.default_rng(0)
    for shape in [(37, 19), (5,), (130,), (3, 5, 9)]:
        leaf = jnp.asarray(rng.normal(size=shape), jnp.float32)
        pk = quantpack_leaf(leaf, bits=8)
        pj = _int8_encode_leaf(leaf, None)
        assert np.asarray(pk["scale"]).tobytes() == \
            np.asarray(pj["scale"]).tobytes()
        np.testing.assert_array_equal(np.asarray(pk["q"]),
                                      np.asarray(pj["q"]))


def test_quantpack_leaf_int4_wire_size_and_bound():
    from repro.comm.codecs import _int4_decode_leaf
    rng = np.random.default_rng(1)
    leaf = jnp.asarray(rng.normal(size=(33, 7)), jnp.float32)  # odd count
    payload = quantpack_leaf(leaf, bits=4, key=jax.random.PRNGKey(2))
    assert payload["q"].shape == ((leaf.size + 1) // 2,)
    dec = _int4_decode_leaf(payload, leaf.shape, jnp.float32)
    err = float(jnp.max(jnp.abs(dec - leaf)))
    assert err <= float(payload["scale"]) + 1e-7


# ---------------------------------------------------------------------------
# clipacc: fused per-client L2 clip + weighted accumulate (DP hot path)
# ---------------------------------------------------------------------------

from repro.kernels.clipacc import clip_accumulate_3d, tree_clip_accumulate
from repro.kernels.clipacc.clipacc import BLOCK_ROWS as CA_ROWS
from repro.kernels.clipacc.clipacc import LANES as CA_LANES
from repro.kernels.clipacc.ref import clip_accumulate_ref


@pytest.mark.parametrize("s_n,tiles", [(1, 1), (2, 1), (3, 2), (4, 5)])
def test_clipacc_matches_ref_bit_exact(s_n, tiles):
    rng = np.random.default_rng(10 * s_n + tiles)
    x = jnp.asarray(rng.normal(size=(s_n, tiles * CA_ROWS, CA_LANES)),
                    jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(s_n,)), jnp.float32)
    for clip in (0.5, 1e3):  # biting and non-biting bounds
        acc, f = clip_accumulate_3d(x, w, clip)
        acc_r, f_r = clip_accumulate_ref(x, w, clip)
        assert np.asarray(acc).tobytes() == np.asarray(acc_r).tobytes()
        assert np.asarray(f).tobytes() == np.asarray(f_r).tobytes()


def test_clipacc_factors_and_norm_semantics():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, CA_ROWS, CA_LANES)), jnp.float32)
    clip = 0.25 * float(jnp.linalg.norm(x[0].ravel()))
    w = jnp.asarray([1.0, 1.0], jnp.float32)
    _, f = clip_accumulate_3d(x, w, clip)
    norms = [float(jnp.linalg.norm(x[s].ravel())) for s in range(2)]
    for s in range(2):
        want = min(1.0, clip / norms[s])
        assert float(f[s, 0]) == pytest.approx(want, rel=1e-5)
    # huge bound: factors exactly 1, accumulate is the plain weighted sum
    _, f1 = clip_accumulate_3d(x, w, 1e9)
    np.testing.assert_array_equal(np.asarray(f1), np.ones((2, 1)))


def test_tree_clip_accumulate_matches_jnp_clip_mean():
    """The tree wrapper (arbitrary leaf shapes, zero padding) must equal
    per-client joint-norm clipping followed by the uniform mean."""
    from repro.privacy import clip_tree_by_l2
    rng = np.random.default_rng(3)
    s_n = 3
    tree = {"a": jnp.asarray(rng.normal(size=(s_n, 37, 19)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(s_n, 130)), jnp.float32)}
    w = jnp.full((s_n,), 1.0 / s_n, jnp.float32)
    mean, factors = tree_clip_accumulate(tree, clip=0.5, weights=w)
    clipped = jax.vmap(lambda t: clip_tree_by_l2(t, 0.5))(tree)
    want = jax.tree.map(lambda u: u.mean(axis=0), clipped)
    for k in tree:
        np.testing.assert_allclose(np.asarray(mean[k]),
                                   np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-7)
    assert factors.shape == (s_n, 1) and float(factors.max()) < 1.0
