"""Property-based kernel-parity fuzz: every Pallas kernel vs its ref.py.

Each kernel in ``repro.kernels`` ships a pure-jnp oracle; this harness
sweeps generated shape/value corpora over all five (quantpack, clipacc,
blockmean, fused_adamw, uploadfuse) and asserts the contract stated in
each kernel's docstring — BIT-EXACT where the oracle replays the
kernel's operation sequence (quantpack, clipacc, uploadfuse),
tolerance-bounded where the reduction order legitimately differs
(blockmean, fused_adamw).

Value families come from ``_hypothesis_compat.adversarial_array``:
dense normals, exact zeros, subnormals (squared norms flush to zero —
the NORM_FLOOR/SCALE_FLOOR guards), huge norms (clip factors near 0,
f32 overflow in the squared sums), near-underflow tinies and mixed
sparse outliers. Client-axis edge cases ride the strategies: S=1 stacks
and all-masked (zero-weight) clients.

Runs green with or without ``hypothesis`` installed — the shim in
``_hypothesis_compat`` degrades to a deterministic fallback sweep.
``KERNEL_FUZZ_EXAMPLES=200`` (the CI kernel-fuzz job, and the
acceptance bar locally) raises the per-test corpus in either mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import (VALUE_KINDS, adversarial_array, given,
                                settings, st)
from repro.kernels.blockmean.ops import block_means_2d
from repro.kernels.blockmean.ref import column_mean_ref
from repro.kernels.clipacc.clipacc import clip_accumulate_3d
from repro.kernels.clipacc.ref import clip_accumulate_ref
from repro.kernels.fused_adamw.fused_adamw import fused_adamw_2d
from repro.kernels.fused_adamw.ref import fused_adamw_ref
from repro.kernels.quantpack.ops import quantpack_leaf
from repro.kernels.quantpack.quantpack import (quantpack_int4_2d,
                                               quantpack_int8_2d)
from repro.kernels.quantpack.ref import quantpack_int4_ref, quantpack_int8_ref
from repro.kernels.uploadfuse import tree_upload_fuse
from repro.kernels.uploadfuse.ops import _layout, _stack3d
from repro.kernels.uploadfuse.ref import upload_fuse_ref
from repro.kernels.uploadfuse.uploadfuse import upload_fuse_3d

QP_LANES = 1024
QP_TILE = 64 * QP_LANES        # quantpack BLOCK_ROWS * LANES


def _bits_eq(got, want, label):
    a, b = np.asarray(got), np.asarray(want)
    assert a.dtype == b.dtype and a.shape == b.shape, (label, a.shape,
                                                       b.shape)
    assert a.tobytes() == b.tobytes(), (
        f"{label}: kernel != ref "
        f"(max |diff| {np.max(np.abs(a.astype(np.float64) - b.astype(np.float64)))})")


# --------------------------------------------------------------- quantpack

@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    rows=st.integers(1, 3),
    seed=st.integers(0, 10_000),
    bits=st.sampled_from([8, 4]),
)
def test_quantpack_parity(kind, rows, seed, bits):
    """Codes and scale bit-exact vs the oracle on padded 2-D tiles."""
    x = jnp.asarray(adversarial_array(kind, (rows * 64, QP_LANES), seed))
    if bits == 8:
        q, s = quantpack_int8_2d(x)
        qr, sr = quantpack_int8_ref(x)
    else:
        u = jax.random.uniform(jax.random.fold_in(
            jax.random.PRNGKey(7), seed), x.shape, jnp.float32)
        q, s = quantpack_int4_2d(x, u)
        qr, sr = quantpack_int4_ref(x, u)
    _bits_eq(q, qr, f"quantpack{bits} codes")
    _bits_eq(s[0, 0], sr, f"quantpack{bits} scale")


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    size=st.sampled_from([1, 7, 130, 8191, 8192, 8193]),
    seed=st.integers(0, 10_000),
)
def test_quantpack_leaf_odd_sizes(kind, size, seed):
    """The leaf wrapper (arbitrary sizes, incl. the shared final nibble
    of odd int4 lengths) stays bit-exact vs the oracle on the padded
    view, sliced to the wire length."""
    flat = adversarial_array(kind, (size,), seed)
    pad = (-size) % QP_TILE
    x2d = jnp.asarray(np.concatenate(
        [flat, np.zeros(pad, np.float32)]).reshape(-1, QP_LANES))
    got = quantpack_leaf(jnp.asarray(flat), bits=8)
    qr, sr = quantpack_int8_ref(x2d)
    _bits_eq(got["q"], np.asarray(qr).reshape(-1)[:size], "leaf codes")
    _bits_eq(got["scale"], sr, "leaf scale")


# ----------------------------------------------------------------- clipacc

@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    s=st.integers(1, 4),
    blocks=st.integers(1, 3),
    clip=st.sampled_from([0.05, 1.0, 1e6]),
    masked=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_clipacc_parity(kind, s, blocks, clip, masked, seed):
    """Accumulate and clip factors bit-exact vs the oracle, including
    S=1 stacks and all-masked (zero-weight) client sets."""
    x = jnp.asarray(adversarial_array(kind, (s, blocks * 8, 1024), seed))
    w = (jnp.zeros((s,), jnp.float32) if masked
         else jnp.full((s,), 1.0 / s, jnp.float32))
    acc, f = clip_accumulate_3d(x, w, clip)
    acc_r, f_r = clip_accumulate_ref(x, w, clip)
    _bits_eq(acc, acc_r, "clipacc acc")
    _bits_eq(f, f_r, "clipacc factors")
    if masked:
        assert not np.any(np.asarray(acc)), "all-masked accumulate != 0"


# --------------------------------------------------------------- blockmean

@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    rows=st.integers(1, 700),
    cols=st.integers(1, 700),
    seed=st.integers(0, 10_000),
)
def test_blockmean_tolerance(kind, rows, cols, seed):
    """Column means within tolerance of the oracle (the kernel's tiled
    partial sums legitimately reassociate the reduction)."""
    x = jnp.asarray(adversarial_array(kind, (rows, cols), seed))
    got = np.asarray(block_means_2d(x))
    want = np.asarray(column_mean_ref(x))
    scale = max(float(np.max(np.abs(np.asarray(x)))), 1e-30)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3 * scale)


# ------------------------------------------------------------- fused_adamw

@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    rows=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_fused_adamw_tolerance(kind, rows, seed):
    """Update/moments within tolerance of the oracle under adversarial
    gradient values (huge g overflows v identically on both sides)."""
    shape = (rows * 64, 1024)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    g = jnp.asarray(adversarial_array(kind, shape, seed + 1))
    m = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    v = jnp.asarray(np.abs(adversarial_array(kind, shape, seed + 2)))
    dg = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    scalars = jnp.asarray([0.9, 0.999, 0.1, 0.00799, 3e-4, 0.5, 0.01, 1e-8],
                          jnp.float32)
    got = fused_adamw_2d(x, g, m, v, dg, scalars)
    want = fused_adamw_ref(x, g, m, v, dg, scalars)
    for gg, ww, label in zip(got, want, ("x", "m", "v")):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"fused_adamw {label}")


# -------------------------------------------------------------- uploadfuse

TREES = (
    {"a": (33, 7), "b": (128,)},
    {"w": (2048,)},
    {"a": (5,), "b": (3, 3), "c": (257,)},
)


def _fuzz_tree(shapes, s, kind, seed):
    return {k: jnp.asarray(np.stack([
        adversarial_array(kind, shp, seed + 31 * i + 7 * j)
        for j in range(s)]))
        for i, (k, shp) in enumerate(sorted(shapes.items()))}


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    tree_id=st.integers(0, len(TREES) - 1),
    s=st.integers(1, 3),
    bits=st.sampled_from([0, 8, 4]),
    clip=st.sampled_from([0.0, 0.5]),
    ef=st.booleans(),
    masked=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_uploadfuse_parity(kind, tree_id, s, bits, clip, ef, masked, seed):
    """Every output of the fused upload megakernel — mean, residual,
    clip/re-clip factors, scales, wire codes — bit-exact vs the oracle
    across the full {codec} x {dp} x {ef} pipeline matrix, including
    S=1 stacks and all-masked client sets."""
    shapes = TREES[tree_id]
    stacked = _fuzz_tree(shapes, s, kind, seed)
    ef_stacked = _fuzz_tree(shapes, s, "normal", seed + 991) if ef else None
    w = (jnp.zeros((s,), jnp.float32) if masked
         else jnp.full((s,), 1.0 / s, jnp.float32))
    keys = (jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(3), i))(jnp.arange(s)) if bits == 4 else None)
    res_k = tree_upload_fuse(stacked, ef_stacked, bits=bits, clip=clip,
                             weights=w, keys=keys, impl="kernel")
    res_r = tree_upload_fuse(stacked, ef_stacked, bits=bits, clip=clip,
                             weights=w, keys=keys, impl="ref")
    for field in ("mean", "residual", "clip_factors", "reclip_factors",
                  "scales", "codes"):
        a, b = getattr(res_k, field), getattr(res_r, field)
        assert (a is None) == (b is None), field
        if a is None:
            continue
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            _bits_eq(la, lb, f"uploadfuse {field}")


@settings(max_examples=10, deadline=None)
@given(
    kind=st.sampled_from(VALUE_KINDS),
    s=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_uploadfuse_3d_direct_parity(kind, s, seed):
    """The raw 3-D kernel entry point vs the oracle on a hand-built
    stack (no ops-layer padding in the loop), dp + int8 + ef — the
    3-phase re-clip path."""
    shapes = {"a": (100,), "b": (9, 9)}
    sizes, rows = _layout([jnp.zeros((1,) + v) for v in shapes.values()])
    seg = np.repeat(np.arange(len(sizes), dtype=np.int32),
                    [nr // 8 for nr in rows])
    x = _stack3d([jnp.asarray(adversarial_array(kind, (s,) + shp,
                                                seed + i))
                  for i, shp in enumerate(shapes.values())],
                 sizes, rows, s)
    e = _stack3d([jnp.asarray(adversarial_array("normal", (s,) + shp,
                                                seed + 77 + i))
                  for i, shp in enumerate(shapes.values())],
                 sizes, rows, s)
    w = jnp.full((s,), 1.0 / s, jnp.float32)
    kw = dict(bits=8, dp=True, ef=True, n_leaves=len(sizes))
    got = upload_fuse_3d(x, e, None, w, 0.5, seg, **kw)
    want = upload_fuse_ref(x, e, None, w, 0.5, seg, **kw)
    for a, b, label in zip(got, want, ("acc", "stats", "codes", "res")):
        _bits_eq(a, b, f"uploadfuse_3d {label}")


# ---------------------------------------------------------------- harness

def test_fuzz_env_raises_example_count(monkeypatch):
    """KERNEL_FUZZ_EXAMPLES drives the fallback corpus size (the CI
    kernel-fuzz job relies on this); with real hypothesis installed the
    override happens at decoration time instead, so this meta-test only
    applies to the shim."""
    import _hypothesis_compat as hc
    if hc.given.__module__.startswith("hypothesis"):
        pytest.skip("real hypothesis present; override is decoration-time")
    monkeypatch.setenv("KERNEL_FUZZ_EXAMPLES", "57")
    calls = []

    @given(a=st.integers(0, 100), b=st.booleans())
    def probe(a, b):
        calls.append((a, b))

    probe()
    assert len(calls) == 57, len(calls)


def test_adversarial_families_deterministic():
    for kind in VALUE_KINDS:
        a = adversarial_array(kind, (4, 5), 3)
        b = adversarial_array(kind, (4, 5), 3)
        assert a.dtype == np.float32
        assert a.tobytes() == b.tobytes(), kind
    sub = adversarial_array("subnormal", (64,), 0)
    assert np.all(np.abs(sub[sub != 0]) < 1.2e-38)
    with pytest.raises(ValueError):
        adversarial_array("nope", (1,), 0)
