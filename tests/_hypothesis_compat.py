"""``hypothesis`` shim: property tests degrade to fixed-example sweeps.

Tier-1 must run green on a bare interpreter (the CI image installs only
jax + numpy + pytest). When ``hypothesis`` is importable the real
``given``/``settings``/``strategies`` are re-exported unchanged; when it
is not, ``given`` expands each strategy into a small deterministic sample
set and runs the test body over an evenly-spaced slice of their cartesian
product — the same assertions, a fixed handful of examples.
"""
from __future__ import annotations

__all__ = ["given", "settings", "st"]

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ImportError:
    import itertools

    class _Strategy:
        """Carries the deterministic examples used in fallback mode."""

        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(dict.fromkeys((lo, (lo + hi) // 2, hi)))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

        @staticmethod
        def floats(lo, hi, **_kw):
            return _Strategy(dict.fromkeys((lo, (lo + hi) / 2, hi)))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = _St()
    _MAX_EXAMPLES = 12

    def settings(*_a, **_kw):
        return lambda fn: fn

    def given(**strategies):
        names = list(strategies)
        combos = list(itertools.product(
            *(strategies[n].samples for n in names)))
        if len(combos) > _MAX_EXAMPLES:
            # evenly-spaced slice so every strategy still varies
            step = len(combos) / _MAX_EXAMPLES
            combos = [combos[int(i * step)] for i in range(_MAX_EXAMPLES)]

        def deco(fn):
            def wrapper(*args, **kwargs):
                for combo in combos:
                    fn(*args, **dict(zip(names, combo)), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
