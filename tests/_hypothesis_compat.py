"""``hypothesis`` shim: property tests degrade to fixed-example sweeps.

Tier-1 must run green on a bare interpreter (the CI image installs only
jax + numpy + pytest). When ``hypothesis`` is importable the real
``given``/``settings``/``strategies`` are re-exported unchanged; when it
is not, ``given`` expands each strategy into a deterministic sample set
and runs the test body over an evenly-spaced slice of their cartesian
product — the same assertions, a fixed handful of examples.

The kernel fuzz harness (tests/test_kernel_properties.py) layers two
extensions on top, available in BOTH modes:

* ``KERNEL_FUZZ_EXAMPLES=<n>`` env var raises the per-test example count
  (the CI kernel-fuzz job runs the seeded 200-case corpus this way).
  In fallback mode, counts beyond the cartesian product are drawn from a
  seeded RNG over each strategy's domain, so the corpus stays
  deterministic and shrinkable-by-seed.
* :func:`adversarial_array` — the shared value-kind generator for
  kernel inputs: dense normals, exact zeros, subnormals, huge norms,
  near-underflow tinies and mixed outliers, all seeded.
"""
from __future__ import annotations

import os

import numpy as np

__all__ = ["VALUE_KINDS", "adversarial_array", "given", "settings", "st"]

#: adversarial value families for kernel-input fuzzing
VALUE_KINDS = ("normal", "zeros", "subnormal", "huge", "tiny", "mixed")


def adversarial_array(kind: str, shape, seed: int) -> np.ndarray:
    """Deterministic f32 test tensor of the given adversarial family.

    ``subnormal`` values sit below the f32 normal range (~1.18e-38), so
    squared norms flush to zero and exercise the NORM_FLOOR / SCALE_FLOOR
    guards; ``huge`` drives clip factors toward 0 and quantization scales
    toward overflow; ``mixed`` plants sparse outliers in a normal field
    (the absmax is decided by a handful of entries)."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(shape)
    if kind == "normal":
        out = base
    elif kind == "zeros":
        out = np.zeros(shape)
    elif kind == "subnormal":
        out = base * 1e-41
    elif kind == "huge":
        out = base * 1e30
    elif kind == "tiny":
        out = base * 1e-30
    elif kind == "mixed":
        out = np.where(rng.random(shape) < 0.1, base * 1e6,
                       np.where(rng.random(shape) < 0.3, 0.0, base))
    else:
        raise ValueError(f"unknown value kind {kind!r}")
    return np.asarray(out, np.float32)


def _env_examples() -> int:
    return int(os.environ.get("KERNEL_FUZZ_EXAMPLES", "0"))


try:
    from hypothesis import given  # noqa: F401
    from hypothesis import settings as _hyp_settings
    from hypothesis import strategies as st  # noqa: F401

    def settings(*args, **kwargs):
        """hypothesis.settings with the KERNEL_FUZZ_EXAMPLES override."""
        n = _env_examples()
        if n:
            kwargs["max_examples"] = n
        return _hyp_settings(*args, **kwargs)

except ImportError:
    import itertools
    import random

    class _Strategy:
        """Carries the deterministic examples used in fallback mode plus
        an optional seeded draw over the full domain (for corpus sizes
        beyond the fixed cartesian product)."""

        def __init__(self, samples, draw=None):
            self.samples = list(samples)
            self._draw = draw

        def draw(self, rng):
            if self._draw is not None:
                return self._draw(rng)
            return rng.choice(self.samples)

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(dict.fromkeys((lo, (lo + hi) // 2, hi)),
                             draw=lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

        @staticmethod
        def floats(lo, hi, **_kw):
            return _Strategy(dict.fromkeys((lo, (lo + hi) / 2, hi)),
                             draw=lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = _St()
    _MAX_EXAMPLES = 12
    _FUZZ_SEED = 0xFEDADA

    def settings(*_a, max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)

        def deco(fn):
            def wrapper(*args, **kwargs):
                # resolved at CALL time: the env var and the settings()
                # decorator (applied above @given, i.e. to this wrapper)
                # both override the default
                n = (_env_examples()
                     or getattr(wrapper, "_fallback_max_examples", None)
                     or _MAX_EXAMPLES)
                combos = list(itertools.product(
                    *(strategies[nm].samples for nm in names)))
                if len(combos) > n:
                    # evenly-spaced slice so every strategy still varies
                    step = len(combos) / n
                    combos = [combos[int(i * step)] for i in range(n)]
                elif len(combos) < n:
                    rng = random.Random(_FUZZ_SEED)
                    combos += [
                        tuple(strategies[nm].draw(rng) for nm in names)
                        for _ in range(n - len(combos))]
                for combo in combos:
                    fn(*args, **dict(zip(names, combo)), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
