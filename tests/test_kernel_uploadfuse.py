"""Composition parity for the fused upload megakernel.

The engine-level contract: with ``use_pallas_uploadfuse=True`` the whole
training trajectory (losses, params, server state incl. EF tables) is
BIT-IDENTICAL whether ``tree_upload_fuse`` routes to the Pallas kernel
or to the chained jnp oracle (``force_impl``), across the full
{DP on/off} x {int8 / int4 / no codec} x {drop faults} x layout matrix;
fused-vs-unfused trajectories agree to float tolerance (the unfused
engine reduces in a different order); and with the flag OFF the traced
round jaxpr is byte-identical to a config that never mentions it.

Wire-code parity pins the codec contract: the kernel's packed codes and
scales reproduce ``repro.comm.codecs`` byte-for-byte, per client and
per leaf.

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.comm.codecs import get_codec
from repro.config import FedConfig
from repro.config.fed_config import CONSTRAINTS
from repro.core import build_fed_state
from repro.core.rounds import trace_round_jaxpr
from repro.data import RoundBatchGenerator, make_task
from repro.kernels.uploadfuse import (force_impl, tree_upload_fuse,
                                      wire_payloads)
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,
                                   plan_round_blocks)
from repro.metrics import MetricsSpool

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])

ALGS = ("fedadamw", "fedadamw+int8", "fedadamw+int4")


@pytest.fixture(scope="module")
def tiny():
    cfg, model, _ = build_tiny("dense")
    task = make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=16,
                     num_samples=256, num_clients=4, dirichlet_alpha=0.6,
                     seed=0)
    return cfg, model, task


def _drive(model, cfg, task, fed, impl="kernel"):
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    engine = RoundEngine(model, fed, specs, alg=alg, donate=False)
    gen = RoundBatchGenerator(task, num_clients=fed.num_clients,
                              clients_per_round=fed.clients_per_round,
                              local_steps=fed.local_steps, batch_size=2,
                              rng=7)
    pre = HostPrefetcher(gen, plan_round_blocks(3, 3, 1), depth=0,
                         stacked=engine.stacked)
    spool = MetricsSpool()
    with force_impl(impl):
        for start, size, batches, cids in pre:
            params, sstate, m = engine.run_block(params, sstate, batches,
                                                 cids, start, size)
            spool.append(start, m, size)
    losses = [m["loss_mean"] for _, m in spool.flush()]
    return losses, params, sstate


def _assert_bit_identical(a, b, tag):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), tag
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.tobytes() == ya.tobytes(), (
            f"{tag}: kernel/ref trajectories diverged "
            f"(max |diff| {np.max(np.abs(xa - ya))})")


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("algorithm", ALGS)
@pytest.mark.parametrize("dp", [False, True])
def test_engine_kernel_ref_parity_and_unfused_drift(tiny, layout,
                                                    algorithm, dp):
    cfg, model, task = tiny
    fed = FedConfig(algorithm=algorithm, num_clients=4,
                    clients_per_round=2, local_steps=2, lr=1e-3,
                    layout=layout, sequential_clients=2,
                    dp_clip=(0.05 if dp else 0.0),
                    use_pallas_uploadfuse=True)
    lk, pk, sk = _drive(model, cfg, task, fed, "kernel")
    lr_, pr, sr = _drive(model, cfg, task, fed, "ref")
    assert lk == lr_, f"losses diverged: {lk} vs {lr_}"
    _assert_bit_identical(pk, pr, "params")
    _assert_bit_identical(sk, sr, "server state")
    # fused vs stock unfused: same pipeline, different reduction order
    unfused = dataclasses.replace(fed, use_pallas_uploadfuse=False)
    lu, _, _ = _drive(model, cfg, task, unfused)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lu),
                               rtol=1e-4, atol=1e-5,
                               err_msg="fused drifted from unfused")


@pytest.mark.parametrize("algorithm", ["fedadamw+int8", "fedadamw+int4"])
@pytest.mark.parametrize("weighting", ["uniform", "data_size"])
def test_engine_parity_with_drop_faults_and_weights(tiny, algorithm,
                                                    weighting):
    """Drop faults (validity-masked, renormalized accumulation weights)
    and data-size aggregation weights ride the same fused kernel —
    client_parallel only per uploadfuse-sequential-no-drop."""
    if _ENV_LAYOUT == "client_sequential":
        pytest.skip("layout pinned by REPRO_LAYOUT")
    cfg, model, task = tiny
    fed = FedConfig(algorithm=algorithm, num_clients=4,
                    clients_per_round=3, local_steps=2, lr=1e-3,
                    layout="client_parallel", agg_weighting=weighting,
                    fault_drop=0.4, fault_seed=5,
                    use_pallas_uploadfuse=True)
    lk, pk, sk = _drive(model, cfg, task, fed, "kernel")
    lr_, pr, sr = _drive(model, cfg, task, fed, "ref")
    assert lk == lr_
    _assert_bit_identical((pk, sk), (pr, sr), "params+state")


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("algorithm", ALGS)
def test_flag_off_jaxpr_byte_identical(tiny, layout, algorithm):
    """use_pallas_uploadfuse=False must be trace-invisible: the round
    program is byte-identical to one built without the flag (the RA201
    gate-parity rows audit the same invariant in CI)."""
    cfg, model, _ = tiny
    base = FedConfig(algorithm=algorithm, num_clients=4,
                     clients_per_round=2, local_steps=2, lr=1e-3,
                     layout=layout, sequential_clients=2)
    off = dataclasses.replace(base, use_pallas_uploadfuse=False)
    assert (str(trace_round_jaxpr(model, off, cfg=cfg)[0])
            == str(trace_round_jaxpr(model, base, cfg=cfg)[0]))


# ----------------------------------------------------- wire-code parity

@pytest.mark.parametrize("bits", [8, 4])
def test_wire_codes_match_jnp_codec(bits):
    """Per-client per-leaf {"q", "scale"} payloads sliced out of the
    kernel's code block equal the jnp codec's encode bytes."""
    s = 3
    shapes = {"a": (130,), "b": (9, 5), "c": (2048,)}
    rng = np.random.default_rng(42)
    stacked = {k: jnp.asarray(rng.standard_normal((s,) + shp),
                              jnp.float32) for k, shp in shapes.items()}
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(11), i))(jnp.arange(s))
    res = tree_upload_fuse(stacked, None, bits=bits, clip=0.0,
                           weights=jnp.full((s,), 1.0 / s, jnp.float32),
                           keys=keys if bits == 4 else None)
    payloads = wire_payloads(stacked, res, bits=bits)
    codec = get_codec("int8" if bits == 8 else "int4")
    for c in range(s):
        client_tree = jax.tree.map(lambda a: a[c], stacked)
        enc = codec.encode(client_tree,
                           keys[c] if bits == 4 else jax.random.PRNGKey(0))
        assert len(enc.data) == len(payloads[c])
        for li, (want, got) in enumerate(zip(enc.data, payloads[c])):
            for fld in ("q", "scale"):
                assert (np.asarray(got[fld]).tobytes()
                        == np.asarray(want[fld]).tobytes()), (bits, c,
                                                              li, fld)


# ------------------------------------------------ constraint redirects

def test_clipacc_constraints_redirect_to_uploadfuse():
    """The clipacc CONSTRAINTS rows the megakernel lifts now point at
    the flag that lifts them."""
    by_name = {c.name: c for c in CONSTRAINTS}
    for name, cfg_kw, codec in (
            ("clipacc-no-codec",
             dict(use_pallas_clipacc=True, dp_clip=1.0), "int8"),
            ("clipacc-parallel-only",
             dict(use_pallas_clipacc=True, dp_clip=1.0,
                  layout="client_sequential"), "")):
        bad = FedConfig(num_clients=4, clients_per_round=2, **cfg_kw)
        msg = by_name[name].check(bad, codec)
        assert msg and "use_pallas_uploadfuse" in msg, (name, msg)


@pytest.mark.parametrize("kw", [
    dict(algorithm="fedadamw+topk0.1"),
    dict(algorithm="fedadamw+lowrank2"),
    dict(use_pallas_clipacc=True, dp_clip=1.0),
    dict(fault_nan=0.1),
    dict(fault_scale=0.1),
    dict(robust_agg="trimmed0.25"),
    dict(layout="client_sequential", sequential_clients=2,
         fault_drop=0.3),
])
def test_uploadfuse_constraints_reject(kw):
    fed = FedConfig(num_clients=4, clients_per_round=2,
                    use_pallas_uploadfuse=True, **kw)
    with pytest.raises(ValueError, match="uploadfuse"):
        fed.validate()


@pytest.mark.parametrize("kw", [
    dict(algorithm="fedadamw+int8", dp_clip=0.5),
    dict(algorithm="fedadamw+int4"),
    dict(algorithm="fedadamw", fault_drop=0.3),
    dict(layout="client_sequential", sequential_clients=2,
         algorithm="fedadamw+int8"),
])
def test_uploadfuse_constraints_accept_fast_path(kw):
    fed = FedConfig(num_clients=4, clients_per_round=2,
                    use_pallas_uploadfuse=True, **kw)
    fed.validate()
