"""Communication layer: codec round-trips, wire-byte exactness, error
feedback, name parsing, and compressed end-to-end training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.comm import (EF_KEY, compressed, get_codec, payload_wire_bytes,
                        upload_wire_bytes)
from repro.comm.codecs import pack_nibbles, unpack_nibbles
from repro.config import FedConfig
from repro.core import build_fed_state, make_round_fn, upload_shape_spec
from repro.core.fedadamw import get_algorithm

KEY = jax.random.PRNGKey(0)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(37, 19)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(101,)), jnp.bfloat16)}


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------

def test_none_roundtrip_exact():
    x = _tree()
    c = get_codec("none")
    y = c.decode(c.encode(x, KEY))
    for k in x:
        assert y[k].dtype == x[k].dtype
        np.testing.assert_array_equal(np.asarray(y[k], np.float32),
                                      np.asarray(x[k], np.float32))


def test_int8_roundtrip_error_bound():
    x = _tree()
    c = get_codec("int8")
    y = c.decode(c.encode(x, KEY))
    # round-to-nearest: error <= scale / 2 per tensor
    w32 = np.asarray(x["w"], np.float32)
    scale = np.abs(w32).max() / 127.0
    err = np.abs(np.asarray(y["w"], np.float32) - w32).max()
    assert err <= scale * 0.5 + 1e-7, (err, scale)


def test_int4_roundtrip_error_bound():
    x = _tree()
    c = get_codec("int4")
    y = c.decode(c.encode(x, KEY))
    # stochastic floor: error < scale per tensor
    w32 = np.asarray(x["w"], np.float32)
    scale = np.abs(w32).max() / 7.0
    err = np.abs(np.asarray(y["w"], np.float32) - w32).max()
    assert err <= scale + 1e-7, (err, scale)


def test_int4_stochastic_rounding_unbiased():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(400,)), jnp.float32)
    c = get_codec("int4")
    dec = jax.jit(lambda k: c.decode(c.encode(x, k)))
    n = 300
    acc = sum(np.asarray(dec(jax.random.PRNGKey(i))) for i in range(n)) / n
    scale = float(jnp.max(jnp.abs(x))) / 7.0
    # SE of the mean of U[0,1)-rounding error is scale/sqrt(12 n);
    # allow ~5 sigma over the max of 400 elements
    tol = 5.0 * scale / np.sqrt(12 * n)
    assert np.abs(acc - np.asarray(x)).max() < tol


def test_int4_pack_unpack_inverse():
    codes = jnp.asarray(np.random.default_rng(0).integers(0, 16, 64),
                        jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(pack_nibbles(codes), 64)),
        np.asarray(codes))


def test_topk_keeps_largest():
    x = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0], jnp.float32)}
    c = get_codec("topk0.34")  # k = ceil(0.34 * 6) = 3
    y = c.decode(c.encode(x, KEY))["w"]
    np.testing.assert_allclose(np.asarray(y),
                               [0.0, -5.0, 0.2, 3.0, 0.0, 0.0], atol=1e-7)


def test_lowrank_exact_on_lowrank_matrix():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(24, 3)).astype(np.float32)
    b = rng.normal(size=(3, 17)).astype(np.float32)
    x = {"w": jnp.asarray(a @ b)}  # rank 3 exactly
    c = get_codec("lowrank3")
    y = c.decode(c.encode(x, KEY))["w"]
    # single power iteration recovers an exactly-rank-r matrix
    np.testing.assert_allclose(np.asarray(y), a @ b, rtol=1e-3, atol=1e-3)


def test_lowrank_small_leaf_passthrough():
    x = {"b": jnp.asarray(np.random.default_rng(0).normal(size=(11,)),
                          jnp.float32)}
    c = get_codec("lowrank4")
    y = c.decode(c.encode(x, KEY))["b"]
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x["b"]))


# ---------------------------------------------------------------------------
# wire bytes: exact for every codec
# ---------------------------------------------------------------------------

def test_wire_bytes_exact_per_codec():
    x = _tree()
    n_w, n_b = 37 * 19, 101
    expected = {
        "none": n_w * 4 + n_b * 2,                      # f32 + bf16
        "int8": (n_w + 4) + (n_b + 4),                  # bytes + f32 scale
        "int4": ((n_w + 1) // 2 + 4) + ((n_b + 1) // 2 + 4),
        # k = ceil(0.1 * n) values (f32) + indices (int32)
        "topk0.1": (71 * 8) + (11 * 8),
        # w (37, 19): P (37, 2) + Q (19, 2) f32; b: dense passthrough
        "lowrank2": (37 + 19) * 2 * 4 + n_b * 4,
    }
    for spec, want in expected.items():
        c = get_codec(spec)
        got = payload_wire_bytes(c.encode(x, KEY))
        assert got == want, (spec, got, want)
        # byte count is shape-static: the eval_shape spec prices the same
        spec_bytes = c.wire_bytes(
            jax.eval_shape(lambda t: c.encode(t, KEY), x))
        assert spec_bytes == want, (spec, spec_bytes, want)


def test_upload_wire_bytes_skips_ef_and_costs_codec():
    up = {"delta": {"w": jnp.zeros((100,), jnp.float32)},
          "v_mean": jnp.zeros((10,), jnp.float32),
          EF_KEY: {"w": jnp.zeros((100,), jnp.float32)}}
    assert upload_wire_bytes(up, None) == 100 * 4 + 10 * 4
    assert upload_wire_bytes(up, get_codec("int8")) == (100 + 4) + 10 * 4


# ---------------------------------------------------------------------------
# name parsing / registry
# ---------------------------------------------------------------------------

def test_algorithm_codec_suffix_parsing():
    alg = get_algorithm(FedConfig(algorithm="fedadamw+int4"))
    assert alg.name == "fedadamw+int4"
    assert alg.needs_client_ids  # error feedback table is per-client
    alg = get_algorithm(FedConfig(algorithm="fedadamw+topk0.25"))
    assert alg.name == "fedadamw+topk0.25"
    # lossless codec: no feedback, no client ids needed
    alg = get_algorithm(FedConfig(algorithm="fedavg+none"))
    assert not alg.needs_client_ids


def test_unknown_codec_spec_rejected():
    with pytest.raises(ValueError):
        FedConfig(algorithm="fedadamw+int2").validate()
    with pytest.raises(ValueError):
        get_codec("bogus")
    with pytest.raises(ValueError):
        get_codec("topk1.5")


def test_int8_backcompat_alias():
    """The pre-comm-layer ``"+int8"`` spelling and the deprecated
    extensions entry points keep working."""
    from repro.core.extensions import fake_quant_int8, quantized, wire_bytes
    alg = get_algorithm(FedConfig(algorithm="fedadamw+int8"))
    assert alg.name == "fedadamw+int8"
    wrapped = quantized(get_algorithm(FedConfig(algorithm="fedavg")))
    assert wrapped.name == "fedavg+int8"
    assert not wrapped.needs_client_ids  # legacy wrapper: no feedback
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5], jnp.float32)
    q = fake_quant_int8(x)
    np.testing.assert_allclose(float(q[1]), 1.0, rtol=1e-6)
    up = {"delta": {"w": jnp.zeros((100,), jnp.float32)},
          "v_mean": jnp.zeros((10,), jnp.float32)}
    assert wire_bytes(up, delta_int8=True) == 100 + 4 + 10 * 4


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_residual_identity():
    """One upload: residual == compensated target minus wire values."""
    fed = FedConfig(algorithm="fedadamw+int4", num_clients=4,
                    clients_per_round=2, local_steps=2)
    codec = get_codec("int4")
    alg = compressed(get_algorithm(FedConfig(algorithm="fedadamw")),
                     codec, error_feedback=True)
    delta = {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)}
    ef = {"w": jnp.full((8, 16), 0.25, jnp.float32)}
    cstate = {"m": delta, "v": delta, "k": jnp.zeros((), jnp.int32),
              EF_KEY: ef, "comm_cid": jnp.zeros((), jnp.int32)}
    fed0 = FedConfig(algorithm="fedadamw", v_aggregation="none",
                     num_clients=4, clients_per_round=2, local_steps=2)
    up = alg.upload(delta, cstate, None, fed0)
    target = np.asarray(delta["w"]) + 0.25
    np.testing.assert_allclose(
        np.asarray(up[EF_KEY]["w"]),
        target - np.asarray(up["delta"]["w"]), atol=1e-6)
    # lossy wire: residual must be nonzero
    assert float(jnp.abs(up[EF_KEY]["w"]).max()) > 0


def test_stochastic_noise_varies_per_round_and_client():
    """The wrapper's round counter decorrelates int4 rounding noise
    across rounds even for identical deltas (a repeated delta must not
    see the same noise stream, or its quantization error would become a
    systematic bias)."""
    codec = get_codec("int4")
    alg = compressed(get_algorithm(FedConfig(algorithm="fedavg")),
                     codec, error_feedback=True)
    fed = FedConfig(algorithm="fedavg", num_clients=4, clients_per_round=2)
    delta = {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    zero_ef = {"w": jnp.zeros((64,), jnp.float32)}

    def wire(rnd, cid):
        cstate = {"k": jnp.zeros((), jnp.int32), EF_KEY: zero_ef,
                  "comm_cid": jnp.asarray(cid, jnp.int32),
                  "comm_round": jnp.asarray(rnd, jnp.int32)}
        return np.asarray(alg.upload(delta, cstate, None, fed)["delta"]["w"])

    assert not np.array_equal(wire(0, 0), wire(1, 0))  # across rounds
    assert not np.array_equal(wire(0, 0), wire(0, 1))  # across clients
    np.testing.assert_array_equal(wire(2, 1), wire(2, 1))  # reproducible

    # without error feedback there is no client id: the data-salt still
    # decorrelates rounds (via the counter) for a repeated delta
    alg_noef = compressed(get_algorithm(FedConfig(algorithm="fedavg")),
                          codec, error_feedback=False)

    def wire_noef(rnd):
        cstate = {"k": jnp.zeros((), jnp.int32),
                  "comm_round": jnp.asarray(rnd, jnp.int32)}
        return np.asarray(
            alg_noef.upload(delta, cstate, None, fed)["delta"]["w"])

    assert not np.array_equal(wire_noef(0), wire_noef(1))
    np.testing.assert_array_equal(wire_noef(0), wire_noef(0))


def _round_setup(algorithm, num_clients=4):
    cfg, model, _ = build_tiny("dense")
    fed = FedConfig(algorithm=algorithm, num_clients=num_clients,
                    clients_per_round=num_clients, local_steps=4, lr=1e-3)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (num_clients, 4, 4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, -1), jnp.int32)}
    cids = jnp.arange(num_clients, dtype=jnp.int32)
    return fed, params, specs, alg, sstate, round_fn, batch, cids


def test_error_feedback_accumulates_across_rounds():
    fed, params, specs, alg, sstate, round_fn, batch, cids = \
        _round_setup("fedadamw+topk0.1")
    assert EF_KEY in sstate

    def table_norms(s):
        return np.asarray(jnp.stack(
            [jnp.sum(jnp.abs(t)) for t in jax.tree.leaves(s[EF_KEY])]))

    assert table_norms(sstate).sum() == 0.0
    params, sstate, _ = round_fn(params, sstate, batch, cids,
                                 jnp.asarray(0))
    after1 = table_norms(sstate).sum()
    assert after1 > 0.0  # lossy upload left a residual for every client
    params, sstate2, _ = round_fn(params, sstate, batch, cids,
                                  jnp.asarray(1))
    after2 = table_norms(sstate2).sum()
    # round 2 re-encodes delta + residual: table changes but stays bounded
    assert after2 > 0.0 and np.isfinite(after2)
    assert not np.allclose(after1, after2)


# ---------------------------------------------------------------------------
# end-to-end: compressed algorithms train through the jitted round engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedadamw+int4", "fedadamw+topk0.1",
                                       "fedadamw+lowrank4"])
def test_compressed_trains_and_saves_bytes(algorithm):
    fed, params, specs, alg, sstate, round_fn, batch, cids = \
        _round_setup(algorithm)
    losses = []
    for r in range(3):
        params, sstate, m = round_fn(params, sstate, batch, cids,
                                     jnp.asarray(r))
        losses.append(float(m["loss_mean"]))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0], losses
    for p in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(p)))
    # codec-aware wire accounting strictly below the dense upload
    codec = get_codec(algorithm.partition("+")[2])
    spec = upload_shape_spec(alg, params, sstate, specs, fed)
    assert upload_wire_bytes(spec, codec) < upload_wire_bytes(spec, None)


def test_quantized_trajectory_close_to_dense():
    """int4 + EF must not materially change the training trajectory."""
    def run(algorithm):
        fed, params, specs, alg, sstate, round_fn, batch, cids = \
            _round_setup(algorithm)
        losses = []
        for r in range(3):
            params, sstate, m = round_fn(params, sstate, batch, cids,
                                         jnp.asarray(r))
            losses.append(float(m["loss_mean"]))
        return losses

    l_dense = run("fedadamw")
    l_int4 = run("fedadamw+int4")
    assert abs(l_dense[-1] - l_int4[-1]) < 0.1 * abs(l_dense[-1]), \
        (l_dense, l_int4)
