"""Infrastructure tests: checkpointer, HLO cost counter, serve loop,
metrics, roofline param counting."""
import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny, tiny_batch
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.config import get_arch
from repro.core.serve import generate, make_serve_step
from repro.roofline.analysis import count_params, model_flops
from repro.roofline.hlo_counter import analyze_hlo


def test_checkpoint_roundtrip_with_state():
    cfg, model, params = build_tiny("dense")
    state = {"t": jnp.asarray(7, jnp.int32),
             "v": jax.tree.map(lambda p: p * 0.5, params)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 12, params=params, server_state=state,
                        extra={"note": "x"})
        p2, s2, step = restore_checkpoint(d, params_template=params,
                                          state_template=state)
    assert step == 12
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2["t"]) == 7


def test_checkpoint_shape_mismatch_raises():
    cfg, model, params = build_tiny("dense")
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, params=params)
        bad = jax.tree.map(
            lambda p: jnp.zeros(p.shape + (1,), p.dtype), params)
        with pytest.raises(ValueError):
            restore_checkpoint(d, params_template=bad)


def test_hlo_counter_scan_trip_counts():
    def body(c, _):
        return c @ c, None

    def f(x, n):
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    for n in (1, 8):
        txt = jax.jit(functools.partial(f, n=n)).lower(x).compile().as_text()
        got = analyze_hlo(txt)["flops"]
        assert got == pytest.approx(2 * 128 ** 3 * n, rel=0.01), n


def test_hlo_counter_nested_scan():
    def layer(c, w):
        return jnp.tanh(c @ w), None

    def f(ws, x):
        def kstep(c, _):
            y, _ = jax.lax.scan(layer, c, ws)
            return y, None
        y, _ = jax.lax.scan(kstep, x, None, length=3)
        return y

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    got = analyze_hlo(txt)["flops"]
    assert got == pytest.approx(2 * 64 ** 3 * 4 * 3, rel=0.01)


def test_generate_greedy_is_deterministic():
    cfg, model, params = build_tiny("dense")
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    a = generate(model, params, prompt, max_new_tokens=6)
    b = generate(model, params, prompt, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (1, 6)


def test_count_params_matches_actual():
    for arch in ("olmo-1b", "mamba2-780m", "mixtral-8x7b"):
        cfg = get_arch(arch)
        from repro.models import build_model
        model = build_model(cfg)
        tree = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        est = count_params(cfg)["total"]
        # analytic count excludes norms/frontends and uses unpadded vocab:
        # must agree within 5%
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_model_flops_moe_uses_active():
    dense_like = get_arch("olmo-1b")
    moe = get_arch("mixtral-8x7b")
    c = count_params(moe)
    assert c["active"] < 0.45 * c["total"]
    assert model_flops(moe, 1000) == pytest.approx(6 * c["active"] * 1000)
