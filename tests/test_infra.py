"""Infrastructure tests: checkpointer, HLO cost counter, serve loop,
metrics, roofline param counting."""
import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.config import get_arch
from repro.core.serve import generate
from repro.roofline.analysis import count_params, model_flops
from repro.roofline.hlo_counter import analyze_hlo


def test_checkpoint_roundtrip_with_state():
    cfg, model, params = build_tiny("dense")
    state = {"t": jnp.asarray(7, jnp.int32),
             "v": jax.tree.map(lambda p: p * 0.5, params)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 12, params=params, server_state=state,
                        extra={"note": "x"})
        p2, s2, step = restore_checkpoint(d, params_template=params,
                                          state_template=state)
    assert step == 12
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2["t"]) == 7


def test_checkpoint_shape_mismatch_raises():
    cfg, model, params = build_tiny("dense")
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, params=params)
        bad = jax.tree.map(
            lambda p: jnp.zeros(p.shape + (1,), p.dtype), params)
        with pytest.raises(ValueError):
            restore_checkpoint(d, params_template=bad)


def test_hlo_counter_scan_trip_counts():
    def body(c, _):
        return c @ c, None

    def f(x, n):
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    for n in (1, 8):
        txt = jax.jit(functools.partial(f, n=n)).lower(x).compile().as_text()
        got = analyze_hlo(txt)["flops"]
        assert got == pytest.approx(2 * 128 ** 3 * n, rel=0.01), n


def test_hlo_counter_nested_scan():
    def layer(c, w):
        return jnp.tanh(c @ w), None

    def f(ws, x):
        def kstep(c, _):
            y, _ = jax.lax.scan(layer, c, ws)
            return y, None
        y, _ = jax.lax.scan(kstep, x, None, length=3)
        return y

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    got = analyze_hlo(txt)["flops"]
    assert got == pytest.approx(2 * 64 ** 3 * 4 * 3, rel=0.01)


def test_generate_greedy_is_deterministic():
    cfg, model, params = build_tiny("dense")
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    a = generate(model, params, prompt, max_new_tokens=6)
    b = generate(model, params, prompt, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (1, 6)


def test_count_params_matches_actual():
    for arch in ("olmo-1b", "mamba2-780m", "mixtral-8x7b"):
        cfg = get_arch(arch)
        from repro.models import build_model
        model = build_model(cfg)
        tree = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        est = count_params(cfg)["total"]
        # analytic count excludes norms/frontends and uses unpadded vocab:
        # must agree within 5%
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_model_flops_moe_uses_active():
    dense_like = get_arch("olmo-1b")
    moe = get_arch("mixtral-8x7b")
    c = count_params(moe)
    assert c["active"] < 0.45 * c["total"]
    assert model_flops(moe, 1000) == pytest.approx(6 * c["active"] * 1000)


# ---------------------------------------------------------------------------
# metric logging / eval loop regressions
# ---------------------------------------------------------------------------

def test_csvlogger_header_grows_with_late_keys(tmp_path):
    """Regression: the header used to freeze on the first row's keys, so
    eval-only columns (test_acc/test_loss) logged on later rounds were
    silently dropped from every training CSV."""
    from repro.metrics import CSVLogger
    path = str(tmp_path / "log.csv")
    lg = CSVLogger(path)
    lg.log({"round": 0, "train_loss": 1.0})
    lg.log({"round": 1, "train_loss": 0.9, "test_acc": 0.5,
            "test_loss": 2.0})
    lg.log({"round": 2, "train_loss": 0.8})
    lg.close()
    lines = open(path).read().strip().split("\n")
    header = lines[0].split(",")
    assert "test_acc" in header and "test_loss" in header
    rows = [dict(zip(header, ln.split(","))) for ln in lines[1:]]
    assert len(rows) == 3
    assert rows[1]["test_acc"] == "0.5"      # the eval row landed
    assert rows[0]["test_acc"] == ""         # non-eval rows: empty cell
    assert rows[2]["train_loss"] == "0.8"


def test_csvlogger_fieldnames_superset_upfront(tmp_path):
    from repro.metrics import CSVLogger
    path = str(tmp_path / "log.csv")
    lg = CSVLogger(path, fieldnames=["round", "train_loss", "test_acc"])
    lg.log({"round": 0, "train_loss": 1.0})
    lg.close()
    lines = open(path).read().strip().split("\n")
    assert lines[0] == "round,train_loss,test_acc"
    assert lines[1] == "0,1.0,"


def test_training_csv_contains_eval_rows(tmp_path):
    """End-to-end: an eval-round row must land in the training CSV."""
    from repro.launch.train import run_training
    path = str(tmp_path / "train.csv")
    run_training(arch="vit-tiny-fl", algorithm="fedavg", rounds=2,
                 num_clients=2, clients_per_round=2, local_steps=2,
                 batch_size=2, eval_every=2, log_path=path, cosine=False)
    lines = open(path).read().strip().split("\n")
    header = lines[0].split(",")
    assert "test_acc" in header and "test_loss" in header
    rows = [dict(zip(header, ln.split(","))) for ln in lines[1:]]
    eval_rows = [r for r in rows if r["test_acc"] != ""]
    assert eval_rows, rows
    assert all(np.isfinite(float(r["test_acc"])) for r in eval_rows)


def test_evaluate_compiles_once():
    """Regression: evaluate() used to call jax.jit(model.loss) per eval
    round — a fresh wrapper (bound methods compare unequal), so every
    eval round recompiled. The hoisted eval fn must trace exactly once
    across eval rounds (the loss body sits inside a lax.scan over the
    test split, so one trace total)."""
    from repro.data import make_task
    from repro.launch.train import evaluate, make_eval_fn
    cfg, model, params = build_tiny("dense")
    task = make_task("class_lm", vocab_size=cfg.vocab_size, seq_len=16,
                     num_samples=128, num_clients=2, dirichlet_alpha=0.6,
                     seed=0)
    traces = {"n": 0}

    def counting_loss(p, b):
        traces["n"] += 1
        return model.loss(p, b)

    eval_fn = make_eval_fn(model, loss_fn=counting_loss)
    r1 = evaluate(model, params, task, batch_size=32, eval_fn=eval_fn)
    r2 = evaluate(model, params, task, batch_size=32, eval_fn=eval_fn)
    assert traces["n"] == 1, traces
    assert np.isfinite(r1["test_loss"]) and r1 == r2


def test_csvlogger_preserves_commas_across_rewrite(tmp_path):
    """Values containing commas must survive the header-widening rewrite
    (rows are re-parsed from disk with the csv module, not split(','))."""
    import csv

    from repro.metrics import CSVLogger
    path = str(tmp_path / "log.csv")
    lg = CSVLogger(path)
    lg.log({"note": "a,b"})
    lg.log({"note": "x", "loss": 1.0})
    lg.close()
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    assert rows[0]["note"] == "a,b" and rows[0]["loss"] == ""
    assert rows[1]["note"] == "x" and rows[1]["loss"] == "1.0"
