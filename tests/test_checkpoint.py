"""Checkpointer: atomic writes, crash-safety of the ``latest`` pointer,
and driver-level resume parity (train R == train R/2 + resume R/2,
pipelined engine, both layouts).

Set ``REPRO_LAYOUT=client_parallel|client_sequential`` to pin the layout
matrix to one entry (the CI layout matrix does)."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.checkpoint import checkpointer as _ckpt

_ENV_LAYOUT = os.environ.get("REPRO_LAYOUT")
LAYOUTS = ([_ENV_LAYOUT] if _ENV_LAYOUT
           else ["client_parallel", "client_sequential"])


def _tree(scale=1.0):
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) * scale,
            "b": jnp.ones((4,), jnp.float32) * scale}


# ------------------------------------------------------------- atomicity

def test_save_restore_roundtrip_and_no_temp_files(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, params=_tree(), server_state={"t": jnp.zeros(())})
    params, state, step = restore_checkpoint(
        d, params_template=_tree(), state_template={"t": jnp.zeros(())})
    assert step == 3
    for a, b in zip(np.asarray(params["w"]).ravel(),
                    np.asarray(_tree()["w"]).ravel()):
        assert a == b
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")], \
        "temp files must not survive a successful save"


def test_mid_write_failure_preserves_previous_checkpoint(tmp_path,
                                                         monkeypatch):
    """A kill mid-.npz-write must leave the PREVIOUS complete checkpoint
    in place with ``latest`` still pointing at it — no truncated payload
    behind the pointer, no lingering temp files."""
    d = str(tmp_path)
    save_checkpoint(d, 1, params=_tree(1.0))

    real_savez = np.savez

    def dying_savez(f, **arrays):
        f.write(b"partial garbage")          # half-written payload
        raise KeyboardInterrupt("preempted")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(d, 2, params=_tree(2.0))
    monkeypatch.setattr(np, "savez", real_savez)

    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    assert not os.path.exists(os.path.join(d, "ckpt_00000002.npz"))
    with open(os.path.join(d, "latest")) as f:
        assert f.read().strip() == "ckpt_00000001"
    params, _, step = restore_checkpoint(d, params_template=_tree())
    assert step == 1 and float(params["w"][1, 2]) == 5.0


def test_latest_pointer_replaced_after_payload(tmp_path, monkeypatch):
    """If the manifest write dies, ``latest`` must still name the old
    complete checkpoint (pointer is replaced LAST)."""
    d = str(tmp_path)
    save_checkpoint(d, 1, params=_tree(1.0))
    original = _ckpt._atomic_write
    calls = {"n": 0}

    def dying_on_json(path, write_fn):
        if path.endswith(".json"):
            calls["n"] += 1
            raise RuntimeError("disk full")
        return original(path, write_fn)

    monkeypatch.setattr(_ckpt, "_atomic_write", dying_on_json)
    with pytest.raises(RuntimeError, match="disk full"):
        save_checkpoint(d, 2, params=_tree(2.0))
    assert calls["n"] == 1
    with open(os.path.join(d, "latest")) as f:
        assert f.read().strip() == "ckpt_00000001"


def test_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, params=_tree())
    bad = {"w": jnp.zeros((3, 3)), "b": jnp.zeros((4,))}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(d, params_template=bad)


# ------------------------------------------------------------- integrity

def _corrupt(path, mode):
    """Bit-flip one payload byte, or truncate the file, in place."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if mode == "bitflip":
        data[len(data) // 2] ^= 0x40
    else:
        data = data[: len(data) // 2]
    with open(path, "wb") as f:
        f.write(bytes(data))


@pytest.mark.parametrize("mode", ["bitflip", "truncate"])
def test_corrupt_latest_falls_back_to_newest_valid(tmp_path, mode):
    """A corrupted newest payload (bit rot or truncation behind the
    atomic-write protocol's back) must degrade to the previous save —
    with a warning — not crash the restore or return garbage."""
    d = str(tmp_path)
    save_checkpoint(d, 1, params=_tree(1.0))
    save_checkpoint(d, 2, params=_tree(2.0))
    _corrupt(os.path.join(d, "ckpt_00000002.npz"), mode)
    with pytest.warns(UserWarning, match="skipping corrupt"):
        params, _, step = restore_checkpoint(d, params_template=_tree())
    assert step == 1
    assert float(params["w"][1, 2]) == 5.0  # the scale-1.0 payload


def test_corrupt_explicit_step_raises(tmp_path):
    """Asking for a specific step means those exact bytes: a checksum
    mismatch is an error, never a silent fallback."""
    from repro.checkpoint import CorruptCheckpointError
    d = str(tmp_path)
    save_checkpoint(d, 3, params=_tree())
    _corrupt(os.path.join(d, "ckpt_00000003.npz"), "bitflip")
    with pytest.raises(CorruptCheckpointError, match="checksum"):
        restore_checkpoint(d, params_template=_tree(), step=3)


def test_every_checkpoint_corrupt_is_actionable(tmp_path):
    from repro.checkpoint import CorruptCheckpointError
    d = str(tmp_path)
    save_checkpoint(d, 1, params=_tree())
    _corrupt(os.path.join(d, "ckpt_00000001.npz"), "truncate")
    with pytest.warns(UserWarning):
        with pytest.raises(CorruptCheckpointError,
                           match="failed verification"):
            restore_checkpoint(d, params_template=_tree())


def test_legacy_manifest_without_checksum_still_restores(tmp_path):
    """Pre-checksum checkpoints (no ``npz_sha256`` key) restore
    unverified — upgrading the code must not orphan old saves."""
    import json
    d = str(tmp_path)
    save_checkpoint(d, 4, params=_tree())
    mpath = os.path.join(d, "ckpt_00000004.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["npz_sha256"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    _, _, step = restore_checkpoint(d, params_template=_tree())
    assert step == 4


# --------------------------------------------------------- driver resume

def _preempt_at(src_dir, dst_dir, step):
    """Copy a finished run's checkpoint dir, then trim it back to the
    ``step`` checkpoint — exactly what a run killed right after saving
    round ``step`` would have left behind (later payloads gone, ``latest``
    pointing at the survivor)."""
    import shutil
    shutil.copytree(src_dir, dst_dir)
    for f in os.listdir(dst_dir):
        if f.startswith("ckpt_") and f not in (
                f"ckpt_{step:08d}.npz", f"ckpt_{step:08d}.json"):
            os.remove(os.path.join(dst_dir, f))
    with open(os.path.join(dst_dir, "latest"), "w") as f:
        f.write(f"ckpt_{step:08d}")


@pytest.mark.parametrize("layout", LAYOUTS)
def test_resume_parity_pipelined(layout, tmp_path):
    """train 6r must equal train 3r + resume 3r — same per-round losses,
    same final eval metrics, BIT-identical final checkpoint — through
    the pipelined engine (prefetch + multi-round fusion). Preemption is
    simulated by trimming the finished run's checkpoint dir back to the
    round-3 save (the interrupted run's cosine horizon and data stream
    are those of the FULL run, which a fresh rounds=3 run would not
    reproduce)."""
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", rounds=6,
              num_clients=4, clients_per_round=2, local_steps=2,
              batch_size=4, eval_every=3, seed=3, layout=layout,
              prefetch_depth=2, rounds_per_call=3, ckpt_every=3)
    d_full, d_res = str(tmp_path / "full"), str(tmp_path / "resumed")

    h_full = run_training(**kw, ckpt_dir=d_full)
    _preempt_at(d_full, d_res, step=3)
    h_res = run_training(**kw, ckpt_dir=d_res, resume=True)

    assert h_res["engine"]["start_round"] == 3
    assert h_res["train_loss"] == h_full["train_loss"][3:]
    assert h_res["test_acc"][-1] == h_full["test_acc"][-1]
    assert h_res["test_loss"][-1] == h_full["test_loss"][-1]

    a = dict(np.load(os.path.join(d_full, "ckpt_00000006.npz")))
    b = dict(np.load(os.path.join(d_res, "ckpt_00000006.npz")))
    assert a.keys() == b.keys()
    for k in a:
        assert a[k].tobytes() == b[k].tobytes(), k


def test_resume_misaligned_block_plan_is_actionable(tmp_path):
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", num_clients=4,
              clients_per_round=2, local_steps=2, batch_size=4, seed=3)
    d = str(tmp_path)
    run_training(**kw, rounds=2, eval_every=2, ckpt_dir=d, ckpt_every=2)
    with pytest.raises(ValueError, match="block plan"):
        run_training(**kw, rounds=6, eval_every=5, ckpt_dir=d,
                     resume=True, rounds_per_call=5)


def test_resume_of_completed_run_is_a_clean_noop(tmp_path):
    """Re-running the finished command with --resume (the supervisor
    retry-until-success pattern) must return an empty-but-well-formed
    history, not crash."""
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", rounds=1,
              num_clients=4, clients_per_round=2, local_steps=1,
              batch_size=4, eval_every=1, seed=3, ckpt_dir=str(tmp_path),
              ckpt_every=1)
    run_training(**kw)
    h = run_training(**kw, resume=True)
    assert h["engine"]["start_round"] == 1
    assert h["train_loss"] == [] and h["test_acc"] == []


def test_unreachable_ckpt_every_is_actionable(tmp_path):
    """A ckpt_every that never lands on a block boundary would silently
    write no checkpoints for the whole sweep — it must fail at launch."""
    from repro.launch.train import run_training
    with pytest.raises(ValueError, match="block boundaries"):
        run_training(arch="vit-tiny-fl", rounds=6, num_clients=4,
                     clients_per_round=2, local_steps=1, batch_size=4,
                     eval_every=5, rounds_per_call=5,
                     ckpt_dir=str(tmp_path), ckpt_every=3)


def test_resume_with_dp_continues_the_budget(tmp_path):
    """A resumed DP run charges the completed rounds to the accountant:
    its final epsilon equals the uninterrupted run's."""
    from repro.launch.train import run_training
    kw = dict(arch="vit-tiny-fl", algorithm="fedadamw", rounds=4,
              num_clients=4, clients_per_round=2, local_steps=2,
              batch_size=4, eval_every=2, seed=3, ckpt_every=2,
              dp_clip=0.5, dp_noise_multiplier=1.0)
    d_full, d_res = str(tmp_path / "a"), str(tmp_path / "b")
    h_full = run_training(**kw, ckpt_dir=d_full)
    _preempt_at(d_full, d_res, step=2)
    h_res = run_training(**kw, ckpt_dir=d_res, resume=True)
    assert h_res["epsilon"][-1] == h_full["epsilon"][-1]
    assert h_res["train_loss"] == h_full["train_loss"][2:]
