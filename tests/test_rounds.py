"""Round-engine tests: layout equivalence, micro-batching, Pallas path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_tiny
from repro.config import FedConfig
from repro.core import build_fed_state, make_round_fn


def _round_once(model, cfg, fed, batch_leaves):
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = jax.jit(make_round_fn(model, fed, specs, alg=alg))
    cids = jnp.arange(batch_leaves["tokens"].shape[0], dtype=jnp.int32)
    return round_fn(params, sstate, batch_leaves, cids, jnp.asarray(0))


def _batch(cfg, s, k, b, seq, seed=0, micro=None):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (s, k, b, seq))
    batch = {"tokens": toks.astype(np.int32),
             "labels": np.roll(toks, -1, -1).astype(np.int32)}
    if micro:
        batch = {kk: v.reshape(s, k, micro, b // micro, seq)
                 for kk, v in batch.items()}
    return {kk: jnp.asarray(v) for kk, v in batch.items()}


def test_parallel_equals_sequential():
    """The two placement layouts implement the same algorithm: identical
    batches must give identical new parameters."""
    cfg, model, _ = build_tiny("dense")
    base = FedConfig(algorithm="fedadamw", num_clients=4,
                     clients_per_round=4, local_steps=3, lr=1e-3,
                     sequential_clients=4)
    batch = _batch(cfg, 4, 3, 4, 16)
    p_par, _, m_par = _round_once(
        model, cfg, dataclasses.replace(base, layout="client_parallel"),
        batch)
    p_seq, _, m_seq = _round_once(
        model, cfg, dataclasses.replace(base, layout="client_sequential"),
        batch)
    np.testing.assert_allclose(float(m_par["loss_mean"]),
                               float(m_seq["loss_mean"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_par), jax.tree.leaves(p_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_microbatching_is_exact():
    """grad accumulation over micro-batches == one big batch gradient."""
    cfg, model, _ = build_tiny("dense")
    base = FedConfig(algorithm="fedadamw", num_clients=2,
                     clients_per_round=2, local_steps=2, lr=1e-3)
    p1, _, m1 = _round_once(model, cfg, base, _batch(cfg, 2, 2, 8, 16))
    fed_mb = dataclasses.replace(base, grad_microbatches=4)
    p2, _, m2 = _round_once(model, cfg, fed_mb,
                            _batch(cfg, 2, 2, 8, 16, micro=4))
    np.testing.assert_allclose(float(m1["loss_mean"]),
                               float(m2["loss_mean"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_pallas_update_matches_jnp():
    cfg, model, _ = build_tiny("dense")
    base = FedConfig(algorithm="fedadamw", num_clients=2,
                     clients_per_round=2, local_steps=2, lr=1e-3)
    batch = _batch(cfg, 2, 2, 4, 16)
    p1, _, _ = _round_once(model, cfg, base, batch)
    p2, _, _ = _round_once(
        model, cfg, dataclasses.replace(base, use_pallas_update=True), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-6)


def test_cosine_schedule_endpoints():
    from repro.core import cosine_lr_scale
    assert float(cosine_lr_scale(jnp.asarray(0), 100)) == pytest.approx(1.0)
    assert float(cosine_lr_scale(jnp.asarray(100), 100)) == pytest.approx(
        0.0, abs=1e-6)


@pytest.mark.parametrize("family", ["moe", "ssm", "hybrid", "vlm", "audio"])
def test_fed_round_every_family(family):
    """FedAdamW must run end-to-end on every architecture family (the
    technique is an optimizer: §Arch-applicability)."""
    cfg, model, _ = build_tiny(family)
    fed = FedConfig(algorithm="fedadamw", num_clients=2,
                    clients_per_round=2, local_steps=2, lr=1e-3)
    batch = _batch(cfg, 2, 2, 2, 16)
    if family in ("vlm", "audio"):
        rng = np.random.default_rng(3)
        batch["frontend_feats"] = jnp.asarray(rng.normal(size=(
            2, 2, 2, cfg.frontend_tokens_per_sample,
            cfg.frontend_embed_dim)), jnp.float32)
    p, sstate, m = _round_once(model, cfg, fed, batch)
    assert np.isfinite(float(m["loss_mean"]))
    for leaf in jax.tree.leaves(p):
        assert bool(jnp.all(jnp.isfinite(leaf)))
