"""Serving layer: prefill + single-token decode steps for batched requests.

The assigned ``decode_32k`` / ``long_500k`` input shapes lower ``serve_step``
— ONE new token against a KV cache (or SSM state) of ``seq_len`` — rather
than ``train_step``. Serving is non-federated: it runs plain sharded
inference with the FL-trained weights (the paper never serves models; this
exists because the assigned shapes require it — DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def make_prefill_fn(model):
    """prefill(params, tokens) -> logits for the full prompt."""

    def prefill(params, batch: Dict[str, Array]):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def make_serve_step(model, *, greedy: bool = True, temperature: float = 1.0):
    """serve_step(params, tokens, cache[, memory]) -> (next_tokens, logits, cache).

    tokens: (B, 1) int32 — the most recent token per request.
    cache: per-layer KV cache / SSM state as built by ``model.init_cache``.
    """

    def serve_step(params, tokens: Array, cache: Any, *,
                   memory: Optional[Array] = None,
                   rng: Optional[jax.Array] = None
                   ) -> Tuple[Array, Array, Any]:
        logits, cache = model.decode_step(params, tokens, cache,
                                          memory=memory)
        last = logits[:, -1, :]
        if greedy or rng is None:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                rng, last.astype(jnp.float32) / temperature).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve_step


def generate(model, params, prompt: Array, max_new_tokens: int, *,
             max_len: Optional[int] = None,
             memory: Optional[Array] = None,
             rng: Optional[jax.Array] = None) -> Array:
    """Simple autoregressive generation loop (prefill token-by-token, then
    decode) used by the examples and integration tests; small-scale only."""
    b, prompt_len = prompt.shape
    max_len = max_len or (prompt_len + max_new_tokens)
    cache = model.init_cache(b, max_len)
    step = make_serve_step(model, greedy=rng is None)

    # prefill by stepping through the prompt (keeps one code path; the
    # production prefill shape uses model.forward instead)
    tok = prompt[:, :1]
    for i in range(prompt_len):
        nxt, _, cache = step(params, prompt[:, i:i + 1], cache, memory=memory)
    out = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = step(params, out[-1], cache, memory=memory)
        out.append(nxt)
    return jnp.concatenate(out, axis=1)
