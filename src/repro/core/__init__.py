"""FedAdamW core: Hessian-block partitioning, block-mean aggregation,
the FedAdamW algorithm and its baselines, and the federated round engine."""
from repro.core.partition import (
    LeafBlockSpec,
    build_block_specs,
    block_means,
    broadcast_means,
    tree_block_means,
    tree_broadcast_means,
    total_blocks,
)
from repro.core.fedadamw import get_algorithm, FedAlgorithm
from repro.core.rounds import (
    make_round_fn,
    make_multi_round_fn,
    make_local_phase,
    init_server_state,
    build_fed_state,
    cosine_lr_scale,
    upload_shape_spec,
)

__all__ = [
    "LeafBlockSpec", "build_block_specs", "block_means", "broadcast_means",
    "tree_block_means", "tree_broadcast_means", "total_blocks",
    "get_algorithm", "FedAlgorithm",
    "make_round_fn", "make_multi_round_fn", "make_local_phase",
    "init_server_state",
    "build_fed_state", "cosine_lr_scale", "upload_shape_spec",
]
