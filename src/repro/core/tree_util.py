"""Small pytree arithmetic helpers used by the federated optimizers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_mean_leading(a):
    """Mean over the leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: x.mean(axis=0), a)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(leaves))


def tree_sq_norm(a):
    return tree_dot(a, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def global_norm(a):
    return jnp.sqrt(tree_sq_norm(a))
