"""Beyond-paper extensions (the paper's conclusion explicitly points at
LAMB/Lion: "We believe FedAdamW opens a new direction for adapting modern
optimizers to FL such as LAMB or Lion").

``fedlamb``  FedAdamW's machinery (block-mean v aggregation, global-update
             correction, decoupled decay) with a LAMB layer-wise trust
             ratio on the final step: x <- x - eta * r * u with
             r = ||x|| / ||u|| per tensor.
``fedlion``  Lion as the local optimizer: sign updates, one momentum, no
             second moment — so there is nothing to block-mean-aggregate;
             it keeps the Delta_G correction and decoupled decay. Its
             upload is delta only (1x communication).

The int8 upload quantization that used to live here moved into the
communication layer (:mod:`repro.comm`): ``fake_quant_int8``,
``quantized`` and ``wire_bytes`` remain as deprecated aliases so existing
imports and the ``"+int8"`` algorithm-name suffix keep working.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core.fedadamw import (FedAlgorithm, _adamw_moments,
                                 _bias_corrections, _delta_g_from_mean_delta,
                                 _fedadamw_init_client, _fedadamw_init_server,
                                 _fedadamw_server_update, _fedadamw_upload,
                                 _plain_delta_server)
from repro.core.tree_util import tree_zeros_like


# ---------------------------------------------------------------------------
# FedLAMB
# ---------------------------------------------------------------------------

def _lamb_local_step(params, grads, cstate, sstate, fed: FedConfig,
                     lr_scale):
    k = cstate["k"] + 1
    t = sstate["t"] + k
    c1, c2 = _bias_corrections(k, t, fed)
    m, v = _adamw_moments(grads, cstate["m"], cstate["v"], fed)
    lr = fed.lr * lr_scale

    def upd(x, mi, vi, dg):
        u = (mi / c1) / (jnp.sqrt(vi / c2) + fed.eps) \
            + fed.alpha * dg.astype(jnp.float32) \
            + fed.weight_decay * x.astype(jnp.float32)
        # LAMB trust ratio, per tensor: ||x|| / ||u|| clipped to [0, 10]
        xn = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
        un = jnp.sqrt(jnp.sum(jnp.square(u)))
        r = jnp.where((xn > 0) & (un > 0),
                      jnp.clip(xn / jnp.maximum(un, 1e-12), 0.0, 10.0), 1.0)
        return (x.astype(jnp.float32) - lr * r * u).astype(x.dtype)

    params = jax.tree.map(upd, params, m, v, sstate["delta_g"])
    return params, {"m": m, "v": v, "k": k}


def fedlamb() -> FedAlgorithm:
    return FedAlgorithm(
        "fedlamb", _fedadamw_init_server, _fedadamw_init_client,
        _lamb_local_step, _fedadamw_upload, _fedadamw_server_update)


# ---------------------------------------------------------------------------
# FedLion
# ---------------------------------------------------------------------------

def fedlion() -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"delta_g": tree_zeros_like(params, jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def init_client(params, sstate, fed, specs=None):
        return {"m": tree_zeros_like(params, jnp.float32),
                "k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        b1, b2 = 0.9, 0.99  # Lion's standard betas
        lr = fed.lr * lr_scale

        def upd(x, mi, g, dg):
            g32 = g.astype(jnp.float32)
            step = jnp.sign(b1 * mi + (1 - b1) * g32) \
                + fed.alpha * dg.astype(jnp.float32) \
                + fed.weight_decay * x.astype(jnp.float32)
            return (x.astype(jnp.float32) - lr * step).astype(x.dtype)

        new_params = jax.tree.map(upd, params, cstate["m"], grads,
                                  sstate["delta_g"])
        m = jax.tree.map(
            lambda mi, g: b2 * mi + (1 - b2) * g.astype(jnp.float32),
            cstate["m"], grads)
        return new_params, {"m": m, "k": cstate["k"] + 1}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta}

    def server_update(params, sstate, mean_up, specs, fed):
        new_params = _plain_delta_server(params, mean_up["delta"], fed)
        return new_params, {
            "delta_g": _delta_g_from_mean_delta(mean_up["delta"], fed),
            "t": sstate["t"] + fed.local_steps}

    return FedAlgorithm("fedlion", init_server, init_client, local_step,
                        upload, server_update)


# ---------------------------------------------------------------------------
# int8 upload quantization — DEPRECATED, now repro.comm (kept as aliases)
# ---------------------------------------------------------------------------

def fake_quant_int8(x: jax.Array) -> jax.Array:
    """Deprecated: ``decode(encode(x))`` of the ``int8`` codec in
    :mod:`repro.comm.codecs`."""
    from repro.comm import get_codec
    codec = get_codec("int8")
    # ra: allow[RA101] deprecated shim: keyless back-compat signature
    out = codec.decode(codec.encode(x, jax.random.PRNGKey(0)))
    return out.astype(x.dtype)


def quantized(alg: FedAlgorithm) -> FedAlgorithm:
    """Deprecated: ``repro.comm.compressed(alg, get_codec("int8"))`` —
    preserved with the original semantics (no error feedback)."""
    from repro.comm import compressed, get_codec
    return compressed(alg, get_codec("int8"), error_feedback=False)


def wire_bytes(upload_tree, *, delta_int8: bool = False) -> int:
    """Deprecated: :func:`repro.comm.upload_wire_bytes` with a codec."""
    from repro.comm import get_codec, upload_wire_bytes
    return upload_wire_bytes(upload_tree,
                             get_codec("int8") if delta_int8 else None)
