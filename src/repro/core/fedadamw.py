"""FedAdamW (paper Algorithm 2) and the seven baselines it is compared to.

Every algorithm is expressed through one uniform interface so the round
engine (:mod:`repro.core.rounds`) can run any of them under either FL
placement layout:

    init_server(params, specs, fed)                  -> server_state
    init_client(params, server_state, fed)           -> client_state
    local_step(params, grads, cstate, sstate, fed,
               lr_scale)                             -> (params, cstate)
    upload(delta, cstate, specs, fed)                -> upload pytree
    commit(sstate, upload, client_ids, specs, fed)   -> (sstate, upload)
        [optional: per-client server-state write-back, pre-aggregation]
    server_update(params, sstate, mean_upload,
                  specs, fed)                        -> (params, sstate)

Algorithms with per-client server state (SCAFFOLD, error feedback) keep
it in a ``repro.state.ClientStateStore`` table and expose ``commit``;
the round engine drives them identically under both placement layouts.

Conventions
-----------
* ``delta`` is the *raw* parameter displacement ``x_i^{r,K} - x_i^{r,0}``
  (paper Algorithms 1-3, the quantity communicated to the server).
* The server applies ``x^{r+1} = x^r + gamma * mean_i(delta_i)`` — with the
  paper's gamma = 1.0 this is exactly FedAvg-style delta averaging
  (Algorithm 1 line 15 / Algorithm 2 server block).
* ``mean_upload`` is whatever cross-client reduction the round engine
  performed: the uniform mean of the paper's algorithms, or — under a
  participation scenario with ``FedConfig.agg_weighting`` set — a
  weighted mean with host-normalized weights (sum 1) over delta, v̄ and
  every other upload entry alike (``repro.core.rounds._weighted_mean``).
  ``server_update`` never needs to know which; its estimator contract
  (aggregate ≈ cohort expectation) is unchanged.
* The broadcast global-update estimate is
  ``Delta_G^r = -1/(K*eta) * mean_i(delta_i)`` (Algorithm 2/3), i.e. an
  *ascent* direction estimate; the local update *adds* ``alpha * Delta_G``
  inside the step so the client descends along the global direction.
* Weight decay: the paper writes ``- eta*(... - lambda*x)`` which would
  *grow* the weights; every AdamW implementation (and the paper's released
  code) decays them. We implement standard decoupled decay
  ``x <- x - eta*(m_hat/(sqrt(v_hat)+eps) + alpha*Delta_G + lambda*x)``
  and record the sign typo in DESIGN.md.
* Bias correction follows Algorithm 2 exactly: ``m_hat = m/(1-beta1^k)``
  with the *local* step index k (m is zeroed each round), and
  ``v_hat = v/(1-beta2^t)`` with the *global* time step t carried across
  rounds (v is warm-started from the aggregated block means).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import partition
from repro.core.tree_util import tree_scale, tree_zeros_like

Array = jax.Array
Tree = Any


@dataclasses.dataclass(frozen=True)
class FedAlgorithm:
    name: str
    init_server: Callable[..., Dict[str, Tree]]
    init_client: Callable[..., Dict[str, Tree]]
    local_step: Callable[..., tuple]
    upload: Callable[..., Dict[str, Tree]]
    server_update: Callable[..., tuple]
    # True when the algorithm keeps per-client server state (a
    # repro.state.ClientStateStore table): the round engine then threads
    # the sampled client ids to init_client and calls ``commit`` — in
    # BOTH placement layouts.
    needs_client_ids: bool = False
    # commit(sstate, upload, client_ids, specs, fed) -> (sstate, upload):
    # write the sampled clients' new per-client rows into the server-state
    # tables and reduce/drop per-client-only upload entries, BEFORE the
    # cross-client aggregation. ``client_ids``/``upload`` are the stacked
    # (S,)-leading round values under client_parallel, or one scalar id /
    # one client's upload per call inside the client_sequential scan.
    commit: Optional[Callable[..., tuple]] = None


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _adamw_moments(grads, m, v, fed: FedConfig):
    b1, b2 = fed.beta1, fed.beta2
    m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(mi.dtype), m, grads)
    v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2)
                     * jnp.square(g.astype(vi.dtype)), v, grads)
    return m, v


def _bias_corrections(k: Array, t: Array, fed: FedConfig):
    kf = k.astype(jnp.float32)
    tf = t.astype(jnp.float32)
    c1 = 1.0 - jnp.power(fed.beta1, kf)
    c2 = 1.0 - jnp.power(fed.beta2, tf if fed.global_t_bias_correction else kf)
    return c1, c2


def _fused_or_jnp_adamw_apply(params, m, v, delta_g, fed: FedConfig, *,
                              c1: Array, c2: Array, lr: Array, alpha: float,
                              lam: float):
    """x <- x - lr*( (m/c1)/(sqrt(v/c2)+eps) + alpha*Delta_G + lam*x )."""
    if fed.use_pallas_update:
        from repro.kernels.fused_adamw import ops as fused_ops
        return fused_ops.tree_fused_adamw_apply(
            params, m, v, delta_g, c1=c1, c2=c2, lr=lr, alpha=alpha,
            lam=lam, eps=fed.eps)

    def upd(x, mi, vi, dg):
        mhat = mi / c1
        vhat = vi / c2
        step = mhat / (jnp.sqrt(vhat) + fed.eps)
        step = step + alpha * dg.astype(step.dtype) + lam * x.astype(step.dtype)
        return (x.astype(jnp.float32) - lr * step).astype(x.dtype)

    return jax.tree.map(upd, params, m, v, delta_g)


def _plain_delta_server(params, mean_delta, fed: FedConfig):
    return jax.tree.map(
        lambda x, d: (x.astype(jnp.float32)
                      + fed.server_lr * d.astype(jnp.float32)).astype(x.dtype),
        params, mean_delta)


def _delta_g_from_mean_delta(mean_delta, fed: FedConfig):
    # NOTE: normalizes by the NOMINAL K. Under a straggler scenario the
    # aggregated delta reflects K_i <= K applied steps per client, so
    # Delta_G is attenuated by ~mean(K_i)/K; agg_weighting="inv_steps"
    # is the built-in counter-measure (docs/scenarios.md §Stragglers).
    scale = -1.0 / (fed.local_steps * fed.lr)
    return tree_scale(mean_delta, scale)


# ---------------------------------------------------------------------------
# FedAdamW (Algorithm 2) — ours
# ---------------------------------------------------------------------------

def _fedadamw_init_server(params, specs, fed: FedConfig):
    state = {
        "delta_g": tree_zeros_like(params, jnp.float32),
        "t": jnp.zeros((), jnp.int32),
    }
    if fed.v_aggregation == "mean_v":
        state["v_bar"] = jax.tree.map(
            lambda s: jnp.zeros((s.n_blocks,), jnp.float32), specs,
            is_leaf=lambda x: isinstance(x, partition.LeafBlockSpec))
    elif fed.v_aggregation in ("full_v", "full_vm"):
        state["v_bar"] = tree_zeros_like(params, jnp.float32)
        if fed.v_aggregation == "full_vm":
            state["m_bar"] = tree_zeros_like(params, jnp.float32)
    return state


def _fedadamw_init_client(params, sstate, fed: FedConfig, specs=None):
    if fed.v_aggregation == "mean_v":
        v0 = partition.tree_broadcast_means(sstate["v_bar"], specs)
    elif fed.v_aggregation in ("full_v", "full_vm"):
        v0 = sstate["v_bar"]
    else:
        v0 = tree_zeros_like(params, jnp.float32)
    m0 = (sstate["m_bar"] if fed.v_aggregation == "full_vm"
          else tree_zeros_like(params, jnp.float32))
    return {"m": m0, "v": v0, "k": jnp.zeros((), jnp.int32)}


def _fedadamw_local_step(params, grads, cstate, sstate, fed: FedConfig,
                         lr_scale):
    k = cstate["k"] + 1
    t = sstate["t"] + k
    c1, c2 = _bias_corrections(k, t, fed)
    lam = fed.weight_decay
    if not fed.decoupled_wd:
        # ablation A3: Adam-style coupled L2 enters the gradient (and the
        # moment estimates) instead of the decoupled decay term
        grads = jax.tree.map(lambda g, x: g + lam * x.astype(g.dtype),
                             grads, params)
        lam = 0.0
    if fed.use_pallas_update:
        # fully fused path: moments + step in one VMEM pass (DESIGN.md §5)
        from repro.kernels.fused_adamw import ops as fused_ops
        params, m, v = fused_ops.tree_fused_adamw_step(
            params, grads, cstate["m"], cstate["v"], sstate["delta_g"],
            beta1=fed.beta1, beta2=fed.beta2, c1=c1, c2=c2,
            lr=fed.lr * lr_scale, alpha=fed.alpha, lam=lam,
            eps=fed.eps)
    else:
        m, v = _adamw_moments(grads, cstate["m"], cstate["v"], fed)
        params = _fused_or_jnp_adamw_apply(
            params, m, v, sstate["delta_g"], fed, c1=c1, c2=c2,
            lr=fed.lr * lr_scale, alpha=fed.alpha, lam=lam)
    return params, {"m": m, "v": v, "k": k}


def _fedadamw_upload(delta, cstate, specs, fed: FedConfig):
    up = {"delta": delta}
    if fed.v_aggregation == "mean_v":
        up["v_mean"] = partition.tree_block_means(cstate["v"], specs)
    elif fed.v_aggregation in ("full_v", "full_vm"):
        up["v_full"] = cstate["v"]
        if fed.v_aggregation == "full_vm":
            up["m_full"] = cstate["m"]
    return up


def _fedadamw_server_update(params, sstate, mean_up, specs, fed: FedConfig):
    new_params = _plain_delta_server(params, mean_up["delta"], fed)
    new_state = dict(sstate)
    new_state["delta_g"] = _delta_g_from_mean_delta(mean_up["delta"], fed)
    new_state["t"] = sstate["t"] + fed.local_steps
    if fed.v_aggregation == "mean_v":
        new_state["v_bar"] = mean_up["v_mean"]
    elif fed.v_aggregation in ("full_v", "full_vm"):
        new_state["v_bar"] = mean_up["v_full"]
        if fed.v_aggregation == "full_vm":
            new_state["m_bar"] = mean_up["m_full"]
    return new_params, new_state


# ---------------------------------------------------------------------------
# Local AdamW / Local Adam (per-round from-scratch moments, no correction)
# ---------------------------------------------------------------------------

def _local_adam_like(name: str, decoupled: bool) -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"t": jnp.zeros((), jnp.int32)}

    def init_client(params, sstate, fed, specs=None):
        return {"m": tree_zeros_like(params, jnp.float32),
                "v": tree_zeros_like(params, jnp.float32),
                "k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        k = cstate["k"] + 1
        lam = fed.weight_decay
        if not decoupled:
            # Adam with coupled L2: decay enters the gradient (and thus m, v)
            grads = jax.tree.map(
                lambda g, x: g + lam * x.astype(g.dtype), grads, params)
        m, v = _adamw_moments(grads, cstate["m"], cstate["v"], fed)
        kf = k.astype(jnp.float32)
        c1 = 1.0 - jnp.power(fed.beta1, kf)
        c2 = 1.0 - jnp.power(fed.beta2, kf)
        zeros = tree_zeros_like(params, jnp.float32)
        params = _fused_or_jnp_adamw_apply(
            params, m, v, zeros, fed, c1=c1, c2=c2, lr=fed.lr * lr_scale,
            alpha=0.0, lam=(lam if decoupled else 0.0))
        return params, {"m": m, "v": v, "k": k}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta}

    def server_update(params, sstate, mean_up, specs, fed):
        return _plain_delta_server(params, mean_up["delta"], fed), sstate

    return FedAlgorithm(name, init_server, init_client, local_step, upload,
                        server_update)


# ---------------------------------------------------------------------------
# FedAvg (Local SGD)
# ---------------------------------------------------------------------------

def _fedavg() -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"t": jnp.zeros((), jnp.int32)}

    def init_client(params, sstate, fed, specs=None):
        return {"k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        lr = fed.lr * lr_scale
        params = jax.tree.map(
            lambda x, g: (x.astype(jnp.float32)
                          - lr * (g.astype(jnp.float32)
                                  + fed.weight_decay * x.astype(jnp.float32))
                          ).astype(x.dtype),
            params, grads)
        return params, {"k": cstate["k"] + 1}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta}

    def server_update(params, sstate, mean_up, specs, fed):
        return _plain_delta_server(params, mean_up["delta"], fed), sstate

    return FedAlgorithm("fedavg", init_server, init_client, local_step,
                        upload, server_update)


# ---------------------------------------------------------------------------
# SCAFFOLD (control variates; Karimireddy et al. 2020, Option II)
# ---------------------------------------------------------------------------

def _scaffold() -> FedAlgorithm:
    from repro.state import store_for

    def init_server(params, specs, fed):
        return {
            "c": tree_zeros_like(params, jnp.float32),
            # per-client control variates, indexed by client id; stored
            # via the client-state store (policy: fed.client_state_policy)
            "c_all": store_for(fed, specs).init(),
        }

    def init_client(params, sstate, fed, specs=None, client_id=None):
        ci = store_for(fed, specs).gather(sstate["c_all"], client_id)
        return {"k": jnp.zeros((), jnp.int32), "c_i": ci,
                "lr_scale": jnp.ones((), jnp.float32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        lr = fed.lr * lr_scale
        params = jax.tree.map(
            lambda x, g, ci, c: (x.astype(jnp.float32)
                                 - lr * (g.astype(jnp.float32) - ci + c
                                         + fed.weight_decay
                                         * x.astype(jnp.float32))
                                 ).astype(x.dtype),
            params, grads, cstate["c_i"], sstate["c"])
        # carry the round's lr scale so upload() divides delta by the
        # eta actually used (cosine decay would otherwise mis-scale c_i+)
        return params, {"k": cstate["k"] + 1, "c_i": cstate["c_i"],
                        "lr_scale": jnp.asarray(lr_scale, jnp.float32)}

    def upload(delta, cstate, specs, fed):
        # Option II: c_i+ = c_i - c + (x^r - x^{r,K})/(K*eta)
        #          = c_i - c - delta/(K*eta)   (computed at the server side
        # needs c, so we upload the -delta/(K*eta) part plus old c_i)
        inv = -1.0 / (fed.local_steps * fed.lr * cstate["lr_scale"])
        return {"delta": delta,
                "c_new_minus_c": jax.tree.map(
                    lambda ci, d: ci + inv * d.astype(jnp.float32),
                    cstate["c_i"], delta)}

    def commit(sstate, up, client_ids, specs, fed):
        # c_i+ = (c_i - delta/(K eta)) - c  for the sampled clients;
        # per-client rows go into the store, the upload keeps only the
        # control-variate *change* (whose cross-client mean the server
        # aggregation consumes) — runs identically with stacked (S,)
        # uploads (client_parallel) or one client at a time (sequential).
        store = store_for(fed, specs)
        c_new = jax.tree.map(lambda u, c: u - c,
                             up["c_new_minus_c"], sstate["c"])
        c_old = store.gather(sstate["c_all"], client_ids)
        new_state = dict(sstate)
        new_state["c_all"] = store.scatter(sstate["c_all"], client_ids, c_new)
        new_up = {k: v for k, v in up.items() if k != "c_new_minus_c"}
        new_up["dc"] = jax.tree.map(jnp.subtract, c_new, c_old)
        return new_state, new_up

    def server_update(params, sstate, mean_up, specs, fed):
        new_params = _plain_delta_server(params, mean_up["delta"], fed)
        new_state = dict(sstate)
        # c += S/N * mean_i(c_i+ - c_i)
        frac = fed.clients_per_round / fed.num_clients
        new_state["c"] = jax.tree.map(
            lambda c, d: c + frac * d, sstate["c"], mean_up["dc"])
        return new_params, new_state

    return FedAlgorithm("scaffold", init_server, init_client, local_step,
                        upload, server_update, needs_client_ids=True,
                        commit=commit)


# ---------------------------------------------------------------------------
# FedCM (client-level momentum; Xu et al. 2021)
# ---------------------------------------------------------------------------

def _fedcm() -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"momentum": tree_zeros_like(params, jnp.float32)}

    def init_client(params, sstate, fed, specs=None):
        return {"k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        lr = fed.lr * lr_scale
        a = fed.fedcm_alpha
        params = jax.tree.map(
            lambda x, g, mo: (x.astype(jnp.float32)
                              - lr * (a * g.astype(jnp.float32)
                                      + (1 - a) * mo
                                      + fed.weight_decay
                                      * x.astype(jnp.float32))
                              ).astype(x.dtype),
            params, grads, sstate["momentum"])
        return params, {"k": cstate["k"] + 1}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta}

    def server_update(params, sstate, mean_up, specs, fed):
        new_params = _plain_delta_server(params, mean_up["delta"], fed)
        # momentum = -mean_delta / (K * eta): descent direction estimate
        mom = tree_scale(mean_up["delta"], -1.0 / (fed.local_steps * fed.lr))
        return new_params, {"momentum": mom}

    return FedAlgorithm("fedcm", init_server, init_client, local_step,
                        upload, server_update)


# ---------------------------------------------------------------------------
# FedAdam (FedOpt: local SGD + server-side Adam; Reddi et al. 2020)
# ---------------------------------------------------------------------------

def _fedadam() -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"server_m": tree_zeros_like(params, jnp.float32),
                "server_v": tree_zeros_like(params, jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def init_client(params, sstate, fed, specs=None):
        return {"k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        lr = fed.lr * lr_scale
        params = jax.tree.map(
            lambda x, g: (x.astype(jnp.float32)
                          - lr * (g.astype(jnp.float32)
                                  + fed.weight_decay * x.astype(jnp.float32))
                          ).astype(x.dtype),
            params, grads)
        return params, {"k": cstate["k"] + 1}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta}

    def server_update(params, sstate, mean_up, specs, fed):
        b1, b2 = fed.beta1, fed.beta2
        # server pseudo-gradient = mean delta (ascent direction toward avg)
        m = jax.tree.map(lambda mo, d: b1 * mo + (1 - b1) * d.astype(jnp.float32),
                         sstate["server_m"], mean_up["delta"])
        v = jax.tree.map(lambda vo, d: b2 * vo + (1 - b2)
                         * jnp.square(d.astype(jnp.float32)),
                         sstate["server_v"], mean_up["delta"])
        t = sstate["t"] + 1
        tf = t.astype(jnp.float32)
        c1 = 1.0 - jnp.power(b1, tf)
        c2 = 1.0 - jnp.power(b2, tf)
        new_params = jax.tree.map(
            lambda x, mi, vi: (x.astype(jnp.float32)
                               + fed.fedadam_server_lr * (mi / c1)
                               / (jnp.sqrt(vi / c2) + fed.fedadam_tau)
                               ).astype(x.dtype),
            params, m, v)
        return new_params, {"server_m": m, "server_v": v, "t": t}

    return FedAlgorithm("fedadam", init_server, init_client, local_step,
                        upload, server_update)


# ---------------------------------------------------------------------------
# FedLADA (local adaptive amended optimizer; Sun et al. 2023)
# Local Adam mixed with the global update estimate; aggregates the FULL
# second moment (the 2x-communication baseline of paper Table 10).
# ---------------------------------------------------------------------------

def _fedlada() -> FedAlgorithm:
    def init_server(params, specs, fed):
        return {"delta_g": tree_zeros_like(params, jnp.float32),
                "v_bar": tree_zeros_like(params, jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def init_client(params, sstate, fed, specs=None):
        return {"m": tree_zeros_like(params, jnp.float32),
                "v": sstate["v_bar"], "k": jnp.zeros((), jnp.int32)}

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        # coupled L2 (Adam-style), amended update:
        #   x <- x - eta*( a * m_hat/(sqrt(v_hat)+eps) + (1-a) * Delta_G )
        lam = fed.weight_decay
        grads = jax.tree.map(lambda g, x: g + lam * x.astype(g.dtype),
                             grads, params)
        k = cstate["k"] + 1
        t = sstate["t"] + k
        m, v = _adamw_moments(grads, cstate["m"], cstate["v"], fed)
        c1, c2 = _bias_corrections(k, t, fed)
        a = fed.fedlada_alpha
        lr = fed.lr * lr_scale

        def upd(x, mi, vi, dg):
            step = a * (mi / c1) / (jnp.sqrt(vi / c2) + fed.eps) + (1 - a) * dg
            return (x.astype(jnp.float32) - lr * step).astype(x.dtype)

        params = jax.tree.map(upd, params, m, v, sstate["delta_g"])
        return params, {"m": m, "v": v, "k": k}

    def upload(delta, cstate, specs, fed):
        return {"delta": delta, "v_full": cstate["v"]}

    def server_update(params, sstate, mean_up, specs, fed):
        new_params = _plain_delta_server(params, mean_up["delta"], fed)
        return new_params, {
            "delta_g": _delta_g_from_mean_delta(mean_up["delta"], fed),
            "v_bar": mean_up["v_full"],
            "t": sstate["t"] + fed.local_steps,
        }

    return FedAlgorithm("fedlada", init_server, init_client, local_step,
                        upload, server_update)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def get_algorithm(fed: FedConfig) -> FedAlgorithm:
    """Resolve ``fed.algorithm``: ``<base>[+<codec>]`` where the suffix is
    an upload codec spec (``fedadamw+int4``, ``fedadamw+topk0.1``, ...)
    handled by the communication layer (repro.comm)."""
    fed.validate()
    from repro.comm import compressed, get_codec, split_algorithm_name
    base_name, codec_spec = split_algorithm_name(fed.algorithm)
    alg = _get_base_algorithm(base_name)
    if codec_spec:
        codec = get_codec(codec_spec, use_pallas=fed.use_pallas_quantpack)
        # error feedback keeps a per-client residual table in the client
        # state store; both placement layouts thread the sampled client
        # ids, so EF is on for every lossy codec unless explicitly
        # disabled (FedConfig.comm_error_feedback=False)
        ef = codec.lossy and fed.comm_error_feedback
        # use_pallas_uploadfuse defers clip/encode/decode to the round
        # engine's one-pass upload megakernel (kernels/uploadfuse)
        alg = compressed(alg, codec, error_feedback=ef,
                         defer=fed.use_pallas_uploadfuse)
    return alg


def _get_base_algorithm(name: str) -> FedAlgorithm:
    if name == "fedadamw":
        return FedAlgorithm(
            "fedadamw", _fedadamw_init_server, _fedadamw_init_client,
            _fedadamw_local_step, _fedadamw_upload, _fedadamw_server_update)
    if name in ("fedavg", "local_sgd"):
        return _fedavg()
    if name == "scaffold":
        return _scaffold()
    if name == "fedcm":
        return _fedcm()
    if name == "fedadam":
        return _fedadam()
    if name == "fedlada":
        return _fedlada()
    if name == "local_adam":
        return _local_adam_like("local_adam", decoupled=False)
    if name == "local_adamw":
        return _local_adam_like("local_adamw", decoupled=True)
    if name == "fedlamb":
        from repro.core.extensions import fedlamb
        return fedlamb()
    if name == "fedlion":
        from repro.core.extensions import fedlion
        return fedlion()
    raise ValueError(name)
