"""Hessian-block partitioning of the parameter tree (paper Appendix D).

FedAdamW communicates only the *block-wise mean* of the second-moment
estimate ``v``. Blocks follow the near-block-diagonal Hessian structure of
Transformers:

  Class 1  query / key                  -> one block per attention head
  Class 2  attn.proj / MLP / experts    -> one block per output neuron (group)
  Class 3  value                        -> one block per output neuron
  Class 4  embedding / output head      -> one block per token (vocab row)
  default  everything else (norms, biases, SSM scalars, conv, router)
           -> per-tensor block; per-head where a head dimension exists
           (Appendix D Algorithm 4: non-Transformer tensors get one block)

A block is described structurally (axes kept vs. averaged) rather than with
element-wise segment ids, so the mean/broadcast are free reshapes even for
70B+ parameter trees: ``block_means`` is ``x.mean(reduce_axes)`` followed by
an optional grouping mean along kept axes; ``broadcast_means`` inverts it.

Grouping implements the paper's ``min_block_size`` heuristic: if a block at
full resolution would hold fewer than ``min_block_size`` elements, adjacent
output neurons are merged (largest divisor of the axis that keeps blocks
above the threshold), and axes are capped so a tensor never exceeds
``max_blocks`` blocks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, ModelConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LeafBlockSpec:
    """Structural description of a leaf's block partition."""

    shape: Tuple[int, ...]
    kept: Tuple[int, ...]      # axes that index blocks (in increasing order)
    groups: Tuple[int, ...]    # number of block groups per kept axis
    cls: str = "default"       # partition class, for reporting

    @property
    def n_blocks(self) -> int:
        return int(np.prod(self.groups)) if self.groups else 1

    @property
    def block_elems(self) -> int:
        total = int(np.prod(self.shape)) if self.shape else 1
        return total // max(self.n_blocks, 1)


def _largest_divisor_at_most(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (>=1)."""
    target = max(1, min(n, target))
    for d in range(target, 0, -1):
        if n % d == 0:
            return d
    return 1


def _make_spec(shape: Tuple[int, ...], kept: Tuple[int, ...], cls: str,
               min_block_size: int, max_blocks: int) -> LeafBlockSpec:
    kept = tuple(sorted(kept))
    total = int(np.prod(shape)) if shape else 1
    if not kept:
        return LeafBlockSpec(shape, (), (), cls)
    # full-resolution blocks: one per index combo of kept axes
    groups = [shape[a] for a in kept]
    elems = total // int(np.prod(groups))
    # merge along the *last* kept axis until block size >= min_block_size
    # and total blocks <= max_blocks
    def n_blocks(gs):
        return int(np.prod(gs))
    i = len(groups) - 1
    while i >= 0:
        cur_elems = total // n_blocks(groups)
        too_small = cur_elems < min_block_size
        too_many = n_blocks(groups) > max_blocks
        if not (too_small or too_many):
            break
        # shrink the group count on axis i
        want = groups[i]
        if too_small:
            factor = math.ceil(min_block_size / cur_elems)
            want = max(1, groups[i] // factor)
        if too_many:
            want = min(want, max(1, groups[i] // math.ceil(
                n_blocks(groups) / max_blocks)))
        new = _largest_divisor_at_most(shape[kept[i]], want)
        if new == groups[i]:
            new = 1  # cannot subdivide further on this axis; collapse it
        groups[i] = new
        if groups[i] == 1:
            i -= 1
        # loop re-checks conditions
    return LeafBlockSpec(shape, kept, tuple(groups), cls)


# ---------------------------------------------------------------------------
# Classification (pattern-matching on parameter-tree key names)
# ---------------------------------------------------------------------------

_QK = ("attn_wq", "attn_wk")
_QK_BIAS = ("attn_bq", "attn_bk")
_VALUE = ("attn_wv", "attn_bv")
_PROJ_OUT_LAST = ("mlp_wi", "mlp_wg", "ssm_in_proj", "moe_router",
                  "frontend_proj", "output_head")
_PROJ_OUT_LAST2 = ("mlp_wo", "attn_wo", "ssm_out_proj")


def _leaf_name(path: Tuple[str, ...]) -> str:
    return path[-1]


def classify_leaf(path: Tuple[str, ...], shape: Tuple[int, ...],
                  stacked: bool, fed: FedConfig) -> LeafBlockSpec:
    """Assign a block spec to one leaf. ``stacked`` marks a leading scan-layer
    axis (always a block axis: blocks never cross layers)."""
    name = _leaf_name(path)
    off = 1 if stacked else 0
    nd = len(shape)

    def spec(kept_rel: Tuple[int, ...], cls: str) -> LeafBlockSpec:
        kept = tuple(a + off for a in kept_rel)
        if stacked:
            kept = (0,) + kept
        s = _make_spec(shape, kept, cls, fed.min_block_size, fed.max_blocks)
        if stacked and 0 not in s.kept:
            # never merge across layers
            s = LeafBlockSpec(shape, (0,) + s.kept[1:], (shape[0],) + s.groups[1:], cls)
        return s

    base_nd = nd - off
    if name.endswith(_QK) and base_nd == 3:        # (D, H, hd) -> per head
        return spec((1,), "qk_per_head")
    if name.endswith(_QK_BIAS) and base_nd == 2:   # (H, hd) -> per head
        return spec((0,), "qk_per_head")
    if name.endswith("attn_wv") and base_nd == 3:  # (D, KV, hd) -> per out-neuron
        return spec((1, 2), "value_per_neuron")
    if name.endswith("attn_bv") and base_nd == 2:
        return spec((0, 1), "value_per_neuron")
    if name.endswith(_PROJ_OUT_LAST2) and base_nd >= 2:
        return spec((base_nd - 1,), "proj_per_neuron")  # output dim last
    if name.endswith(_PROJ_OUT_LAST) and base_nd >= 2:
        return spec((base_nd - 1,), "proj_per_neuron")  # (in, out)
    if name.startswith("moe_exp_") and base_nd == 3:  # (E, in, out)
        return spec((0, 2), "expert_per_neuron")
    if name.startswith("moe_shared_") and base_nd == 2:
        return spec((base_nd - 1,), "proj_per_neuron")
    if name.endswith("embed_tokens") and base_nd == 2:  # (V, D) -> per token
        return spec((0,), "embed_per_token")
    if name in ("ssm_A_log", "ssm_D", "ssm_dt_bias") and base_nd == 1:
        return spec((0,), "ssm_per_head")
    if name.endswith("ssm_conv") and base_nd == 2:  # (w, ch) -> per channel
        return spec((1,), "ssm_per_channel")
    # default: one block for the whole tensor (per layer when stacked)
    return spec((), "default")


# ---------------------------------------------------------------------------
# Tree-level API
# ---------------------------------------------------------------------------

def _is_stacked(path: Tuple[str, ...], cfg: ModelConfig) -> bool:
    """Leaves under a scanned stack carry a leading layer axis."""
    if cfg.family == "hybrid":
        return False  # hybrid stacks are python-unrolled dicts
    return len(path) >= 2 and path[0] in ("layers", "encoder")


def _tree_paths(tree) -> Dict[Tuple[str, ...], Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = tuple(
            k.key if hasattr(k, "key") else str(k.idx) for k in kp)
        out[path] = leaf
    return out


def build_block_specs(params, cfg: ModelConfig, fed: FedConfig):
    """Returns a pytree (same structure as params) of LeafBlockSpec."""
    paths = _tree_paths(params)
    specs = {p: classify_leaf(p, tuple(leaf.shape), _is_stacked(p, cfg), fed)
             for p, leaf in paths.items()}
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    spec_leaves = []
    for kp, _ in flat:
        path = tuple(k.key if hasattr(k, "key") else str(k.idx) for k in kp)
        spec_leaves.append(specs[path])
    return jax.tree_util.tree_unflatten(treedef, spec_leaves)


def total_blocks(spec_tree) -> int:
    return sum(s.n_blocks for s in jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, LeafBlockSpec)))


# ---------------------------------------------------------------------------
# Mean / broadcast for a single leaf
# ---------------------------------------------------------------------------

def block_means(x: Array, spec: LeafBlockSpec) -> Array:
    """(…leaf shape…) -> (n_blocks,) block means (fp32)."""
    x = x.astype(jnp.float32)
    reduce_axes = tuple(a for a in range(x.ndim) if a not in spec.kept)
    m = x.mean(axis=reduce_axes) if reduce_axes else x
    if not spec.kept:
        return m.reshape(1)
    # group each kept axis: (d,) -> (g, d//g) and mean the inner part
    new_shape = []
    for g, d in zip(spec.groups, m.shape):
        new_shape += [g, d // g]
    m = m.reshape(new_shape)
    inner = tuple(range(1, 2 * len(spec.groups), 2))
    m = m.mean(axis=inner)
    return m.reshape(-1)


def broadcast_means(means: Array, spec: LeafBlockSpec) -> Array:
    """(n_blocks,) -> full leaf shape (fp32), inverse of block_means."""
    if not spec.kept:
        return jnp.broadcast_to(means.reshape(()), spec.shape)
    m = means.reshape(spec.groups)
    # expand each grouped axis back to full dim
    for i, a in enumerate(spec.kept):
        d = spec.shape[a]
        g = spec.groups[i]
        m = jnp.repeat(m, d // g, axis=i) if g != d else m
    # m now has shape (shape[kept0], shape[kept1], ...); insert singleton
    # dims for the reduced axes and broadcast to the full leaf shape
    out_shape = spec.shape
    view_shape = [out_shape[a] if a in spec.kept else 1 for a in range(len(out_shape))]
    m = m.reshape(view_shape)
    return jnp.broadcast_to(m, out_shape)


def tree_block_means(tree, spec_tree):
    return jax.tree.map(
        lambda x, s: block_means(x, s), tree, spec_tree,
        is_leaf=lambda x: isinstance(x, LeafBlockSpec))


def tree_broadcast_means(means_tree, spec_tree):
    return jax.tree.map(
        lambda m, s: broadcast_means(m, s), means_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, LeafBlockSpec))


def partition_report(spec_tree) -> str:
    """Human-readable summary: class -> (#tensors, #blocks)."""
    agg: Dict[str, list] = {}
    for s in jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, LeafBlockSpec)):
        agg.setdefault(s.cls, [0, 0])
        agg[s.cls][0] += 1
        agg[s.cls][1] += s.n_blocks
    lines = [f"{k:20s} tensors={v[0]:5d} blocks={v[1]:9d}"
             for k, v in sorted(agg.items())]
    return "\n".join(lines)
