"""Federated round engine: one jittable ``round_fn`` per (model, algorithm).

A *round* (paper Algorithms 1-3) is: broadcast global params -> each of the
S sampled clients runs K local optimizer steps on its own data -> clients
upload (delta, aggregation payload) -> server averages and updates.

Two placement layouts (DESIGN.md §2):

``client_parallel``
    The S clients are vmapped over a leading axis of the per-round batch
    tensor; under pjit that axis is sharded over the (``pod``, ``data``)
    mesh axes so each client trains on its own mesh slice, and the
    ``mean`` over the client axis lowers to the cross-client all-reduce —
    the "server" is the collective itself.

``client_sequential``
    One client at a time occupies the whole mesh (params + optimizer state
    FSDPxTP sharded over *all* axes); ``lax.scan`` iterates the
    ``(batches, client_ids)`` pairs of the round and accumulates upload
    sums online, so peak memory never holds more than one client's
    optimizer state. Required for the >13B architectures.

Algorithms with per-client server state (SCAFFOLD control variates, the
error-feedback residual table — any ``repro.state.ClientStateStore``
table) work in BOTH layouts: the engine passes each client's id to
``init_client`` (gather the client's row) and calls the algorithm's
``commit`` hook with the client's upload (scatter the new row, reduce
per-client-only upload entries) — vectorized over the stacked uploads in
``client_parallel``, one client at a time inside the sequential scan.

The K local steps are a ``lax.scan`` over the per-step batch axis; the
whole round is one XLA program (one ``jax.jit``), which is what the
multi-pod dry-run lowers.

Participation scenarios (``repro.scenario``, docs/scenarios.md) ride the
round batch pytree under two reserved keys that :func:`_pop_scenario`
splits off at trace time:

* ``STEP_MASK_KEY`` — an ``(S, K)`` bool step-validity mask (straggler
  simulation: client s only *applies* its first K_s steps; masked steps
  still compute their gradient — static shapes — but the parameter /
  optimizer-state update is discarded and the loss carries zero metric
  weight).
* ``AGG_WEIGHTS_KEY`` — an ``(S,)`` f32 weight vector (sums to 1) that
  replaces the uniform cross-client mean of the uploads (delta, block-mean
  v, SCAFFOLD dc, ...) with a weighted reduction.

Key presence is part of the pytree *structure*, so a degenerate scenario
(no reserved keys) traces the exact seed program — bit-exactness with the
scenario-free engine is structural, not numerical luck. Both layouts,
donation, and ``rounds_per_call`` fusion handle the keys unchanged: the
fused scan slices ``(M, S, K)`` masks per round like any other batch leaf.

Client-level DP (``repro.privacy``, docs/privacy.md) hooks in at three
points, in BOTH layouts, statically gated on ``fed.dp_clip > 0`` (the
disabled config traces the exact pre-privacy program):

* each client's raw ``delta`` is L2-clipped inside ``local_phase``
  BEFORE ``alg.upload`` — i.e. before any upload codec encodes it — and
  every other aggregated upload entry (block-mean v, SCAFFOLD
  ``c_new_minus_c``) is clipped per client right after;
* entries the ``commit`` hook introduces (SCAFFOLD ``dc``) are clipped
  per client post-commit, pre-aggregation;
* seeded Gaussian noise lands on the aggregated mean (server-side,
  secure-agg-style), keyed on ``(dp_seed, round_index)`` so every
  execution mode draws identical bits.

``FedConfig.use_pallas_clipacc`` (client_parallel, codec-free) swaps the
delta entry's clip + uniform mean for the fused
``repro.kernels.clipacc`` pass over the (S, model-size) upload stack.

Fault injection + defense (``repro.faults``, docs/faults.md) follows the
same two patterns. Injection rides the batch pytree under two more
reserved keys that :func:`_pop_faults` splits off — ``FAULT_DROP_KEY``
((S,) bool upload-dropout mask) and ``FAULT_MULT_KEY`` ((S,) f32
multiplier carrying NaN corruption / norm inflation) — applied to the
aggregated upload entries AFTER commit and AFTER the DP clip (a faulty
client does not politely clip itself). The defense is statically gated
on ``fed.robust_agg != "none"``: an on-device per-client validity mask
(finite check, transport arrivals, optional norm-outlier screen) feeds
the robust-aggregation registry, rejected clients are zero-weighted, the
surviving count scales DP noise and the quorum check
(``fed.min_quorum``: too few survivors ⇒ the round commits no state
change, round index still advances). Fault-free + defense-free traces
the exact pre-fault program — structural bit-exactness again.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.config import FedConfig, ModelConfig
from repro.core import partition
from repro.core.fedadamw import FedAlgorithm, get_algorithm
from repro.core.tree_util import tree_sub
from repro.faults import FAULT_DROP_KEY, FAULT_MULT_KEY
from repro.faults.defense import (apply_fault_mult, injected_codes,
                                  parse_robust_agg, robust_aggregate,
                                  upload_validity)
from repro.privacy import add_round_noise, clip_tree_by_l2, clip_upload_aux
from repro.scenario import AGG_WEIGHTS_KEY, STEP_MASK_KEY
from repro.telemetry.diagnostics import (attach_round_diagnostics,
                                         local_diagnostics, tree_sqnorm)
from repro.telemetry.ledger import (LEDGER_METRIC_KEY,
                                    finalize_ledger_block,
                                    local_ledger_stats,
                                    split_ledger_stats)

Array = jax.Array


def _clip_commit_entries(upload, pre_commit_keys, clip: float, *,
                         stacked: bool):
    """Per-client L2 clip of the upload entries the ``commit`` hook
    introduced (SCAFFOLD's ``dc``), pre-aggregation. ``stacked`` = the
    entries carry a leading (S,) client axis (client_parallel); the
    sequential scan clips one client's scalar entries per call."""
    def clip_entry(v):
        if stacked:
            return jax.vmap(lambda t: clip_tree_by_l2(t, clip))(v)
        return clip_tree_by_l2(v, clip)

    return {k: (v if k in pre_commit_keys else clip_entry(v))
            for k, v in upload.items()}


def _pop_scenario(batches):
    """Split the reserved scenario keys out of the round batch pytree ->
    ``(data_batches, step_mask | None, agg_weights | None)``. Presence is
    static (pytree structure), so jit traces a mask-free program when the
    scenario is degenerate."""
    if not isinstance(batches, dict) or not (
            STEP_MASK_KEY in batches or AGG_WEIGHTS_KEY in batches):
        return batches, None, None
    batches = dict(batches)
    return (batches, batches.pop(STEP_MASK_KEY, None),
            batches.pop(AGG_WEIGHTS_KEY, None))


def _pop_faults(batches):
    """Split the reserved fault keys out of the round batch pytree ->
    ``(data_batches, drop_mask | None, fault_mult | None)`` — the
    :func:`_pop_scenario` pattern: presence is pytree structure, so the
    fault-free stream traces the fault-free program."""
    if not isinstance(batches, dict) or not (
            FAULT_DROP_KEY in batches or FAULT_MULT_KEY in batches):
        return batches, None, None
    batches = dict(batches)
    return (batches, batches.pop(FAULT_DROP_KEY, None),
            batches.pop(FAULT_MULT_KEY, None))


def _weighted_mean(uploads, weights):
    """Cross-client upload reduction: uniform mean (weights=None, the
    paper's Algorithms 1-3) or a ``(S,)``-weighted sum (weights sum to 1,
    host-normalized by ``repro.scenario.aggregation_weights``)."""
    if weights is None:
        return jax.tree.map(lambda u: u.mean(axis=0), uploads)

    def wmean(u):
        # explicit left-to-right chain over the (small, static) client
        # axis instead of a sum() reduction: XLA picks reduction shapes
        # per program, so the same reduction can round differently inside
        # the fused multi-round scan body than in the single-round
        # program — a fixed association order keeps eager and fused
        # trajectories bit-identical under active scenarios too
        acc = u[0] * weights[0]
        for i in range(1, u.shape[0]):
            acc = acc + u[i] * weights[i]
        return acc.astype(u.dtype)

    return jax.tree.map(wmean, uploads)


def init_server_state(alg: FedAlgorithm, params, specs, fed: FedConfig):
    return alg.init_server(params, specs, fed)


def _accum_dtype(dtype) -> jnp.dtype:
    """Accumulator dtype for gradient micro-batching: match the gradient
    leaf unless it is a sub-32-bit float, which still sums in f32."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating) and jnp.finfo(dtype).bits < 32:
        return jnp.dtype(jnp.float32)
    return dtype


def cosine_lr_scale(round_index: Array, total_rounds: int,
                    min_scale: float = 0.0) -> Array:
    """Paper Appendix C: cosine learning-rate decay over rounds."""
    frac = jnp.clip(round_index.astype(jnp.float32) / max(total_rounds, 1),
                    0.0, 1.0)
    return min_scale + (1 - min_scale) * 0.5 * (1 + jnp.cos(jnp.pi * frac))


def make_local_phase(loss_fn: Callable, alg: FedAlgorithm, fed: FedConfig,
                     specs) -> Callable:
    """Returns local_phase(global_params, sstate, batches, lr_scale[, cid,
    step_valid]) -> (upload, metrics). ``batches``: pytree with leading K
    axis. ``step_valid`` (optional, (K,) bool) is the straggler
    step-validity mask: invalid steps keep the batch shape (their
    gradient is computed and discarded) but apply no update, so the
    upload reflects exactly the client's first K_i steps.

    With client-level DP on (``fed.dp_clip > 0``) the raw delta is
    L2-clipped HERE, before ``alg.upload`` — so an upload codec encodes
    the bounded values (wire bytes unchanged) — and the auxiliary upload
    entries are clipped per client right after. The fused clipacc kernel
    (client_parallel, codec-free) instead clips the delta at aggregation
    time, which is the same math with no codec in between; the fused
    uploadfuse megakernel likewise clips inside its one-pass upload
    pipeline (before it quantizes), so both kernels take over the delta
    clip while the auxiliary entries stay clipped here."""
    dp_on = fed.dp_clip > 0.0
    clip_delta_here = dp_on and not (fed.use_pallas_clipacc
                                     or fed.use_pallas_uploadfuse)
    diag_on = fed.telemetry_diagnostics
    ledger_on = fed.telemetry_ledger

    def local_phase(gparams, sstate, batches, lr_scale, client_id=None,
                    step_valid=None):
        if alg.needs_client_ids:
            cstate = alg.init_client(gparams, sstate, fed, specs=specs,
                                     client_id=client_id)
        else:
            cstate = alg.init_client(gparams, sstate, fed, specs=specs)

        if fed.grad_microbatches > 1:
            # One zero accumulator tree per local phase, shared by every
            # local step's micro-batch scan (was: fresh f32 zeros per
            # grad call, i.e. per local step). Leaves are dtype-matched
            # to the gradients so f32 training adds straight into the
            # scan carry with no per-micro-step cast copy; sub-32-bit
            # grads still accumulate in f32.
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, _accum_dtype(p.dtype)), gparams)

        def grad_of(params, batch):
            """Batch leaves are (b, ...) normally, or (mb, b_micro, ...)
            when fed.grad_microbatches > 1 — the micro axis is explicit in
            the input layout (NOT a reshape of the batch axis) so the
            sharded batch sub-dimension stays intact under GSPMD and the
            scan never iterates a sharded axis."""
            if fed.grad_microbatches <= 1:
                (loss, _aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                return loss, grads

            mb = fed.grad_microbatches

            def micro_step(acc, mbatch):
                (loss, _aux), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, gi: a + (gi if gi.dtype == a.dtype
                                       else gi.astype(a.dtype)),
                    acc[0], g)
                return (gsum, acc[1] + loss), None

            (gsum, lsum), _ = jax.lax.scan(
                micro_step, (zero_grads, jnp.zeros((), jnp.float32)), batch)
            inv = 1.0 / mb
            return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

        def step(carry, batch):
            params, cst = carry
            loss, grads = grad_of(params, batch)
            params, cst = alg.local_step(params, grads, cst, sstate, fed,
                                         lr_scale)
            return (params, cst), loss

        def masked_step(carry, xs):
            # straggler simulation: an invalid step computes its gradient
            # (the scan shape is static) but the update is discarded —
            # params AND client optimizer state (m, v, k, control
            # variates) carry through unchanged, exactly as if the client
            # had stopped after its K_i-th step
            batch, valid = xs
            params, cst = carry
            loss, grads = grad_of(params, batch)
            new_params, new_cst = alg.local_step(params, grads, cst, sstate,
                                                 fed, lr_scale)
            keep = lambda new, old: jnp.where(valid, new, old)  # noqa: E731
            params = jax.tree.map(keep, new_params, params)
            cst = jax.tree.map(keep, new_cst, cst)
            return (params, cst), loss

        if step_valid is None:
            (params_k, cstate_k), losses = jax.lax.scan(
                step, (gparams, cstate), batches)
            metrics = {"loss_first": losses[0], "loss_last": losses[-1],
                       "loss_mean": losses.mean()}
        else:
            (params_k, cstate_k), losses = jax.lax.scan(
                masked_step, (gparams, cstate), (batches, step_valid))
            v = step_valid.astype(jnp.float32)
            n_valid = jnp.maximum(v.sum(), 1.0)
            # last VALID step's loss: index of the largest k with v[k]=1
            # (k=0 is always valid — straggler_min_steps >= 1)
            last = jnp.argmax(jnp.arange(losses.shape[0]) * v)
            metrics = {"loss_first": losses[0], "loss_last": losses[last],
                       "loss_mean": (losses * v).sum() / n_valid}
        delta = tree_sub(params_k, gparams)
        # flight recorder: the clip-activation column needs the PRE-clip
        # squared norm — measured here regardless of which component
        # (local clip, clipacc, uploadfuse) performs the actual clip,
        # since all three bound the same raw delta
        raw_sq = tree_sqnorm(delta) if (ledger_on and dp_on) else None
        if clip_delta_here:
            delta = clip_tree_by_l2(delta, fed.dp_clip)
        up = alg.upload(delta, cstate_k, specs, fed)
        if dp_on:
            up = clip_upload_aux(up, fed.dp_clip)
        if ledger_on:
            metrics = {**metrics, **local_ledger_stats(
                raw_sq, up.get("delta", delta), step_valid=step_valid,
                num_steps=losses.shape[0])}
        if diag_on:
            # per-client scalar accumulators for the Figure-2 gauges
            # (repro.telemetry.diagnostics); measured on the upload's
            # delta entry when present (post-codec, post-clip — i.e. the
            # values actually aggregated), else the raw local delta
            metrics = {**metrics,
                       **local_diagnostics(up.get("delta", delta), up)}
        return up, metrics

    return local_phase


def make_round_fn(model, fed: FedConfig, specs, *,
                  alg: Optional[FedAlgorithm] = None,
                  loss_fn: Optional[Callable] = None,
                  cosine_total_rounds: int = 0) -> Callable:
    """Build the jittable round function.

    round_fn(gparams, sstate, batches, client_ids, round_index)
        -> (new_params, new_sstate, metrics)

    batches: pytree whose leaves have leading axes (S, K, ...) —
    clients x local-steps x per-step batch.
    """
    alg = alg or get_algorithm(fed)
    loss_fn = loss_fn or model.loss
    local_phase = make_local_phase(loss_fn, alg, fed, specs)
    dp_on = fed.dp_clip > 0.0
    dp_noise_on = dp_on and fed.dp_noise_multiplier > 0.0
    diag_on = fed.telemetry_diagnostics
    ledger_on = fed.telemetry_ledger
    # defense layer (repro.faults, docs/faults.md) — statically gated:
    # robust_agg == "none" with no fault keys on the batch traces the
    # exact pre-fault program
    robust_kind, trim_frac = parse_robust_agg(fed.robust_agg)
    defense_on = robust_kind != "none"
    quorum_on = fed.min_quorum > 0
    # fused one-pass upload (kernels/uploadfuse): the compressed wrapper
    # ran in defer mode, so every upload carries the RAW delta (plus the
    # client's current EF residual row) and the engine owns the whole
    # fold -> DP clip -> quantize -> re-clip -> accumulate pipeline
    fuse_on = fed.use_pallas_uploadfuse
    if fuse_on:
        from repro.comm.codecs import split_algorithm_name
        from repro.comm.compress import _encode_key
        from repro.comm.error_feedback import EF_KEY, ROUND_KEY
        from repro.kernels.uploadfuse import tree_upload_fuse
        _, _fuse_spec = split_algorithm_name(fed.algorithm)
        fuse_bits = {"int8": 8, "int4": 4}.get(_fuse_spec or "", 0)

        def fuse_uploads(delta_stack, ef_stack, weights, cids, rnd):
            # int4 stochastic rounding draws the SAME per-(round, client)
            # keys the unfused codec derives, so the fused trajectory
            # reuses the unfused noise stream
            keys = None
            if fuse_bits == 4:
                keys = jax.vmap(
                    lambda c: _encode_key(rnd, c, None))(cids)
            return tree_upload_fuse(
                delta_stack, ef_stack, bits=fuse_bits,
                clip=fed.dp_clip if dp_on else 0.0,
                weights=weights, keys=keys)

    def _lr_scale(round_index):
        if cosine_total_rounds:
            return cosine_lr_scale(round_index, cosine_total_rounds)
        return jnp.ones((), jnp.float32)

    if fed.layout == "client_parallel":

        def round_fn(gparams, sstate, batches, client_ids, round_index):
            batches, step_mask, agg_w = _pop_scenario(batches)
            batches, f_drop, f_mult = _pop_faults(batches)
            sstate0 = sstate  # pre-commit state, for the quorum rollback
            lr_scale = _lr_scale(round_index)
            # "trace/*" spans time PROGRAM CONSTRUCTION (this body runs
            # on the host only while jit traces it) — they never touch
            # the traced XLA program, so telemetry-off is structurally
            # bit-exact
            with telemetry.span("trace/local_phase", "trace"):
                if step_mask is None:
                    uploads, metrics = jax.vmap(
                        local_phase, in_axes=(None, None, 0, None, 0),
                        out_axes=0)(gparams, sstate, batches, lr_scale,
                                    client_ids)
                else:
                    uploads, metrics = jax.vmap(
                        local_phase, in_axes=(None, None, 0, None, 0, 0),
                        out_axes=0)(gparams, sstate, batches, lr_scale,
                                    client_ids, step_mask)
            if ledger_on:
                # the led_* stats are (S,)-resolution: strip them before
                # the cross-client metric mean below and re-attach as
                # the per-round stats block once the aggregate is known
                metrics, led_stats = split_ledger_stats(metrics)
            led_valid = None  # set by the defense branch when it runs
            if fuse_on:
                # one fused pass over the stacked raw deltas: pull the
                # delta stack (and the clients' current residual rows)
                # out of the upload dict, run the megakernel, and hand
                # ``commit`` the NEW residuals; the surviving entries
                # (block-mean v, SCAFFOLD dc) aggregate below with the
                # same effective weights the kernel folded in
                with telemetry.span("trace/uploadfuse", "trace"):
                    uploads = dict(uploads)
                    delta_stack = uploads.pop("delta")
                    ef_stack = uploads.pop(EF_KEY, None)
                    s = jax.tree.leaves(delta_stack)[0].shape[0]
                    base_w = (agg_w if agg_w is not None
                              else jnp.full((s,), 1.0 / s, jnp.float32))
                    if f_drop is not None:
                        # dropped uploads never arrived: renormalize the
                        # weights over the survivors so the fused
                        # accumulate IS the masked mean
                        wv = base_w * jnp.logical_not(f_drop).astype(
                            jnp.float32)
                        w_eff = wv / jnp.maximum(jnp.sum(wv), 1e-12)
                    else:
                        w_eff = base_w
                    fused = fuse_uploads(
                        delta_stack, ef_stack, w_eff, client_ids,
                        sstate[ROUND_KEY] if fuse_bits == 4 else None)
                    if fused.residual is not None:
                        uploads[EF_KEY] = fused.residual
            if alg.commit is not None:
                # write the sampled clients' per-client server state rows
                # (control variates, EF residuals) before aggregation
                with telemetry.span("trace/commit", "trace"):
                    pre_commit_keys = set(uploads)
                    sstate, uploads = alg.commit(sstate, uploads,
                                                 client_ids, specs, fed)
                    if dp_on:
                        # entries commit introduced (SCAFFOLD dc) are
                        # clipped per client pre-aggregation like
                        # everything else
                        uploads = _clip_commit_entries(
                            uploads, pre_commit_keys, fed.dp_clip,
                            stacked=True)
            with telemetry.span("trace/aggregate", "trace"):
                if f_mult is not None:
                    # NaN corruption / norm inflation land AFTER the DP
                    # clip and the commit hook: a faulty client does not
                    # politely clip itself, and its own state-table row
                    # keeps the clean values (the corruption models the
                    # wire, not the client's local training)
                    uploads = apply_fault_mult(uploads, f_mult)
                n_valid = None
                if fuse_on:
                    # the kernel already produced the weighted delta
                    # mean; the remaining entries take the same masked
                    # weights so a dropped client vanishes from every
                    # entry consistently
                    if f_drop is not None:
                        n_valid = jnp.sum(
                            jnp.logical_not(f_drop).astype(jnp.float32))
                    mean_up = dict(_weighted_mean(uploads, w_eff))
                    mean_up["delta"] = fused.mean
                elif defense_on or f_drop is not None:
                    # upload validator + masked/robust aggregation:
                    # dropped uploads never arrived (observable by ANY
                    # server), the finite/norm screens need the defense
                    arrived = (None if f_drop is None
                               else jnp.logical_not(f_drop))
                    if defense_on:
                        valid = upload_validity(
                            uploads, arrived=arrived, kind=robust_kind,
                            norm_mult=fed.robust_norm_mult)
                    else:
                        valid = arrived
                    led_valid = valid
                    mean_up, n_valid = robust_aggregate(
                        uploads, valid, agg_w,
                        kind=robust_kind if defense_on else "mean",
                        trim_frac=trim_frac)
                elif dp_on and fed.use_pallas_clipacc:
                    # fused per-client clip + uniform accumulate for the
                    # delta entry (one pass over the S x model-size
                    # stack; validation pins agg_weighting=uniform, so
                    # agg_w is None here)
                    from repro.kernels.clipacc import tree_clip_accumulate
                    s = jax.tree.leaves(uploads["delta"])[0].shape[0]
                    mean_delta, _ = tree_clip_accumulate(
                        uploads["delta"], clip=fed.dp_clip,
                        weights=jnp.full((s,), 1.0 / s, jnp.float32))
                    rest = {k: v for k, v in uploads.items()
                            if k != "delta"}
                    mean_up = dict(_weighted_mean(rest, agg_w))
                    mean_up["delta"] = mean_delta
                else:
                    mean_up = _weighted_mean(uploads, agg_w)
                clean_up = mean_up  # pre-noise mean, for diagnostics
                if dp_noise_on:
                    # noise std scales to the SURVIVING cohort when the
                    # validator rejected clients (sigma*C/S_valid keeps
                    # the per-client guarantee as S_valid shrinks)
                    mean_up = add_round_noise(mean_up, fed, round_index,
                                              cohort_size=n_valid)
            with telemetry.span("trace/server_update", "trace"):
                new_params, new_state = alg.server_update(
                    gparams, sstate, mean_up, specs, fed)
            if quorum_on:
                # too few survivors: commit NOTHING — params AND server
                # state (incl. the rows this round's commit hook wrote)
                # roll back to the round-start values; the round index
                # and every rng stream advance outside, so schedules
                # stay aligned
                ok = n_valid >= fed.min_quorum
                keep = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
                new_params = jax.tree.map(keep, new_params, gparams)
                new_state = jax.tree.map(keep, new_state, sstate0)
            out_metrics = jax.tree.map(lambda m: m.mean(axis=0), metrics)
            if n_valid is not None:
                out_metrics["agg_survivors"] = n_valid
            if quorum_on:
                out_metrics["quorum_ok"] = ok.astype(jnp.float32)
            if diag_on:
                out_metrics = attach_round_diagnostics(out_metrics,
                                                       clean_up)
            if ledger_on:
                out_metrics[LEDGER_METRIC_KEY] = finalize_ledger_block(
                    led_stats, client_ids=client_ids,
                    mean_delta_sq=tree_sqnorm(clean_up["delta"]),
                    dp_clip=fed.dp_clip,
                    arrived=(None if f_drop is None
                             else jnp.logical_not(f_drop)),
                    valid=led_valid,
                    injected=injected_codes(f_drop, f_mult))
            return new_params, new_state, out_metrics

    else:  # client_sequential

        def round_fn(gparams, sstate, batches, client_ids, round_index):
            batches, step_mask, agg_w = _pop_scenario(batches)
            batches, f_drop, f_mult = _pop_faults(batches)
            sstate0 = sstate  # pre-commit state, for the quorum rollback
            lr_scale = _lr_scale(round_index)
            weighted = agg_w is not None
            faults_on = f_drop is not None
            # per-client validity folds into the online accumulation:
            # the sequential layout supports the "mean" defense (rank
            # statistics would need the full client stack — rejected by
            # config validation)
            track_valid = defense_on or faults_on

            def _fuse_one(sst, up, cid, w):
                """Sequential fused upload: the same megakernel run on a
                one-client (S=1) stack inside the scan body. The client's
                aggregation weight folds into the kernel's accumulate, so
                ``contrib`` must NOT weight the delta again; uniform runs
                keep weight 1 and divide by n at the end like every other
                entry."""
                up = dict(up)
                delta = up.pop("delta")
                ef_row = up.pop(EF_KEY, None)
                one = lambda t: jax.tree.map(lambda a: a[None], t)  # noqa: E731
                wvec = jnp.reshape(jnp.asarray(
                    1.0 if w is None else w, jnp.float32), (1,))
                fused = fuse_uploads(
                    one(delta), None if ef_row is None else one(ef_row),
                    wvec, jnp.reshape(cid, (1,)),
                    sst[ROUND_KEY] if fuse_bits == 4 else None)
                up["delta"] = fused.mean
                if fused.residual is not None:
                    up[EF_KEY] = jax.tree.map(lambda a: a[0],
                                              fused.residual)
                return up

            def one_client(sst, per_client_batches, cid, step_valid,
                           w=None):
                """One client's local phase + per-client state commit.

                Distinct clients touch distinct table rows, so committing
                inside the scan is exactly the vectorized commit of the
                parallel layout (round-start values for everything the
                clients *read*: c, delta_g and each client's own row)."""
                if step_valid is None:
                    up, m = local_phase(gparams, sst, per_client_batches,
                                        lr_scale, cid)
                else:
                    up, m = local_phase(gparams, sst, per_client_batches,
                                        lr_scale, cid, step_valid)
                if fuse_on:
                    up = _fuse_one(sst, up, cid, w)
                if alg.commit is not None:
                    pre_commit_keys = set(up)
                    sst, up = alg.commit(sst, up, cid, specs, fed)
                    if dp_on:
                        up = _clip_commit_entries(
                            up, pre_commit_keys, fed.dp_clip,
                            stacked=False)
                return sst, up, m

            def client_valid(up, x):
                """Scalar validity of one client's (post-fault) upload:
                arrived (dropout fault) AND — when the defense is on —
                every aggregated element finite."""
                ok = jnp.ones((), jnp.bool_)
                if faults_on:
                    ok = jnp.logical_and(ok, jnp.logical_not(x["fd"]))
                if defense_on:
                    ok = jnp.logical_and(
                        ok, upload_validity(up, arrived=None,
                                            kind="mean", norm_mult=0.0,
                                            stacked=False))
                return ok

            def contrib(up, w):
                # weights sum to 1, so the accumulated weighted
                # contributions ARE the weighted mean — no final divide
                # (under validity masking a renormalizing weight-sum
                # accumulator rides along instead)
                if not weighted:
                    return up
                if fuse_on:
                    # the fused kernel already folded w into the delta
                    wmul = lambda u: (u * w).astype(u.dtype)  # noqa: E731
                    return {k: (v if k == "delta"
                                else jax.tree.map(wmul, v))
                            for k, v in up.items()}
                return jax.tree.map(lambda u: (u * w).astype(u.dtype), up)

            def scan_client(acc, xs):
                if track_valid:
                    acc_up, acc_m, n, nv, ws, sst = acc
                else:
                    acc_up, acc_m, n, sst = acc
                sst, up, m = one_client(sst, xs["b"], xs["cid"],
                                        xs.get("sm"), xs.get("w"))
                if ledger_on:
                    # per-client scalars leave the scan as stacked ys —
                    # they must NOT fold into the metric sum below
                    m, led = split_ledger_stats(m)
                if f_mult is not None:
                    up = apply_fault_mult(up, xs["fm"], stacked=False)
                if track_valid:
                    ok = client_valid(up, xs)
                    okf = ok.astype(jnp.float32)
                    # zero the rejected upload BEFORE weighting: the
                    # corrupt values are NaN and NaN * 0 = NaN
                    up = jax.tree.map(
                        lambda u: jnp.where(ok, u, jnp.zeros((), u.dtype)),
                        up)
                    nv = nv + okf
                    ws = ws + (xs["w"] * okf if weighted else okf)
                acc_up = jax.tree.map(jnp.add, acc_up,
                                      contrib(up, xs.get("w")))
                acc_m = jax.tree.map(jnp.add, acc_m, m)
                ys = None
                if ledger_on:
                    # same ingredients the parallel layout hands
                    # finalize_ledger_block, one client at a time
                    ys = dict(led)
                    if faults_on:
                        ys["arrived"] = jnp.logical_not(xs["fd"])
                        ys["injected"] = injected_codes(xs["fd"],
                                                        xs["fm"])
                    if track_valid:
                        ys["valid"] = ok
                if track_valid:
                    return (acc_up, acc_m, n + 1, nv, ws, sst), ys
                return (acc_up, acc_m, n + 1, sst), ys

            xs = {"b": batches, "cid": client_ids}
            if step_mask is not None:
                xs["sm"] = step_mask
            if weighted:
                xs["w"] = agg_w
            if faults_on:
                xs["fd"] = f_drop
                xs["fm"] = f_mult

            # build zero accumulators with the right structure via one
            # abstract evaluation (no FLOPs at runtime: jitted away)
            def _first_contrib(x):
                _, up, m = one_client(sstate, x["b"], x["cid"], x.get("sm"),
                                      x.get("w"))
                if ledger_on:
                    m, _ = split_ledger_stats(m)
                return contrib(up, x.get("w")), m

            acc_shape = jax.eval_shape(_first_contrib,
                                       jax.tree.map(lambda x: x[0], xs))
            acc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                acc_shape)
            zero = jnp.zeros((), jnp.float32)
            carry0 = ((acc0[0], acc0[1], zero, zero, zero, sstate)
                      if track_valid else (acc0[0], acc0[1], zero, sstate))
            # trace-time span (see client_parallel): host cost of
            # constructing the scanned client program, not device time
            with telemetry.span("trace/local_phase", "trace"):
                if track_valid:
                    (sum_up, sum_m, n, n_valid, wsum, sstate_k), led_rows \
                        = jax.lax.scan(scan_client, carry0, xs)
                else:
                    (sum_up, sum_m, n, sstate_k), led_rows = jax.lax.scan(
                        scan_client, carry0, xs)
                    n_valid = None
            with telemetry.span("trace/aggregate", "trace"):
                inv = 1.0 / jnp.maximum(n, 1.0)
                if track_valid:
                    # masked (weighted) mean over the survivors: wsum is
                    # the valid count (uniform) or the valid weight sum
                    winv = 1.0 / jnp.maximum(wsum, 1e-12)
                    mean_up = jax.tree.map(lambda u: u * winv, sum_up)
                    if defense_on:
                        from repro.faults.defense import \
                            clamp_nonneg_entries
                        mean_up = clamp_nonneg_entries(mean_up)
                elif weighted:
                    mean_up = sum_up
                else:
                    mean_up = jax.tree.map(lambda u: u * inv, sum_up)
                clean_up = mean_up  # pre-noise mean, for diagnostics
                if dp_noise_on:
                    mean_up = add_round_noise(mean_up, fed, round_index,
                                              cohort_size=n_valid)
            out_metrics = jax.tree.map(lambda m: m * inv, sum_m)
            if n_valid is not None:
                out_metrics["agg_survivors"] = n_valid
            with telemetry.span("trace/server_update", "trace"):
                new_params, new_state = alg.server_update(
                    gparams, sstate_k, mean_up, specs, fed)
            if quorum_on:
                ok = n_valid >= fed.min_quorum
                keep = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
                new_params = jax.tree.map(keep, new_params, gparams)
                new_state = jax.tree.map(keep, new_state, sstate0)
                out_metrics["quorum_ok"] = ok.astype(jnp.float32)
            if diag_on:
                out_metrics = attach_round_diagnostics(out_metrics,
                                                       clean_up)
            if ledger_on:
                # led_rows: scan-stacked (S,) ingredients — identical
                # column math to the parallel layout by construction
                out_metrics[LEDGER_METRIC_KEY] = finalize_ledger_block(
                    led_rows, client_ids=client_ids,
                    mean_delta_sq=tree_sqnorm(clean_up["delta"]),
                    dp_clip=fed.dp_clip,
                    arrived=led_rows.get("arrived"),
                    valid=led_rows.get("valid"),
                    injected=led_rows.get("injected"))
            return new_params, new_state, out_metrics

    return round_fn


def make_multi_round_fn(model, fed: FedConfig, specs, *,
                        alg: Optional[FedAlgorithm] = None,
                        loss_fn: Optional[Callable] = None,
                        cosine_total_rounds: int = 0) -> Callable:
    """Fuse M consecutive federated rounds into ONE jitted call.

    multi_round_fn(gparams, sstate, batches, client_ids, round_index)
        -> (new_params, new_sstate, metrics)

    batches: pytree whose leaves have leading axes (M, S, K, ...);
    client_ids: (M, S); round_index: scalar index of the FIRST round of
    the block. Metrics leaves come back stacked per round, shape (M,).

    The body is exactly the single-round ``make_round_fn`` program
    scanned over the round axis — the cosine schedule is computed from
    the carried round index (``round_index + i`` on step i), so a fused
    trajectory is bit-identical to M eager calls on the same data while
    paying the host dispatch / transfer cost once per block
    (``FedConfig.rounds_per_call``). Launch-bound small models amortize
    their per-call overhead by M; compute-bound models are unaffected.
    """
    round_fn = make_round_fn(model, fed, specs, alg=alg, loss_fn=loss_fn,
                             cosine_total_rounds=cosine_total_rounds)

    def multi_round_fn(gparams, sstate, batches, client_ids, round_index):
        def body(carry, xs):
            params, sst, r = carry
            per_round_batches, cids = xs
            params, sst, m = round_fn(params, sst, per_round_batches,
                                      cids, r)
            return (params, sst, r + 1), m

        (params, sstate, _), metrics = jax.lax.scan(
            body, (gparams, sstate, jnp.asarray(round_index)),
            (batches, client_ids))
        return params, sstate, metrics

    return multi_round_fn


def build_fed_state(model, fed: FedConfig, rng: jax.Array,
                    cfg: Optional[ModelConfig] = None):
    """Convenience: init params, block specs, algorithm, server state."""
    cfg = cfg or model.cfg
    params = model.init(rng)
    specs = partition.build_block_specs(params, cfg, fed)
    alg = get_algorithm(fed)
    sstate = init_server_state(alg, params, specs, fed)
    return params, specs, alg, sstate


def upload_shape_spec(alg: FedAlgorithm, params, sstate, specs,
                      fed: FedConfig):
    """Shape/dtype spec of one client's upload pytree (no FLOPs: abstract
    evaluation only). ``params`` stands in for the delta — same spec."""
    def one_upload():
        kw = {"specs": specs}
        if alg.needs_client_ids:
            kw["client_id"] = jnp.zeros((), jnp.int32)
        cstate = alg.init_client(params, sstate, fed, **kw)
        return alg.upload(params, cstate, specs, fed)

    return jax.eval_shape(one_upload)


# ---------------------------------------------------------- trace entry points
#
# Abstract-only construction of the round program for the static analyzer
# (repro.analysis.jaxpr_audit) and for gate-parity tests: everything below
# runs zero FLOPs — parameters are never allocated, the model never runs.
# Two traces of the same (model, fed) produce byte-identical jaxpr text,
# which is what makes IR diffing a substitute for trajectory parity.

def round_abstract_args(model, fed: FedConfig, *, cfg=None, batch_size=2,
                        seq_len=16, batch_example=None, with_scenario=None,
                        with_faults=None, rounds=0):
    """Abstract ``round_fn`` argument tree — no parameter allocation.

    Returns ``((params, sstate, batches, client_ids, round_index), specs,
    alg)`` where every array is a ``jax.ShapeDtypeStruct``. ``rounds > 0``
    prepends the (M,) multi-round axis to batches/client_ids (the
    ``make_multi_round_fn`` calling convention). ``batch_example`` is one
    per-step batch pytree of arrays/ShapeDtypeStructs to stack to
    (S, K, ...); the default is the LM ``{"tokens", "labels"}`` pair used
    by every vit/gpt config. ``with_scenario`` forces the reserved
    step-mask/weights keys on/off; default mirrors what the scenario
    engine would emit for ``fed``. ``with_faults`` does the same for the
    reserved fault keys (default: on iff any fault probability is > 0).
    """
    cfg = cfg or model.cfg
    # ra: allow[RA101] abstract eval: the key is never consumed
    params = jax.eval_shape(model.init, jax.random.key(0))
    specs = partition.build_block_specs(params, cfg, fed)
    alg = get_algorithm(fed)
    sstate = jax.eval_shape(
        lambda p: init_server_state(alg, p, specs, fed), params)
    s, k = fed.clients_per_round, fed.local_steps
    sd = jax.ShapeDtypeStruct
    lead = (rounds,) if rounds else ()
    if batch_example is None:
        batch_example = {"tokens": sd((batch_size, seq_len), jnp.int32),
                         "labels": sd((batch_size, seq_len), jnp.int32)}
    batches = jax.tree.map(
        lambda a: sd(lead + (s, k) + tuple(a.shape), a.dtype), batch_example)
    if with_scenario is None:
        with_scenario = (fed.straggler_frac > 0.0
                         or fed.agg_weighting != "uniform")
    if with_scenario:
        batches[STEP_MASK_KEY] = sd(lead + (s, k), jnp.bool_)
        batches[AGG_WEIGHTS_KEY] = sd(lead + (s,), jnp.float32)
    if with_faults is None:
        with_faults = fed.faults_enabled()
    if with_faults:
        batches[FAULT_DROP_KEY] = sd(lead + (s,), jnp.bool_)
        batches[FAULT_MULT_KEY] = sd(lead + (s,), jnp.float32)
    client_ids = sd(lead + (s,), jnp.int32)
    round_index = sd((), jnp.int32)
    return (params, sstate, batches, client_ids, round_index), specs, alg


def trace_round_jaxpr(model, fed: FedConfig, *, cfg=None,
                      multi_rounds=0, cosine_total_rounds=10, **kw):
    """Trace the round program abstractly -> ``(ClosedJaxpr, args)``.

    ``multi_rounds > 0`` traces ``make_multi_round_fn`` over that many
    scanned rounds instead of the single-round program. Keyword args are
    forwarded to :func:`round_abstract_args`. The jaxpr's pretty-printed
    text is deterministic: equal programs ⇒ equal strings, so
    ``str(trace_round_jaxpr(m, a)[0]) == str(trace_round_jaxpr(m, b)[0])``
    is the gate-parity check."""
    args, specs, alg = round_abstract_args(
        model, fed, cfg=cfg, rounds=multi_rounds, **kw)
    maker = make_multi_round_fn if multi_rounds else make_round_fn
    fn = maker(model, fed, specs, alg=alg,
               cosine_total_rounds=cosine_total_rounds)
    return jax.make_jaxpr(fn)(*args), args
