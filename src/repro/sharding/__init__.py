"""Sharding rules: parameter-tree -> PartitionSpec for the production mesh."""
from repro.sharding.specs import (
    param_pspecs,
    state_pspecs,
    batch_pspec,
    cache_pspecs,
    client_axes,
    fsdp_axes,
)

__all__ = [
    "param_pspecs", "state_pspecs", "batch_pspec", "cache_pspecs",
    "client_axes", "fsdp_axes",
]
