"""Parameter / state / batch PartitionSpec rules.

The production mesh (launch/mesh.py) is ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod. Rules:

``client_parallel`` layout
    Params replicated over the client axes (``pod``+``data``) and
    tensor-parallel over ``model``; the per-round batch carries a leading
    client axis sharded over the client axes. The cross-client mean of the
    uploads is the all-reduce.

``client_sequential`` layout
    One client at a time owns the whole mesh: params are tensor-parallel
    over ``model`` AND fully-sharded (FSDP/ZeRO-3 style) over the client
    axes; the local batch's batch dim is sharded over the client axes.

Model-axis rules per leaf name (head-factored layouts from
repro.models.attention):

    attn_wq  (D, H, hd)   -> shard H        (column / head parallel)
    attn_wk/v(D, KV, hd)  -> shard KV if divisible, else hd
    attn_wo  (H, hd, D)   -> shard H        (row parallel)
    mlp_wi/wg(D, F)       -> shard F
    mlp_wo   (F, D)       -> shard F
    moe_exp_*(E, ., .)    -> shard E (expert parallel) if divisible,
                             else the F dim (tensor parallel inside experts)
    embed    (V, D)       -> shard V
    output   (D, V)       -> shard V
    ssm_in/out_proj       -> shard the d_inner dim
    ssm_conv (w, CH)      -> shard CH
    small 1-D params      -> replicated

Every rule checks divisibility against the actual mesh axis size and falls
back to replication — no architecture can fail to lower because of an
indivisible axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import FedConfig, ModelConfig

MODEL_AXIS = "model"


def client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that host clients / data parallelism."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return client_axes(mesh)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _leaf_name(path) -> str:
    last = path[-1]
    return last.key if hasattr(last, "key") else str(last)


def _path_names(path) -> Tuple[str, ...]:
    return tuple(k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
                 for k in path)


def _model_dim_rule(name: str, shape: Tuple[int, ...], off: int,
                    cfg: ModelConfig, msize: int) -> Optional[int]:
    """Return the (absolute) axis index to shard over ``model``, or None."""
    nd = len(shape) - off

    def ok(rel_axis: int) -> bool:
        return shape[rel_axis + off] % msize == 0

    def pick(*rel_axes: int) -> Optional[int]:
        for a in rel_axes:
            if 0 <= a < nd and ok(a):
                return a + off
        return None

    if name.endswith(("attn_wq",)) and nd == 3:
        return pick(1, 0)                       # heads, else d_model rows
    if name.endswith(("attn_wk", "attn_wv")) and nd == 3:
        return pick(1, 2, 0)                    # kv heads, else head_dim
    if name.endswith("attn_wo") and nd == 3:
        return pick(0, 2)                       # heads (row parallel)
    if name.endswith(("attn_bq", "attn_bk", "attn_bv")) and nd == 2:
        return pick(0, 1)
    if name.endswith(("mlp_wi", "mlp_wg")) and nd == 2:
        return pick(1)
    if name.endswith("mlp_wo") and nd == 2:
        return pick(0)
    if name.startswith("moe_exp_") and nd == 3:
        if cfg.moe_shard == "ep":
            a = pick(0)
            if a is not None:
                return a
        # tensor-parallel inside experts: F is axis 2 for wi/wg, 1 for wo
        return pick(2 if name.endswith(("wi", "wg")) else 1)
    if name.startswith("moe_shared_") and nd == 2:
        return pick(1 if name.endswith("wi") or name.endswith("wg") else 0)
    if name.endswith("moe_router") and nd == 2:
        return None                             # (D, E): tiny, replicate
    if name.endswith("embed_tokens") and nd == 2:
        return pick(0)                          # vocab rows
    if name.endswith("output_head") and nd == 2:
        return pick(1)                          # vocab cols
    if name.endswith("ssm_in_proj") and nd == 2:
        return pick(1)
    if name.endswith("ssm_out_proj") and nd == 2:
        return pick(0)
    if name.endswith("ssm_conv") and nd == 2:
        return pick(1)
    if name.endswith("frontend_proj") and nd == 2:
        return pick(1)
    return None


def _fsdp_dim_rule(shape: Tuple[int, ...], taken: Optional[int],
                   fsize: int) -> Optional[int]:
    """Pick the largest remaining axis divisible by the FSDP size."""
    best, best_dim = None, 0
    for a, d in enumerate(shape):
        if a == taken:
            continue
        if d % fsize == 0 and d > best_dim:
            best, best_dim = a, d
    return best


def leaf_pspec(path, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh,
               fed: Optional[FedConfig] = None) -> P:
    names = _path_names(path)
    name = _leaf_name(path)
    # stacked scan-layer leading axis (layers/encoder stacks, non-hybrid)
    stacked = (cfg.family != "hybrid" and len(names) >= 2
               and names[0] in ("layers", "encoder"))
    off = 1 if stacked else 0
    msize = mesh.shape[MODEL_AXIS] if MODEL_AXIS in mesh.axis_names else 1

    spec: list = [None] * len(shape)
    taken = _model_dim_rule(name, shape, off, cfg, msize)
    if taken is not None:
        spec[taken] = MODEL_AXIS

    sequential = fed is not None and fed.layout == "client_sequential"
    if sequential:
        fax = fsdp_axes(mesh)
        if fax:
            fsize = _axis_size(mesh, tuple(fax))
            a = _fsdp_dim_rule(shape, taken, fsize)
            if a is not None:
                spec[a] = fax if len(fax) > 1 else fax[0]
    return P(*spec)


def param_pspecs(params, cfg: ModelConfig, mesh: Mesh,
                 fed: Optional[FedConfig] = None):
    """Pytree of PartitionSpec matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [leaf_pspec(kp, tuple(x.shape), cfg, mesh, fed)
              for kp, x in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def state_pspecs(sstate, params_specs, params, cfg: ModelConfig, mesh: Mesh,
                 fed: Optional[FedConfig] = None):
    """Server-state PartitionSpecs: param-shaped leaves inherit the param
    spec; per-client state tables (``repro.state.ClientStateStore`` —
    SCAFFOLD's ``c_all``, the EF residual table) shard their leading
    ``num_clients`` axis over the client mesh axes (``pod`` + ``data``)
    so the table is distributed instead of replicated; everything else
    (scalars, block-mean vectors) is replicated."""
    from repro.state import CLIENT_TABLE_KEYS, client_row_pspec

    flat_params = {}
    for kp, spec in jax.tree_util.tree_flatten_with_path(params_specs)[0]:
        flat_params[_path_names(kp)] = spec
    param_shapes = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        param_shapes[_path_names(kp)] = tuple(leaf.shape)

    n_clients = fed.num_clients if fed is not None else 0

    flat, treedef = jax.tree_util.tree_flatten_with_path(sstate)
    out = []
    for kp, leaf in flat:
        # fields like delta_g/v_bar/momentum/server_m mirror the param tree:
        # strip the leading field name and look the rest up; reuse the param
        # spec only when the shapes actually match (block-mean vectors don't)
        names = _path_names(kp)
        sub = names[1:]
        if sub in flat_params and param_shapes[sub] == tuple(leaf.shape):
            out.append(flat_params[sub])
        elif names and names[0] in CLIENT_TABLE_KEYS and n_clients > 1:
            out.append(client_row_pspec(leaf, mesh, n_clients))
        else:
            out.append(P(*([None] * leaf.ndim)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_pspec(mesh: Mesh, fed: Optional[FedConfig] = None,
                *, rank: int = 4) -> P:
    """Per-round batch leaves of the given rank.

    Leaves are (S, K, b, ...) — or (S, K, mb, b_micro, ...) with gradient
    micro-batching. client_parallel shards the client axis S; sequential
    shards the batch axis b / b_micro over the client axes.
    """
    cax = client_axes(mesh)
    ax = cax if len(cax) > 1 else (cax[0] if cax else None)
    spec = [None] * rank
    if fed is not None and fed.layout == "client_sequential":
        b_axis = 3 if (fed.grad_microbatches > 1) else 2
        spec[b_axis] = ax
    else:
        spec[0] = ax
    return P(*spec)


def eval_batch_pspec(mesh: Mesh) -> P:
    cax = client_axes(mesh)
    ax = cax if len(cax) > 1 else (cax[0] if cax else None)
    return P(ax, None)


def cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh):
    """KV-cache / SSM-state PartitionSpecs for serving.

    KV leaves are (L?, B, len, KV, hd) (leading stacked-layer axis when the
    stack is scanned). Batch shards over the client axes; KV heads shard
    over ``model`` when divisible, else head_dim. SSM state (L?, B, H, P, N)
    shards B over client axes and H over model when divisible.
    """
    msize = mesh.shape[MODEL_AXIS] if MODEL_AXIS in mesh.axis_names else 1
    cax = client_axes(mesh)
    bax = cax if len(cax) > 1 else (cax[0] if cax else None)
    bsize = _axis_size(mesh, tuple(cax)) if cax else 1

    # base ranks: k/v (B,len,KV,hd)=4, state (B,H,P,N)=4, conv (B,w,CH)=3;
    # scanned stacks prepend a layer axis (+1)
    base_rank = {"k": 4, "v": 4, "state": 4, "conv": 3}

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        if name == "index" or nd <= 1 or name not in base_rank:
            return P(*([None] * nd))
        spec = [None] * nd
        off = nd - base_rank[name]          # 1 when layer-stacked, else 0
        b_ax = off
        if bax is not None and shape[b_ax] % bsize == 0 and bsize > 1:
            spec[b_ax] = bax
        if name in ("k", "v"):
            kv_ax, hd_ax = nd - 2, nd - 1
            if shape[kv_ax] % msize == 0 and msize > 1:
                spec[kv_ax] = MODEL_AXIS
            elif shape[hd_ax] % msize == 0 and msize > 1:
                spec[hd_ax] = MODEL_AXIS
        elif name == "state":                     # (.., H, P, N)
            h_ax = nd - 3
            if shape[h_ax] % msize == 0 and msize > 1:
                spec[h_ax] = MODEL_AXIS
        elif name == "conv":                      # (.., w, CH)
            ch_ax = nd - 1
            if shape[ch_ax] % msize == 0 and msize > 1:
                spec[ch_ax] = MODEL_AXIS
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(kp, leaf) for kp, leaf in flat])


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
