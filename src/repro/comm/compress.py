"""``compressed(alg, codec)``: wrap any FedAlgorithm so its delta upload
goes through an upload codec, with optional client-resident error feedback.

Replaces ``repro.core.extensions.quantized`` (int8-only, no feedback).
The wrapper is algorithm-agnostic: the base algorithm's own state and
auxiliary upload entries (block-mean v, control variates, ...) pass
through untouched; only the ``delta`` entry is run through
``decode(encode(.))`` so the server averages exactly the values the wire
would carry, while :func:`repro.comm.upload_wire_bytes` costs the true
payload.

With error feedback on, the per-client residuals live in a
:class:`repro.state.ClientStateStore` table (policy:
``FedConfig.client_state_policy``) inside server state; the wrapper sets
``needs_client_ids`` and commits each sampled client's new residual row
through the algorithm ``commit`` hook — which the round engine drives in
BOTH placement layouts (vectorized under ``client_parallel``, one client
per scan step under ``client_sequential``). Everything stays
jit/vmap/scan-compatible: comm state is threaded through the client-state
dict and carried across the local-step scan unchanged.

Behavior change vs the legacy ``extensions.quantized``: the ``"+int8"``
algorithm suffix now gets error feedback by default, which improves the
trajectory but allocates the per-client residual table (``blockmean`` /
``int8`` store policies shrink it). Set
``FedConfig.comm_error_feedback=False`` for the old no-feedback
semantics; ``extensions.quantized`` itself keeps them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm.codecs import Codec
from repro.comm.error_feedback import (CID_KEY, COMM_STATE_KEYS, EF_KEY,
                                       ROUND_KEY)
from repro.core.fedadamw import FedAlgorithm
from repro.core.tree_util import tree_add, tree_sub
from repro.state import store_for


def _strip_comm(d: dict) -> dict:
    return {k: v for k, v in d.items() if k not in COMM_STATE_KEYS}


def _encode_key(round_index, client_id, target) -> jax.Array:
    """Per-(round, client) PRNG key, derived inside the trace: stochastic
    codecs need noise independent of the data and fresh each round, but
    the round engine threads no rng — so the wrapper keeps its own round
    counter in server state and folds it with the sampled client id
    (which both placement layouts now thread to every stochastic-codec
    client). The data-derived salt below is a documented FALLBACK only,
    for callers that invoke ``upload`` outside the round engine with no
    client id in scope: without it two clients holding equal-magnitude
    deltas would draw identical rounding noise and their quantization
    errors would correlate instead of averaging out."""
    key = jax.random.PRNGKey(0)  # ra: allow[RA101] THE sanctioned root; fold_in below
    if round_index is not None:
        key = jax.random.fold_in(key, round_index)
    if client_id is not None:
        key = jax.random.fold_in(key, client_id)
    else:
        total = sum(jnp.sum(jnp.abs(leaf).astype(jnp.float32))
                    for leaf in jax.tree.leaves(target))
        salt = jax.lax.bitcast_convert_type(total.astype(jnp.float32),
                                            jnp.int32)
        key = jax.random.fold_in(key, salt)
    return key


def compressed(alg: FedAlgorithm, codec: Codec, *,
               error_feedback: Optional[bool] = None,
               defer: bool = False) -> FedAlgorithm:
    """Route ``alg``'s delta upload through ``codec``.

    ``error_feedback=None`` enables feedback iff the codec is lossy.

    ``defer=True`` (set by ``FedConfig.use_pallas_uploadfuse``) skips the
    per-client clip/encode/decode in ``upload`` and ships the RAW delta
    plus the client's current residual row instead: the round engine
    runs the whole pipeline — fold, DP clip, quantize, decoded re-clip,
    weighted accumulate — in one fused Pallas pass over the stacked
    uploads (kernels/uploadfuse) and writes the new residual back into
    the upload dict before ``commit`` scatters it. State layout, wire
    accounting and the commit/server_update hooks are unchanged."""
    ef = codec.lossy if error_feedback is None else error_feedback
    # client ids are needed for the EF residual table AND for stochastic
    # codecs (per-client rounding noise decorrelation) — both layouts
    # provide them
    needs_ids = ef or alg.needs_client_ids or codec.stochastic

    def init_server(params, specs, fed):
        sstate = dict(alg.init_server(params, specs, fed))
        if ef:
            # per-client residual rows in the client-state store (dense:
            # num_clients f32 copies of the params, same footprint as
            # SCAFFOLD's control-variate table; blockmean/int8 shrink it)
            sstate[EF_KEY] = store_for(fed, specs).init()
        if codec.stochastic:
            sstate[ROUND_KEY] = jnp.zeros((), jnp.int32)
        return sstate

    def init_client(params, sstate, fed, specs=None, client_id=None):
        kw = {"specs": specs}
        if alg.needs_client_ids:
            kw["client_id"] = client_id
        cstate = dict(alg.init_client(params, sstate, fed, **kw))
        if ef:
            if client_id is None:
                raise ValueError(
                    f"{alg.name}+{codec.name} uses error feedback: "
                    "init_client needs the sampled client_id")
            cstate[EF_KEY] = store_for(fed, specs).gather(
                sstate[EF_KEY], client_id)
        if client_id is not None:
            cstate[CID_KEY] = jnp.asarray(client_id, jnp.int32)
        if codec.stochastic:
            cstate[ROUND_KEY] = sstate[ROUND_KEY]
        return cstate

    def local_step(params, grads, cstate, sstate, fed, lr_scale):
        comm = {k: cstate[k] for k in COMM_STATE_KEYS if k in cstate}
        params, new_c = alg.local_step(params, grads, _strip_comm(cstate),
                                       sstate, fed, lr_scale)
        new_c = dict(new_c)
        new_c.update(comm)
        return params, new_c

    def upload(delta, cstate, specs, fed):
        up = dict(alg.upload(delta, _strip_comm(cstate), specs, fed))
        if defer:
            # fused path: hand the engine the raw delta and the current
            # residual row; kernels/uploadfuse does the rest in-pass
            if ef:
                up[EF_KEY] = cstate[EF_KEY]
            return up
        target = tree_add(delta, cstate[EF_KEY]) if ef else delta
        if ef and fed.dp_clip > 0.0:
            # client-level DP + error feedback: the residual must fold
            # in BEFORE the clip — the codec then encodes a bounded
            # target (sensitivity holds) and the new residual tracks
            # exactly what went on the wire. (The incoming delta was
            # already clipped in local_phase; this re-clip bounds the
            # fold, it never enlarges anything.)
            from repro.privacy import clip_tree_by_l2
            target = clip_tree_by_l2(target, fed.dp_clip)
        key = (_encode_key(cstate.get(ROUND_KEY), cstate.get(CID_KEY),
                           target)
               # ra: allow[RA101] deterministic codecs ignore the key
               if codec.stochastic else jax.random.PRNGKey(0))
        decoded = codec.decode(codec.encode(target, key))
        decoded = jax.tree.map(lambda d, x: d.astype(x.dtype),
                               decoded, delta)
        if fed.dp_clip > 0.0:
            # the server aggregates the DECODED values, and lossy
            # codecs add per-coordinate quantization error AFTER the
            # clip — ||decoded|| can exceed dp_clip by O(scale*sqrt(d)),
            # which would silently break the sensitivity bound the DP
            # noise is calibrated to. Re-clip what actually ships; with
            # EF on, the clip error lands in the residual and is
            # re-sent like any other compression error.
            from repro.privacy import clip_tree_by_l2
            decoded = clip_tree_by_l2(decoded, fed.dp_clip)
        up["delta"] = decoded
        if ef:
            up[EF_KEY] = tree_sub(target, decoded)
        return up

    def commit(sstate, up, client_ids, specs, fed):
        new_sstate = dict(sstate)
        new_up = {k: v for k, v in up.items() if k != EF_KEY}
        if ef:
            new_sstate[EF_KEY] = store_for(fed, specs).scatter(
                sstate[EF_KEY], client_ids, up[EF_KEY])
        if alg.commit is not None:
            new_sstate, new_up = alg.commit(new_sstate, new_up, client_ids,
                                            specs, fed)
        return new_sstate, new_up

    def server_update(params, sstate, mean_up, specs, fed):
        # per-client rows were already committed; EF residuals never reach
        # the aggregation (commit strips them), so only guard against
        # direct callers that skip commit
        base_mean = {k: v for k, v in mean_up.items() if k != EF_KEY}
        new_params, new_sstate = alg.server_update(
            params, sstate, base_mean, specs, fed)
        new_sstate = dict(new_sstate)
        if ef:
            # base server_updates that rebuild their state dict (fedcm)
            # would drop the table
            new_sstate[EF_KEY] = sstate[EF_KEY]
        if codec.stochastic:
            new_sstate[ROUND_KEY] = sstate[ROUND_KEY] + 1
        return new_params, new_sstate

    return FedAlgorithm(f"{alg.name}+{codec.name}", init_server, init_client,
                        local_step, upload, server_update,
                        needs_client_ids=needs_ids,
                        commit=(commit if (ef or alg.commit is not None)
                                else None))
