"""Upload codec registry: what a client→server payload looks like on the wire.

A :class:`Codec` owns the three sides of the communication story:

``encode(tree, key) -> Encoded``
    Compress a parameter-delta pytree into a wire payload. The payload's
    array leaves have *exactly* the sizes that would be transferred (int4
    codes are physically packed two-per-byte, top-k carries only the kept
    values + indices), so byte accounting is a property of the payload
    spec, never a side estimate. ``key`` feeds stochastic codecs.

``decode(payload) -> tree``
    Reconstruct the dense tree the server averages. In simulation the
    client runs ``decode(encode(x))`` before upload so the aggregation
    sees exactly the values the wire would carry.

``wire_bytes(payload_spec) -> int``
    Exact transfer size of a payload (works on arrays or the
    ``jax.eval_shape`` spec — sizes are shape-static).

Codecs are looked up by *spec string*: ``none``, ``int8``, ``int4``,
``topk<ratio>`` (e.g. ``topk0.1``), ``lowrank<rank>`` (e.g. ``lowrank8``).
The spec doubles as the algorithm-name suffix (``fedadamw+int4``).

To add a codec: write ``encode_leaf/decode_leaf`` pair, lift with
:func:`leafwise_codec`, and :func:`register_codec` a parser for its spec.

Usage — round-trip a delta through int8 and price the wire exactly
(runs under ``python -m doctest``):

>>> import jax, jax.numpy as jnp
>>> from repro.comm.codecs import (get_codec, payload_wire_bytes,
...                                split_algorithm_name)
>>> codec = get_codec("int8")
>>> delta = {"w": jnp.linspace(-1.0, 1.0, 6)}
>>> enc = codec.encode(delta, jax.random.PRNGKey(0))
>>> payload_wire_bytes(enc)          # 6 int8 codes + one f32 scale
10
>>> approx = codec.decode(enc)       # what the server actually averages
>>> bool(jnp.max(jnp.abs(approx["w"] - delta["w"])) < 0.01)
True
>>> split_algorithm_name("fedadamw+topk0.1")   # the suffix convention
('fedadamw', 'topk0.1')
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any

_SCALE_FLOOR = 1e-12   # guards all-zero tensors (scale would divide by 0)
# f32-rounded reciprocals: a single multiply is bit-deterministic across
# the jnp and Pallas quantpack paths (see kernels/quantpack), so both
# produce identical wire payloads
_INV_QMAX8 = float(np.float32(1.0 / 127.0))
_INV_QMAX4 = float(np.float32(1.0 / 7.0))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Encoded:
    """Wire payload: per-leaf array dicts + static reconstruction metadata.

    ``data`` is a list of dict-of-arrays (one per leaf of the encoded
    tree, in flatten order); ``meta`` is static aux data (treedef plus
    per-leaf (shape, dtype)) so the payload traverses jit/eval_shape as a
    pytree whose only traced content is the wire arrays."""

    data: Any
    meta: Any

    def tree_flatten(self):
        return (self.data,), self.meta

    @classmethod
    def tree_unflatten(cls, meta, children):
        return cls(children[0], meta)


@dataclasses.dataclass(frozen=True)
class Codec:
    name: str                      # canonical spec string, e.g. "topk0.1"
    lossy: bool
    encode: Callable[[Tree, jax.Array], Encoded]
    decode: Callable[[Encoded], Tree]
    # True when encode() consumes the PRNG key (stochastic rounding);
    # deterministic codecs let callers pass a constant key for free.
    # lowrank uses its key only for the projection init, which is meant
    # to be reused across rounds (PowerSGD-style warm start) -> False.
    stochastic: bool = False

    def wire_bytes(self, payload_spec) -> int:
        return payload_wire_bytes(payload_spec)


def payload_wire_bytes(payload) -> int:
    """Exact bytes of a payload (arrays or ShapeDtypeStructs)."""
    return sum(int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(payload))


def leafwise_codec(name: str, lossy: bool, encode_leaf: Callable,
                   decode_leaf: Callable, *, stochastic: bool = False
                   ) -> Codec:
    """Lift per-leaf (encode, decode) into a tree codec.

    ``encode_leaf(x, key) -> dict_of_arrays``; ``decode_leaf(data, shape,
    dtype) -> x``. Each leaf gets an independent fold of the key."""

    def encode(tree: Tree, key: jax.Array) -> Encoded:
        leaves, treedef = jax.tree.flatten(tree)
        data = [encode_leaf(x, jax.random.fold_in(key, i))
                for i, x in enumerate(leaves)]
        meta = (treedef, tuple((x.shape, jnp.dtype(x.dtype).name)
                               for x in leaves))
        return Encoded(data, meta)

    def decode(payload: Encoded) -> Tree:
        treedef, shapes = payload.meta
        leaves = [decode_leaf(d, shape, jnp.dtype(dt))
                  for d, (shape, dt) in zip(payload.data, shapes)]
        return jax.tree.unflatten(treedef, leaves)

    return Codec(name, lossy, encode, decode, stochastic)


# ---------------------------------------------------------------------------
# none — dense passthrough (the uncompressed wire format)
# ---------------------------------------------------------------------------

def _none_codec() -> Codec:
    return leafwise_codec(
        "none", False,
        lambda x, key: {"values": x},
        lambda d, shape, dtype: d["values"])


# ---------------------------------------------------------------------------
# int8 — symmetric per-tensor scale, round-to-nearest
# ---------------------------------------------------------------------------

def _int8_scale(x32: jax.Array) -> jax.Array:
    return jnp.maximum(jnp.max(jnp.abs(x32)), _SCALE_FLOOR) * _INV_QMAX8


def _int8_encode_leaf(x, key):
    x32 = x.astype(jnp.float32).reshape(-1)
    scale = _int8_scale(x32)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _int8_decode_leaf(d, shape, dtype):
    return (d["q"].astype(jnp.float32) * d["scale"]).reshape(shape) \
        .astype(dtype)


# ---------------------------------------------------------------------------
# int4 — stochastic rounding, two codes packed per byte
# ---------------------------------------------------------------------------
# Wire format per tensor: ceil(n/2) bytes of codes (offset-8 nibbles,
# element 2i in the low nibble of byte i) + one f32 scale. Stochastic
# rounding q = floor(x/scale + u), u ~ U[0,1) is unbiased:
# E[q]*scale = x exactly, so the client-mean of int4 uploads is an
# unbiased estimate of the mean delta.

def pack_nibbles(codes: jax.Array) -> jax.Array:
    """uint8 codes in [0, 15], flat, even length -> half-length bytes."""
    pairs = codes.reshape(-1, 2)
    return (pairs[:, 0] | (pairs[:, 1] << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_nibbles`, sliced to the true element count."""
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=1).reshape(-1)[:n]


def _int4_scale(x32: jax.Array) -> jax.Array:
    return jnp.maximum(jnp.max(jnp.abs(x32)), _SCALE_FLOOR) * _INV_QMAX4


def _int4_encode_leaf(x, key):
    x32 = x.astype(jnp.float32).reshape(-1)
    n = x32.size
    scale = _int4_scale(x32)
    u = jax.random.uniform(key, (n,), jnp.float32)
    q = jnp.clip(jnp.floor(x32 / scale + u), -8, 7).astype(jnp.int32)
    codes = (q + 8).astype(jnp.uint8)
    if n % 2:
        codes = jnp.concatenate([codes, jnp.full((1,), 8, jnp.uint8)])
    return {"q": pack_nibbles(codes), "scale": scale}


def _int4_decode_leaf(d, shape, dtype):
    n = int(np.prod(shape)) if shape else 1
    codes = unpack_nibbles(d["q"], n)
    x = (codes.astype(jnp.int32) - 8).astype(jnp.float32) * d["scale"]
    return x.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# topk — magnitude sparsification (values + int32 indices)
# ---------------------------------------------------------------------------

def _topk_codec(ratio: float) -> Codec:
    if not 0.0 < ratio <= 1.0:
        raise ValueError(f"topk ratio must be in (0, 1], got {ratio}")

    def encode_leaf(x, key):
        x32 = x.astype(jnp.float32).reshape(-1)
        k = max(1, int(math.ceil(ratio * x32.size)))
        _, idx = jax.lax.top_k(jnp.abs(x32), k)
        return {"values": jnp.take(x32, idx), "indices": idx.astype(jnp.int32)}

    def decode_leaf(d, shape, dtype):
        n = int(np.prod(shape)) if shape else 1
        dense = jnp.zeros((n,), jnp.float32).at[d["indices"]].set(d["values"])
        return dense.reshape(shape).astype(dtype)

    return leafwise_codec(f"topk{ratio:g}", True, encode_leaf, decode_leaf)


# ---------------------------------------------------------------------------
# lowrank — per-2D-leaf truncated projection (PowerSGD-style single
# power iteration: P = orth(M Q0), Q = M^T P; wire carries P and Q)
# ---------------------------------------------------------------------------

def _lowrank_codec(rank: int) -> Codec:
    if rank < 1:
        raise ValueError(f"lowrank rank must be >= 1, got {rank}")

    def encode_leaf(x, key):
        if x.ndim != 2 or min(x.shape) <= rank:
            # too small to win from factorization: dense passthrough
            return {"values": x.astype(jnp.float32)}
        m, n = x.shape
        x32 = x.astype(jnp.float32)
        q0 = jax.random.normal(key, (n, rank), jnp.float32)
        p, _ = jnp.linalg.qr(x32 @ q0)
        return {"p": p, "q": x32.T @ p}

    def decode_leaf(d, shape, dtype):
        if "values" in d:
            return d["values"].astype(dtype)
        return (d["p"] @ d["q"].T).astype(dtype)

    return leafwise_codec(f"lowrank{rank}", True, encode_leaf, decode_leaf)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# name -> factory(arg_string_or_None) -> Codec; parameterized codecs parse
# the spec remainder ("topk0.1" -> factory("0.1"))
_REGISTRY: Dict[str, Callable[[Optional[str]], Codec]] = {}


def register_codec(name: str,
                   factory: Callable[[Optional[str]], Codec]) -> None:
    _REGISTRY[name] = factory


def _exact(name: str, arg: Optional[str], make: Callable[[], Codec]) -> Codec:
    if arg is not None:
        raise ValueError(f"codec {name!r} takes no parameter, got {arg!r}")
    return make()


def _pallas_quant_codec(name: str) -> Codec:
    """int8/int4 with encoding routed through the fused quantize-pack
    Pallas kernel (same math and wire format as the jnp path)."""
    from repro.kernels.quantpack import ops as qp_ops
    bits = {"int8": 8, "int4": 4}[name]
    decode = _int8_decode_leaf if bits == 8 else _int4_decode_leaf
    return leafwise_codec(
        name, True,
        lambda x, key: qp_ops.quantpack_leaf(x, bits=bits, key=key),
        decode, stochastic=(bits == 4))


register_codec("none", lambda arg: _exact("none", arg, _none_codec))
register_codec("int8", lambda arg: _exact("int8", arg, lambda: leafwise_codec(
    "int8", True, _int8_encode_leaf, _int8_decode_leaf)))
register_codec("int4", lambda arg: _exact("int4", arg, lambda: leafwise_codec(
    "int4", True, _int4_encode_leaf, _int4_decode_leaf, stochastic=True)))
register_codec("topk", lambda arg: _topk_codec(float(arg if arg else 0.1)))
register_codec("lowrank", lambda arg: _lowrank_codec(int(arg if arg else 4)))


def parse_codec_spec(spec: str, *, use_pallas: bool = False) -> Codec:
    """``"int4"`` / ``"topk0.1"`` / ``"lowrank8"`` -> Codec (ValueError on
    unknown). ``use_pallas`` routes int8/int4 encoding through the fused
    quantize-pack kernel (interpret mode off-TPU)."""
    for name in sorted(_REGISTRY, key=len, reverse=True):
        if spec == name or spec.startswith(name):
            arg = spec[len(name):] or None
            try:
                codec = _REGISTRY[name](arg)
            except ValueError as e:
                raise ValueError(f"bad codec spec {spec!r}: {e}") from e
            if use_pallas and name in ("int8", "int4"):
                codec = _pallas_quant_codec(name)
            return codec
    raise ValueError(
        f"unknown codec spec {spec!r}; known: {sorted(_REGISTRY)}")


def get_codec(spec: str, *, use_pallas: bool = False) -> Codec:
    return parse_codec_spec(spec, use_pallas=use_pallas)


def split_algorithm_name(name: str) -> tuple:
    """``"fedadamw+int4"`` -> ``("fedadamw", "int4")``; no suffix ->
    ``(name, None)``. The one place the suffix convention lives."""
    base, _, spec = name.partition("+")
    return base, (spec or None)


def codec_for(algorithm_name: str, *,
              use_pallas: bool = False) -> Optional[Codec]:
    """Codec named by an algorithm's ``+<codec>`` suffix, or None."""
    _, spec = split_algorithm_name(algorithm_name)
    return get_codec(spec, use_pallas=use_pallas) if spec else None


def upload_wire_bytes(upload_spec: Dict[str, Tree],
                      codec: Optional[Codec] = None) -> int:
    """True per-client transfer size of one upload pytree.

    ``delta`` is costed through the codec's wire payload; ``comm_ef``
    (error-feedback residual) is client-resident and never transferred;
    every other entry (block-mean v, control variates, ...) ships dense
    at its dtype size."""
    total = 0
    for name, sub in upload_spec.items():
        if name == "comm_ef":
            continue
        if name == "delta" and codec is not None and codec.name != "none":
            payload_spec = jax.eval_shape(  # ra: allow[RA101] abstract: sizes only
                lambda t: codec.encode(t, jax.random.PRNGKey(0)), sub)
            total += codec.wire_bytes(payload_spec)
        else:
            total += payload_wire_bytes(sub)
    return int(total)
