"""Client-resident error feedback for lossy upload codecs.

Each client i keeps a residual e_i across rounds and uploads the
compressed *compensated* delta (EF-SGD / EF21 family):

    target_i = delta_i + e_i
    wire_i   = decode(encode(target_i))
    e_i'     = target_i - wire_i

so the quantization/sparsification error is re-injected instead of lost —
the cumulative compression error stays bounded and lossy codecs track the
uncompressed trajectory.

In a real deployment e_i never leaves the client. This simulation keeps
the per-client residuals in a :class:`repro.state.ClientStateStore` table
inside server state (exactly how SCAFFOLD's per-client control variates
are kept), gathered per client id at round start and scattered back via
the algorithm ``commit`` hook in both placement layouts; the residual
rides the upload pytree only to reach that commit and is excluded from
wire accounting (:func:`repro.comm.upload_wire_bytes`).

The dense-table helpers below predate the store and remain as thin
dense-policy equivalents for external callers; new code should use
``repro.state.store_for(fed, specs)`` directly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any

# keys the compression wrapper threads through client state / uploads;
# never part of the base algorithm's own state
EF_KEY = "comm_ef"
CID_KEY = "comm_cid"
ROUND_KEY = "comm_round"
COMM_STATE_KEYS = (EF_KEY, CID_KEY, ROUND_KEY)


def init_ef_table(params: Tree, num_clients: int) -> Tree:
    """Zero residual table: one f32 copy of the params per client."""
    return jax.tree.map(
        lambda x: jnp.zeros((num_clients,) + x.shape, jnp.float32), params)


def client_residual(table: Tree, client_id) -> Tree:
    return jax.tree.map(lambda t: t[client_id], table)


def scatter_residuals(table: Tree, per_client_residuals: Tree,
                      client_ids) -> Tree:
    """Write the sampled clients' new residuals back into the table.

    ``per_client_residuals`` leaves carry a leading (S,) client axis (the
    vmapped uploads); ``client_ids`` is the matching (S,) index vector."""
    return jax.tree.map(
        lambda t, u: t.at[client_ids].set(u.astype(jnp.float32)),
        table, per_client_residuals)
