"""Communication layer: what goes on the wire, how it is compressed, and
what it costs (README.md §Communication layer).

``codecs``           wire-accurate upload codec registry
                     (none | int8 | int4 | topk<r> | lowrank<k>)
``error_feedback``   client-resident residual accumulation for lossy codecs
``compress``         ``compressed(alg, codec)`` FedAlgorithm wrapper
"""
from repro.comm.codecs import (
    Codec,
    Encoded,
    codec_for,
    get_codec,
    parse_codec_spec,
    payload_wire_bytes,
    register_codec,
    split_algorithm_name,
    upload_wire_bytes,
)
from repro.comm.compress import compressed
from repro.comm.error_feedback import (
    CID_KEY,
    COMM_STATE_KEYS,
    EF_KEY,
    ROUND_KEY,
    client_residual,
    init_ef_table,
    scatter_residuals,
)

__all__ = [
    "Codec", "Encoded", "codec_for", "get_codec", "parse_codec_spec",
    "payload_wire_bytes", "register_codec", "split_algorithm_name",
    "upload_wire_bytes",
    "compressed", "CID_KEY", "COMM_STATE_KEYS", "EF_KEY", "ROUND_KEY",
    "client_residual", "init_ef_table", "scatter_residuals",
]
