"""Per-round client sampling and batch assembly.

``round_batches`` builds the (S, K, batch, seq) pytree the round engine
scans/vmaps over: S sampled clients, K local steps, each step a fresh
mini-batch drawn from that client's own (non-iid) shard.

``RoundBatchGenerator`` wraps the two into a reusable deterministic
per-round stream so the pipelined driver (``repro.launch.pipeline``) can
assemble round r+1 on a background thread while round r computes, with
bit-identical data to the eager loop.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

import numpy as np

from repro.data.synthetic import SyntheticTask


def sample_clients(num_clients: int, clients_per_round: int,
                   rng: np.random.Generator) -> np.ndarray:
    return rng.choice(num_clients, size=clients_per_round, replace=False)


def round_batches(task: SyntheticTask, client_ids: np.ndarray,
                  local_steps: int, batch_size: int,
                  rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Returns {tokens, labels}: (S, K, batch, seq) int32 arrays."""
    s = len(client_ids)
    tok = np.empty((s, local_steps, batch_size, task.seq_len), np.int32)
    lab = np.empty_like(tok)
    for si, cid in enumerate(client_ids):
        for k in range(local_steps):
            b = task.client_batch(int(cid), batch_size, rng)
            tok[si, k] = b["tokens"]
            lab[si, k] = b["labels"]
    return {"tokens": tok, "labels": lab}


class RoundBatchGenerator:
    """Deterministic per-round ``(batches, client_ids)`` stream.

    One instance owns one ``np.random.Generator`` and consumes it in
    exactly the order of the eager seed loop (``sample_clients`` then
    ``round_batches``, once per round), so eager, host-prefetched, and
    multi-round-fused executions of the same seed see bit-identical
    data regardless of *when* each round's batch is assembled.
    """

    def __init__(self, task: SyntheticTask, *, num_clients: int,
                 clients_per_round: int, local_steps: int, batch_size: int,
                 rng: Union[np.random.Generator, int, None] = None):
        self.task = task
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.local_steps = local_steps
        self.batch_size = batch_size
        if not isinstance(rng, np.random.Generator):
            rng = np.random.default_rng(rng)
        self.rng = rng
        self.rounds_produced = 0

    def next_round(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """One round's ``({tokens, labels}: (S, K, b, seq)}, cids: (S,))``."""
        cids = sample_clients(self.num_clients, self.clients_per_round,
                              self.rng)
        batches = round_batches(self.task, cids, self.local_steps,
                                self.batch_size, self.rng)
        self.rounds_produced += 1
        return batches, cids.astype(np.int32)

    def next_rounds(self, m: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """``m`` consecutive rounds stacked on a new leading axis:
        ``({tokens, labels}: (M, S, K, b, seq)}, cids: (M, S))``.

        Implemented as exactly ``m`` calls of :meth:`next_round` so the
        rng stream — and therefore the data — matches per-round
        consumption by construction.
        """
        rounds = [self.next_round() for _ in range(m)]
        batches = {k: np.stack([b[k] for b, _ in rounds])
                   for k in rounds[0][0]}
        cids = np.stack([c for _, c in rounds])
        return batches, cids


def synthetic_round_batches(vocab_size: int, client_ids: np.ndarray,
                            local_steps: int, batch_size: int, seq_len: int,
                            rng: np.random.Generator
                            ) -> Dict[str, np.ndarray]:
    """Random-token batches (for perf/dry-run paths that never look at loss
    values, only shapes)."""
    s = len(client_ids)
    shape = (s, local_steps, batch_size, seq_len)
    tok = rng.integers(0, vocab_size, size=shape, dtype=np.int64).astype(np.int32)
    lab = np.roll(tok, -1, axis=-1)
    return {"tokens": tok, "labels": lab}
