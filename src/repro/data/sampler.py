"""Per-round client sampling and batch assembly.

``round_batches`` builds the (S, K, batch, seq) pytree the round engine
scans/vmaps over: S sampled clients, K local steps, each step a fresh
mini-batch drawn from that client's own (non-iid) shard.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.data.synthetic import SyntheticTask


def sample_clients(num_clients: int, clients_per_round: int,
                   rng: np.random.Generator) -> np.ndarray:
    return rng.choice(num_clients, size=clients_per_round, replace=False)


def round_batches(task: SyntheticTask, client_ids: np.ndarray,
                  local_steps: int, batch_size: int,
                  rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Returns {tokens, labels}: (S, K, batch, seq) int32 arrays."""
    s = len(client_ids)
    tok = np.empty((s, local_steps, batch_size, task.seq_len), np.int32)
    lab = np.empty_like(tok)
    for si, cid in enumerate(client_ids):
        for k in range(local_steps):
            b = task.client_batch(int(cid), batch_size, rng)
            tok[si, k] = b["tokens"]
            lab[si, k] = b["labels"]
    return {"tokens": tok, "labels": lab}


def synthetic_round_batches(vocab_size: int, client_ids: np.ndarray,
                            local_steps: int, batch_size: int, seq_len: int,
                            rng: np.random.Generator
                            ) -> Dict[str, np.ndarray]:
    """Random-token batches (for perf/dry-run paths that never look at loss
    values, only shapes)."""
    s = len(client_ids)
    shape = (s, local_steps, batch_size, seq_len)
    tok = rng.integers(0, vocab_size, size=shape, dtype=np.int64).astype(np.int32)
    lab = np.roll(tok, -1, axis=-1)
    return {"tokens": tok, "labels": lab}
