"""Per-round client sampling and batch assembly.

``round_batches`` builds the (S, K, batch, seq) pytree the round engine
scans/vmaps over: S sampled clients, K local steps, each step a fresh
mini-batch drawn from that client's own (non-iid) shard.

``RoundBatchGenerator`` wraps the two into a reusable deterministic
per-round stream so the pipelined driver (``repro.launch.pipeline``) can
assemble round r+1 on a background thread while round r computes, with
bit-identical data to the eager loop. Attach a
``repro.scenario.ParticipationScenario`` to drive availability-aware
sampling, straggler step masks, and aggregation weights through the same
stream (docs/scenarios.md).

Sampling strategies are a registry keyed by ``FedConfig.sampling``:

>>> sorted(SAMPLERS)
['available', 'uniform', 'weighted']
>>> import numpy as np
>>> rng = np.random.default_rng(0)
>>> cids = get_sampler("uniform")(8, 4, rng)
>>> sorted(set(int(c) for c in cids)) == sorted(int(c) for c in cids)
True
>>> avail = np.array([1, 1, 0, 0, 0, 0, 0, 0], dtype=bool)
>>> sorted(get_sampler("available")(8, 2, rng, available=avail).tolist())
[0, 1]
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np

from repro import telemetry
from repro.data.synthetic import SyntheticTask
from repro.scenario import ParticipationScenario


def validate_participation(num_clients: int, clients_per_round: int) -> None:
    """Actionable errors for impossible participation setups (the silent
    failure mode: ``Generator.choice(replace=False)`` raises a generic
    "larger sample than population" with no federated context)."""
    if num_clients < 1:
        raise ValueError(
            f"num_clients must be >= 1, got {num_clients}")
    if clients_per_round < 1:
        raise ValueError(
            f"clients_per_round must be >= 1, got {clients_per_round} "
            "(a federated round needs at least one participant)")
    if clients_per_round > num_clients:
        raise ValueError(
            f"clients_per_round={clients_per_round} exceeds "
            f"num_clients={num_clients}: a round samples clients WITHOUT "
            "replacement, so it cannot draw more distinct clients than "
            "exist. Lower clients_per_round (or raise num_clients).")


# ---------------------------------------------------------------------------
# sampling strategy registry
# ---------------------------------------------------------------------------
# A sampler picks the round's S participants from the N clients:
#   sampler(num_clients, clients_per_round, rng, *,
#           data_sizes=None, available=None) -> (S,) int ids
# It consumes `rng` (the generator's shared stream); `data_sizes` is the
# per-client sample count vector; `available` the availability mask.

Sampler = Callable[..., np.ndarray]
SAMPLERS: Dict[str, Sampler] = {}


def register_sampler(name: str, fn: Sampler) -> None:
    SAMPLERS[name] = fn


def get_sampler(name: str) -> Sampler:
    try:
        return SAMPLERS[name]
    except KeyError:
        raise ValueError(f"unknown sampling strategy {name!r}; "
                         f"known: {sorted(SAMPLERS)}") from None


def _uniform_sampler(num_clients: int, clients_per_round: int,
                     rng: np.random.Generator, *, data_sizes=None,
                     available=None) -> np.ndarray:
    """Uniform without replacement over ALL clients (the seed engine's
    sampler — availability is ignored, which models a server that assigns
    work blindly). Makes exactly one ``rng.choice`` call so the rng
    stream is byte-identical to the pre-scenario engine."""
    return rng.choice(num_clients, size=clients_per_round, replace=False)


def _weighted_sampler(num_clients: int, clients_per_round: int,
                      rng: np.random.Generator, *, data_sizes=None,
                      available=None) -> np.ndarray:
    """Data-size-weighted without replacement: clients with bigger shards
    are proportionally more likely to be picked."""
    if data_sizes is None:
        raise ValueError("sampling='weighted' needs per-client data sizes "
                         "(build the scenario from a task or pass "
                         "data_sizes=)")
    p = np.asarray(data_sizes, np.float64)
    if len(p) != num_clients or (p <= 0).any():
        raise ValueError("weighted sampling needs one positive data size "
                         f"per client (got {len(p)} sizes for "
                         f"{num_clients} clients)")
    return rng.choice(num_clients, size=clients_per_round, replace=False,
                      p=p / p.sum())


def _available_sampler(num_clients: int, clients_per_round: int,
                       rng: np.random.Generator, *, data_sizes=None,
                       available=None) -> np.ndarray:
    """Availability-constrained uniform: sample from this round's
    available set. When fewer than S clients are available the round is
    topped up uniformly from the unavailable set (the server waits for
    them) so the jitted round keeps its static S — the top-up keeps the
    semantics total rather than crashing mid-sweep on an unlucky round."""
    if available is None:
        available = np.ones(num_clients, dtype=bool)
    avail = np.flatnonzero(available)
    if len(avail) >= clients_per_round:
        pick = rng.choice(len(avail), size=clients_per_round, replace=False)
        return avail[pick]
    unavail = np.flatnonzero(~np.asarray(available, bool))
    need = clients_per_round - len(avail)
    fill = rng.choice(len(unavail), size=need, replace=False)
    return np.concatenate([avail, unavail[fill]])


register_sampler("uniform", _uniform_sampler)
register_sampler("weighted", _weighted_sampler)
register_sampler("available", _available_sampler)


def sample_clients(num_clients: int, clients_per_round: int,
                   rng: np.random.Generator) -> np.ndarray:
    validate_participation(num_clients, clients_per_round)
    return _uniform_sampler(num_clients, clients_per_round, rng)


def round_batches(task: SyntheticTask, client_ids: np.ndarray,
                  local_steps: int, batch_size: int,
                  rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Returns {tokens, labels}: (S, K, batch, seq) int32 arrays."""
    s = len(client_ids)
    tok = np.empty((s, local_steps, batch_size, task.seq_len), np.int32)
    lab = np.empty_like(tok)
    for si, cid in enumerate(client_ids):
        for k in range(local_steps):
            b = task.client_batch(int(cid), batch_size, rng)
            tok[si, k] = b["tokens"]
            lab[si, k] = b["labels"]
    return {"tokens": tok, "labels": lab}


class RoundBatchGenerator:
    """Deterministic per-round ``(batches, client_ids)`` stream.

    One instance owns one ``np.random.Generator`` and consumes it in
    exactly the order of the eager seed loop (client sampling then
    ``round_batches``, once per round), so eager, host-prefetched, and
    multi-round-fused executions of the same seed see bit-identical
    data regardless of *when* each round's batch is assembled.

    ``scenario`` (a ``repro.scenario.ParticipationScenario``) swaps in
    availability-aware sampling and attaches the straggler step mask and
    aggregation weights to the batch dict under the reserved keys; its
    availability/straggler processes draw from their own per-round seeded
    generators, NEVER from this stream, so attaching a degenerate
    scenario changes nothing — bit-exactness holds by construction.

    ``faults`` (a ``repro.faults.FaultModel``) rides the same pattern:
    its schedule is a pure function of ``(fault_seed, round_index)``
    under its own salt, attached under the reserved fault keys, so the
    data stream and the scenario processes are untouched and every
    execution mode sees the identical fault realization.
    """

    def __init__(self, task: SyntheticTask, *, num_clients: int,
                 clients_per_round: int, local_steps: int, batch_size: int,
                 rng: Union[np.random.Generator, int, None] = None,
                 scenario: Optional[ParticipationScenario] = None,
                 faults=None):
        validate_participation(num_clients, clients_per_round)
        self.task = task
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.local_steps = local_steps
        self.batch_size = batch_size
        if not isinstance(rng, np.random.Generator):
            rng = np.random.default_rng(rng)
        self.rng = rng
        self.scenario = scenario
        self.faults = faults if faults is not None and faults.active else None
        self.rounds_produced = 0

    def next_round(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """One round's ``({tokens, labels[, _step_mask, _agg_weights]}:
        (S, K, b, seq)}, cids: (S,))``."""
        r = self.rounds_produced
        with telemetry.span("sample"):
            if self.scenario is None:
                cids = sample_clients(self.num_clients,
                                      self.clients_per_round, self.rng)
            else:
                cids = self.scenario.sample_round(r, self.rng)
        telemetry.set_gauge("round/cohort_size", len(cids))
        batches = round_batches(self.task, cids, self.local_steps,
                                self.batch_size, self.rng)
        if self.scenario is not None:
            batches.update(self.scenario.round_payload(r, cids))
        if self.faults is not None:
            batches.update(self.faults.round_payload(r, cids))
        self.rounds_produced += 1
        return batches, cids.astype(np.int32)

    def next_rounds(self, m: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """``m`` consecutive rounds stacked on a new leading axis:
        ``({tokens, labels, ...}: (M, S, K, b, seq)}, cids: (M, S))``.

        Implemented as exactly ``m`` calls of :meth:`next_round` so the
        rng stream — and therefore the data — matches per-round
        consumption by construction. Scenario payload keys stack to
        ``(M, S, K)`` / ``(M, S)`` and scan apart inside the fused
        multi-round program.
        """
        rounds = [self.next_round() for _ in range(m)]
        batches = {k: np.stack([b[k] for b, _ in rounds])
                   for k in rounds[0][0]}
        cids = np.stack([c for _, c in rounds])
        return batches, cids


def synthetic_round_batches(vocab_size: int, client_ids: np.ndarray,
                            local_steps: int, batch_size: int, seq_len: int,
                            rng: np.random.Generator
                            ) -> Dict[str, np.ndarray]:
    """Random-token batches (for perf/dry-run paths that never look at loss
    values, only shapes)."""
    s = len(client_ids)
    shape = (s, local_steps, batch_size, seq_len)
    tok = rng.integers(0, vocab_size, size=shape, dtype=np.int64).astype(np.int32)
    lab = np.roll(tok, -1, axis=-1)
    return {"tokens": tok, "labels": lab}
