"""Synthetic non-iid federated tasks.

The container has no CIFAR-100/GLUE data (DESIGN.md §6 assumption change #1),
so the paper's experiments are reproduced *qualitatively* on synthetic tasks
whose client heterogeneity is controlled by the same Dirichlet(α) scheme
(Hsu et al. 2019) the paper uses: Dir-0.6 = low heterogeneity, Dir-0.1 =
high heterogeneity.

Two task kinds:

``class_lm``
    The CIFAR/ViT-Tiny analogue. Each sample is a token sequence drawn from
    a class-conditional Markov chain over a small vocabulary; the model must
    predict the class token at the final position (all other label positions
    are masked with -1). Dirichlet label skew partitions samples to clients.
    "Test accuracy" = final-position class accuracy on an iid held-out set.

``lm``
    A plain heterogeneous language-modeling task: each client owns a mixture
    of topic-specific bigram generators; Dirichlet(α) sets each client's
    topic mixture. Next-token loss everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

Array = np.ndarray


def dirichlet_label_partition(labels: Array, num_clients: int, alpha: float,
                              rng: np.random.Generator,
                              min_per_client: int = 2) -> List[np.ndarray]:
    """Hsu et al. (2019) Dirichlet partitioning: for each class, split its
    sample indices across clients with proportions ~ Dir(alpha)."""
    num_classes = int(labels.max()) + 1
    client_indices: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            client_indices[ci].extend(part.tolist())
    out = []
    for ci in range(num_clients):
        idx = np.asarray(client_indices[ci], dtype=np.int64)
        if len(idx) < min_per_client:  # give starved clients random samples
            extra = rng.integers(0, len(labels), size=min_per_client - len(idx))
            idx = np.concatenate([idx, extra])
        rng.shuffle(idx)
        out.append(idx)
    return out


@dataclasses.dataclass
class SyntheticTask:
    kind: str
    vocab_size: int
    seq_len: int
    num_clients: int
    tokens: Array                 # (n, seq) int32 — all training samples
    labels: Array                 # (n, seq) int32 — next-token targets, -1 masked
    client_indices: List[np.ndarray]
    test_tokens: Array
    test_labels: Array
    num_classes: int = 0

    def client_batch(self, client_id: int, batch_size: int,
                     rng: np.random.Generator) -> Dict[str, Array]:
        idx = self.client_indices[client_id]
        sel = idx[rng.integers(0, len(idx), size=batch_size)]
        return {"tokens": self.tokens[sel], "labels": self.labels[sel]}

    def test_batch(self, batch_size: int,
                   rng: Optional[np.random.Generator] = None) -> Dict[str, Array]:
        if rng is None:
            sel = np.arange(min(batch_size, len(self.test_tokens)))
        else:
            sel = rng.integers(0, len(self.test_tokens), size=batch_size)
        return {"tokens": self.test_tokens[sel], "labels": self.test_labels[sel]}

    def test_split_batches(self, batch_size: int) -> Dict[str, Array]:
        """The FULL test split as ``(nb, batch, seq)`` stacks for a jitted
        eval scan. The split is padded to a whole number of batches with
        rows whose labels are all -1 (every position masked), so padding
        contributes zero weight to any valid-count-weighted metric.
        Memoized per batch size — eval runs every few rounds on the same
        arrays."""
        cache = getattr(self, "_test_stack_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_test_stack_cache", cache)
        if batch_size not in cache:
            n = len(self.test_tokens)
            nb = max(1, -(-n // batch_size))
            pad = nb * batch_size - n
            tok = np.concatenate(
                [self.test_tokens,
                 np.zeros((pad, self.seq_len), np.int32)])
            lab = np.concatenate(
                [self.test_labels,
                 np.full((pad, self.seq_len), -1, np.int32)])
            cache[batch_size] = {
                "tokens": tok.reshape(nb, batch_size, self.seq_len),
                "labels": lab.reshape(nb, batch_size, self.seq_len)}
        return cache[batch_size]


def _class_markov_chains(num_classes: int, feat_vocab: int,
                         rng: np.random.Generator) -> Array:
    """Per-class bigram transition matrices, peaked differently per class."""
    trans = rng.dirichlet(np.full(feat_vocab, 0.3),
                          size=(num_classes, feat_vocab))
    return trans.astype(np.float64)


def _sample_chain(trans: Array, length: int, rng: np.random.Generator) -> Array:
    v = trans.shape[-1]
    out = np.empty(length, np.int32)
    s = rng.integers(0, v)
    for t in range(length):
        out[t] = s
        s = rng.choice(v, p=trans[s])
    return out


def make_task(kind: str = "class_lm", *, vocab_size: int = 64,
              seq_len: int = 32, num_samples: int = 4096,
              num_clients: int = 16, dirichlet_alpha: float = 0.6,
              num_classes: int = 10, num_topics: int = 8,
              seed: int = 0, test_fraction: float = 0.15) -> SyntheticTask:
    rng = np.random.default_rng(seed)

    if kind == "class_lm":
        # feature tokens occupy [0, vocab-num_classes); class tokens the rest
        feat_vocab = vocab_size - num_classes
        assert feat_vocab >= 8, "vocab too small for class_lm"
        trans = _class_markov_chains(num_classes, feat_vocab, rng)
        y = rng.integers(0, num_classes, size=num_samples)
        tokens = np.empty((num_samples, seq_len), np.int32)
        labels = np.full((num_samples, seq_len), -1, np.int32)
        for i in range(num_samples):
            tokens[i] = _sample_chain(trans[y[i]], seq_len, rng)
            labels[i, -1] = feat_vocab + y[i]  # class token target at the end
        n_test = int(num_samples * test_fraction)
        task_labels = y[n_test:]
        parts = dirichlet_label_partition(task_labels, num_clients,
                                          dirichlet_alpha, rng)
        return SyntheticTask(
            kind=kind, vocab_size=vocab_size, seq_len=seq_len,
            num_clients=num_clients,
            tokens=tokens[n_test:], labels=labels[n_test:],
            client_indices=parts,
            test_tokens=tokens[:n_test], test_labels=labels[:n_test],
            num_classes=num_classes)

    if kind == "lm":
        # topic-specific bigram LMs; client topic mixtures ~ Dir(alpha)
        trans = _class_markov_chains(num_topics, vocab_size, rng)
        mixtures = rng.dirichlet(np.full(num_topics, dirichlet_alpha),
                                 size=num_clients)
        per_client = num_samples // num_clients
        tokens = np.empty((num_clients * per_client, seq_len + 1), np.int32)
        owner = np.empty(num_clients * per_client, np.int64)
        row = 0
        for ci in range(num_clients):
            for _ in range(per_client):
                topic = rng.choice(num_topics, p=mixtures[ci])
                tokens[row] = _sample_chain(trans[topic], seq_len + 1, rng)
                owner[row] = ci
                row += 1
        inputs = tokens[:, :-1]
        targets = tokens[:, 1:].astype(np.int32)
        n_test = int(len(inputs) * test_fraction)
        test_sel = rng.choice(len(inputs), size=n_test, replace=False)
        test_mask = np.zeros(len(inputs), bool)
        test_mask[test_sel] = True
        parts = [np.flatnonzero((owner == ci) & ~test_mask)
                 for ci in range(num_clients)]
        parts = [p if len(p) > 1 else np.array([0, 1]) for p in parts]
        return SyntheticTask(
            kind=kind, vocab_size=vocab_size, seq_len=seq_len,
            num_clients=num_clients,
            tokens=inputs, labels=targets,
            client_indices=parts,
            test_tokens=inputs[test_mask], test_labels=targets[test_mask])

    raise ValueError(f"unknown task kind {kind!r}")
