"""Synthetic federated data pipeline (Dirichlet non-iid partitioning)."""
from repro.data.synthetic import SyntheticTask, make_task
from repro.data.sampler import (SAMPLERS, RoundBatchGenerator, get_sampler,
                                register_sampler, round_batches,
                                sample_clients, validate_participation)

__all__ = ["SyntheticTask", "make_task", "sample_clients", "round_batches",
           "RoundBatchGenerator", "SAMPLERS", "get_sampler",
           "register_sampler", "validate_participation"]
