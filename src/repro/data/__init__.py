"""Synthetic federated data pipeline (Dirichlet non-iid partitioning)."""
from repro.data.synthetic import SyntheticTask, make_task
from repro.data.sampler import (RoundBatchGenerator, round_batches,
                                sample_clients)

__all__ = ["SyntheticTask", "make_task", "sample_clients", "round_batches",
           "RoundBatchGenerator"]
