"""Model configuration dataclasses.

A single ``ModelConfig`` describes every architecture family in the assigned
pool: dense decoder-only Transformers (with GQA / qk-norm / QKV-bias /
non-parametric-LN variants), mixture-of-experts, Mamba2 SSD state-space
models, Zamba2-style hybrids, encoder-decoder (audio) stacks and VLM language
towers fed by stub modality frontends.

Configs are frozen dataclasses so they can be used as static (hashable)
arguments to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Attention block configuration (GQA by default)."""

    num_heads: int = 8
    num_kv_heads: int = 8               # kv_heads == num_heads -> MHA
    head_dim: Optional[int] = None      # default: d_model // num_heads
    qkv_bias: bool = False              # Qwen2-style bias on QKV projections
    qk_norm: bool = False               # Qwen3-style RMSNorm on per-head q,k
    sliding_window: Optional[int] = None  # None -> full causal attention
    rope_theta: float = 10000.0
    use_mrope: bool = False             # Qwen2-VL multimodal rotary embedding
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2

    def resolved_head_dim(self, d_model: int) -> int:
        return self.head_dim if self.head_dim is not None else d_model // self.num_heads


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: Optional[int] = None   # expert hidden size (default: ModelConfig.d_ff)
    capacity_factor: float = 1.25       # dispatch capacity per expert
    aux_loss_weight: float = 0.01       # load-balance auxiliary loss
    router_jitter: float = 0.0
    num_shared_experts: int = 0         # llama4-style always-on shared expert
    tokens_per_group: int = 512         # routing group size (bounds dispatch memory)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD, state-space duality) configuration."""

    state_dim: int = 128                # N: SSM state size
    head_dim: int = 64                  # P: channels per SSD head
    expand: int = 2                     # d_inner = expand * d_model
    chunk_size: int = 256               # SSD chunked-scan block length
    conv_width: int = 4                 # depthwise causal conv width
    ngroups: int = 1                    # B/C groups
    # cross-chunk recurrence: "scan" = sequential lax.scan over chunks
    # (the paper's formulation); "closed" = exact closed-form masked
    # decay-matrix einsum — no serial dependency, MXU-friendly, and it
    # removes the per-trip stacked-state traffic that dominates the train
    # memory roofline (EXPERIMENTS.md §Perf pair 2)
    cross_chunk: str = "closed"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Top-level architecture description."""

    name: str = "model"
    family: str = "dense"               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32000
    attention: AttentionConfig = dataclasses.field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    norm_type: str = "rmsnorm"          # rmsnorm | layernorm | nonparam_ln (OLMo)
    mlp_type: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # hybrid (zamba2): attention block shared across the stack, inserted
    # every `hybrid_attn_every` layers; the rest are Mamba2 blocks.
    hybrid_attn_every: int = 6
    hybrid_shared_attn: bool = True

    # encoder-decoder (audio / seamless): number of encoder layers (decoder
    # uses `num_layers`); cross-attention in every decoder block.
    encoder_layers: int = 0

    # modality frontend stubs (vlm/audio): dimensionality of precomputed
    # patch/frame embeddings consumed via a linear projector.
    frontend_embed_dim: int = 0
    frontend_tokens_per_sample: int = 0

    # attention implementation: "naive" materializes (b, h, s, s) scores;
    # "chunked" is an exact flash-style online-softmax over KV chunks (the
    # only way 32k+ sequences fit HBM); "auto" picks by sequence length.
    attn_impl: str = "auto"
    attn_chunk_threshold: int = 2048
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024

    # max positions for rotary tables / cache sizing sanity checks
    max_seq_len: int = 1 << 20

    # source citation for the config (paper / model card)
    source: str = ""

    # ----- sharding hints (consumed by repro.sharding.specs) ---------------
    # axis of attention projections sharded over the `model` mesh axis:
    # "heads" (column parallel, default) | "embed" (row parallel; for archs
    # whose head count does not divide the model axis, e.g. qwen2-vl 12H)
    attn_shard: str = "heads"
    # MoE expert weights: "ep" (experts over model axis) | "tp" (d_ff_expert
    # over model axis; for E < mesh model size, e.g. mixtral 8E on 16 chips)
    moe_shard: str = "ep"
    # FL placement layout this arch requires (see DESIGN.md §2)
    fl_layout: str = "client_parallel"  # client_parallel | client_sequential

    # ----- derived helpers -------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.attention.resolved_head_dim(self.d_model)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context_decode(self) -> bool:
        """True iff decode memory is sub-linear in context (SSM state) or the
        attention cache is windowed (sliding-window attention)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attention.sliding_window is not None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence of per-layer block kinds for the decoder stack."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "hybrid":
                if (i + 1) % self.hybrid_attn_every == 0:
                    kinds.append("attn")
                else:
                    kinds.append("ssm")
            elif self.family == "ssm":
                kinds.append("ssm")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def validate(self) -> None:
        a = self.attention
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm":
            if a.num_heads % a.num_kv_heads != 0:
                raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"{self.family} family requires SSMConfig")
        if self.family == "audio" and self.encoder_layers <= 0:
            raise ValueError("audio family requires encoder_layers > 0")
        if self.family in ("vlm", "audio") and self.frontend_embed_dim <= 0:
            raise ValueError("modality family requires frontend_embed_dim")


def reduced_variant(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
                    max_experts: int = 4) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 layers, d_model ≤ 512, ≤4 experts.

    Keeps the family-defining features (GQA ratio, qk_norm, biases, MoE top-k,
    SSM state, hybrid cadence, enc-dec, frontends) while shrinking dims.
    """
    a = cfg.attention
    # head count must divide d_model and keep head_dim even (RoPE halves)
    heads = 4 if a.num_heads >= 4 else 2
    # preserve "GQA vs MHA" character
    if a.num_kv_heads == a.num_heads:
        kv = heads
    else:
        kv = max(1, heads // max(1, a.num_heads // a.num_kv_heads))
    att = dataclasses.replace(
        a,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        sliding_window=min(a.sliding_window, 128) if a.sliding_window else None,
    )
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(max_experts, cfg.moe.num_experts),
            top_k=min(cfg.moe.top_k, min(max_experts, cfg.moe.num_experts)),
            d_ff_expert=d_model * 2,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_dim=min(cfg.ssm.state_dim, 32),
                                  head_dim=32, chunk_size=64)
    enc = min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        d_ff=d_model * 4,
        vocab_size=min(cfg.vocab_size, 1024),
        attention=att,
        moe=moe,
        ssm=ssm,
        hybrid_attn_every=2,
        encoder_layers=enc,
        frontend_embed_dim=min(cfg.frontend_embed_dim, 64) if cfg.frontend_embed_dim else 0,
        frontend_tokens_per_sample=min(cfg.frontend_tokens_per_sample, 16)
        if cfg.frontend_tokens_per_sample else 0,
        max_seq_len=4096,
    )
