"""Federated optimization configuration (FedAdamW and baselines).

Cross-field interaction rules live in one declarative table,
:data:`CONSTRAINTS`, read by BOTH :meth:`FedConfig.validate` and the
static analyzer (``repro.analysis``): validation raises the first
violated constraint's message; the analyzer uses the table to prove its
jaxpr-audit config matrix is legal and to enumerate the interaction
surface in docs. Adding a rule = adding one table row.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Hyperparameters of a federated optimization run.

    Defaults follow the paper's experimental section (Appendix C):
    lr 3e-4, weight decay 0.01, alpha 0.5, beta1 0.9, beta2 0.999,
    server lr (gamma) 1.0, K=50 local steps.
    """

    algorithm: str = "fedadamw"
    # fedadamw | fedavg | scaffold | fedcm | fedadam | fedlada
    # | local_adam | local_adamw | local_sgd (alias of fedavg)

    num_clients: int = 64              # N
    clients_per_round: int = 16        # S
    local_steps: int = 50              # K
    rounds: int = 100                  # R

    lr: float = 3e-4                   # local learning rate (eta)
    server_lr: float = 1.0             # gamma
    weight_decay: float = 0.01         # lambda (decoupled)
    alpha: float = 0.5                 # global-update correction strength
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    # FedAdamW aggregation strategy ablation (paper Table 7):
    # mean_v (ours, O(B)) | full_v (Agg-v, O(d)) | full_vm (Agg-vm, O(2d)) | none
    v_aggregation: str = "mean_v"

    # continuous time-step bias correction for v (Algorithm 2 keeps a global t)
    global_t_bias_correction: bool = True

    # ablation A3 (paper Table 4): couple the weight decay into the gradient
    # (Adam-style L2) instead of the decoupled AdamW form
    decoupled_wd: bool = True

    # baseline-specific
    fedcm_alpha: float = 0.1           # FedCM momentum mixing
    fedadam_tau: float = 1e-3          # FedAdam server adaptivity epsilon
    fedadam_server_lr: float = 1e-2
    fedlada_alpha: float = 0.5         # FedLADA mixing

    # block partition controls (Appendix D)
    min_block_size: int = 512
    max_blocks: int = 65536

    # per-client server-side state tables (SCAFFOLD control variates, EF
    # residuals — repro.state.ClientStateStore): how each client's row is
    # stored. dense (exact f32) | blockmean (per-Hessian-block means,
    # O(n_blocks)/client) | int8 (quantized rows, ~4x memory cut)
    client_state_policy: str = "dense"

    # placement: client_parallel | client_sequential (see DESIGN.md §2)
    layout: str = "client_parallel"
    # number of sequential client chunks when layout == client_sequential
    sequential_clients: int = 4

    use_pallas_update: bool = False    # route local update through the Pallas kernel

    # multi-round fusion (repro.launch.pipeline): lax.scan this many
    # consecutive rounds inside ONE jitted call over pre-staged batch
    # stacks, amortizing per-call dispatch/transfer overhead where small
    # models are launch-bound. 1 = one jitted call per round (seed
    # behavior). Trajectories are bit-identical for any value; blocks
    # never cross an eval boundary.
    rounds_per_call: int = 1

    # communication layer (repro.comm): algorithm names take an upload
    # codec suffix ("fedadamw+int4", "fedadamw+topk0.1", ...)
    comm_error_feedback: bool = True   # EF for lossy codecs (client_parallel)
    use_pallas_quantpack: bool = False  # fused quantize-pack kernel for int8/int4

    # --- participation scenario (repro.scenario, docs/scenarios.md):
    # system heterogeneity on top of the Dirichlet data heterogeneity.
    # The defaults describe the degenerate scenario (all clients always
    # available, uniform sampling + weights, every client runs K steps),
    # which is BIT-EXACT with the scenario-free engine.
    availability: str = "always_on"
    # always_on | bernoulli<rate>[:<concentration>] | trace[:<path.npy>]
    sampling: str = "uniform"
    # uniform | weighted (data-size) | available (availability-constrained)
    straggler_frac: float = 0.0        # fraction of clients that straggle
    straggler_min_steps: int = 1       # floor of a straggler's K_i
    agg_weighting: str = "uniform"     # uniform | data_size | inv_steps
    scenario_seed: int = 0             # availability/straggler rng seed

    # --- client-level differential privacy (repro.privacy,
    # docs/privacy.md): per-client L2 clipping of every aggregated upload
    # entry (applied in core.rounds BEFORE codec compression, both
    # layouts) plus seeded Gaussian noise on the post-aggregation mean,
    # keyed on (dp_seed, round_index) so eager/prefetched/fused execution
    # stay bit-identical. dp_clip == 0 disables DP entirely (statically
    # gated: the traced program is the pre-privacy engine, bit-exact).
    dp_clip: float = 0.0               # C: per-client L2 bound (0 = off)
    dp_noise_multiplier: float = 0.0   # sigma: noise std = sigma*C on the sum
    target_epsilon: float = 0.0        # invert into sigma at config time
    #   (privacy.resolve_dp_noise; mutually exclusive with a nonzero
    #   dp_noise_multiplier)
    dp_delta: float = 1e-5             # delta of the (eps, delta) guarantee
    dp_seed: int = 0                   # server noise seed
    use_pallas_clipacc: bool = False   # fused clip+accumulate kernel for the
    #   delta entry (client_parallel, codec-free DP runs)
    use_pallas_uploadfuse: bool = False  # one-pass upload megakernel:
    #   error-feedback fold + DP clip + int8/int4 quantize + decoded
    #   re-clip + weighted accumulate in a single read of the stacked
    #   upload (kernels/uploadfuse, docs/kernels.md). Works in BOTH
    #   layouts and composes DP with the int8/int4 codecs — the
    #   combinations clipacc cannot fuse. fault_drop rides the kernel's
    #   accumulation weights; corruption faults and robust_agg defenses
    #   need the unfused path (see the uploadfuse-* constraint rows).

    # --- fault injection + defense (repro.faults, docs/faults.md):
    # post-sampling failure modes and the server-side guard rails.
    # Injection probabilities are per (round, client), drawn from
    # (fault_seed, round_index)-keyed rngs host-side; all zeros (the
    # default) emits no reserved batch keys and traces the exact
    # fault-free round program. The defense (robust_agg != "none") is
    # statically gated the same way: none | mean | trimmed<f> |
    # coordinate_median | norm_filter (rank-based entries are
    # client_parallel-only; the sequential scan supports "mean").
    fault_drop: float = 0.0            # P[upload never arrives]
    fault_nan: float = 0.0             # P[upload is NaN-corrupted]
    fault_scale: float = 0.0           # P[upload norm-inflated]
    fault_scale_factor: float = 1e3    # the inflation factor
    fault_seed: int = 0                # fault schedule rng seed
    robust_agg: str = "none"           # defense registry entry
    robust_norm_mult: float = 5.0      # norm_filter: reject clients with
    #   joint upload norm > this multiple of the finite-client median
    min_quorum: int = 0                # a round with fewer surviving
    #   uploads commits NO state change (0 = quorum off); the round
    #   index and every rng stream still advance

    # --- telemetry (repro.telemetry, docs/observability.md): opt-in
    # device-side diagnostics — per-round client-drift RMS and v̄
    # cross-client variance (the paper's Figure-2 quantities) computed
    # from scalar accumulators inside the round program and drained via
    # the normal metrics path. Off (default) is statically gated: no
    # metric keys are added and the traced program is byte-identical to
    # the pre-telemetry engine. Host-side tracing/counters are NOT
    # controlled here — they live outside the jitted program entirely.
    telemetry_diagnostics: bool = False

    # opt-in per-client flight recorder (repro.telemetry.ledger,
    # docs/observability.md): every round emits an (S, n_stats) stats
    # block — participation, executed steps, upload L2, drift
    # contribution, DP clip activation, wire arrival, fault/defense
    # verdicts — riding the MetricsSpool like any other metric, drained
    # at eval boundaries and spilled as npz + manifest by the launcher.
    # Off (default) is statically gated exactly like the diagnostics:
    # byte-identical traced program, no extra keys.
    telemetry_ledger: bool = False

    # gradient micro-batching inside each local step: the per-step batch is
    # split into this many chunks whose gradients are accumulated (identical
    # semantics — the mean of micro-gradients IS the batch gradient) so the
    # activation working set shrinks by the same factor. Required to fit the
    # >30B architectures' train_4k shape in 16 GB HBM (EXPERIMENTS.md
    # §Dry-run memory iteration).
    grad_microbatches: int = 1

    def validate(self) -> None:
        # lazy import: the comm layer depends on this config module
        from repro.comm.codecs import split_algorithm_name
        base, codec_spec = split_algorithm_name(self.algorithm)
        if base not in (
            "fedadamw", "fedavg", "scaffold", "fedcm", "fedadam", "fedlada",
            "local_adam", "local_adamw", "local_sgd",
            "fedlamb", "fedlion",  # beyond-paper (paper conclusion)
        ):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if codec_spec:
            # raises ValueError on unknown codec specs
            from repro.comm.codecs import parse_codec_spec
            parse_codec_spec(codec_spec)
        if self.v_aggregation not in ("mean_v", "full_v", "full_vm", "none"):
            raise ValueError(f"unknown v_aggregation {self.v_aggregation!r}")
        if self.layout not in ("client_parallel", "client_sequential"):
            raise ValueError(f"unknown layout {self.layout!r}")
        if self.client_state_policy not in ("dense", "blockmean", "int8"):
            raise ValueError(
                f"unknown client_state_policy {self.client_state_policy!r}")
        self._validate_participation()
        # domain check BEFORE the constraint table so cross-field rows
        # may assume the spec parses (lazy import: faults depends on
        # nothing here, the config stays the bottom layer)
        from repro.faults.defense import parse_robust_agg
        parse_robust_agg(self.robust_agg)
        for c in CONSTRAINTS:
            msg = c.check(self, codec_spec)
            if msg is not None:
                raise ValueError(msg)

    def dp_enabled(self) -> bool:
        """Client-level DP is on iff a finite clip norm is set."""
        return self.dp_clip > 0.0

    def faults_enabled(self) -> bool:
        """Any fault process has nonzero probability (the batch stream
        then carries the reserved fault keys)."""
        return (self.fault_drop > 0.0 or self.fault_nan > 0.0
                or self.fault_scale > 0.0)

    def defense_enabled(self) -> bool:
        """The upload validator + robust aggregation are traced in."""
        return self.robust_agg != "none"

    def _validate_participation(self) -> None:
        """Participation / scenario DOMAIN checks — value must name a
        known sampler/availability/weight scheme (the raw numpy failure
        for S > N is a generic 'larger sample than population' with no
        federated context). Range and cross-field rules live in
        :data:`CONSTRAINTS`."""
        from repro.data.sampler import get_sampler, validate_participation
        validate_participation(self.num_clients, self.clients_per_round)
        # raises ValueError with the known-spec list on a bad spec; the
        # trace path is validated when the schedule is actually loaded
        from repro.scenario.availability import parse_availability
        if not self.availability.startswith("trace"):
            parse_availability(self.availability, self.num_clients)
        get_sampler(self.sampling)
        from repro.scenario.weights import WEIGHT_SCHEMES
        if self.agg_weighting not in WEIGHT_SCHEMES:
            raise ValueError(
                f"unknown agg_weighting {self.agg_weighting!r}; "
                f"known: {WEIGHT_SCHEMES}")


# --------------------------------------------------------------- constraints
#
# The declarative cross-field rule table. One row per invariant; a row's
# ``check(cfg, codec_spec)`` returns None when satisfied or the full
# actionable error message when violated. ``FedConfig.validate`` raises
# the first violation; ``repro.analysis`` imports the table to validate
# its audit-matrix configs and to document the interaction surface.

@dataclasses.dataclass(frozen=True)
class Constraint:
    name: str                      # stable slug (docs, analyzer reports)
    fields: Tuple[str, ...]        # config fields the rule reads
    check: Callable[["FedConfig", str], Optional[str]]


def _c(name, fields, fn):
    return Constraint(name=name, fields=tuple(fields), check=fn)


def _robust_kind(cfg: "FedConfig") -> str:
    """Parsed defense registry entry ('none' | 'mean' | 'trimmed' |
    'coordinate_median' | 'norm_filter'); validate() runs the domain
    check before the table, so this never raises inside a row."""
    from repro.faults.defense import parse_robust_agg
    return parse_robust_agg(cfg.robust_agg)[0]


CONSTRAINTS: Tuple[Constraint, ...] = (
    _c("rounds-per-call-min", ("rounds_per_call",),
       lambda c, s: None if c.rounds_per_call >= 1 else
       "rounds_per_call must be >= 1"),
    _c("sequential-clients-min", ("sequential_clients", "layout"),
       lambda c, s: None if (c.layout != "client_sequential"
                             or c.sequential_clients >= 1) else
       f"sequential_clients must be >= 1, got {c.sequential_clients}"),
    _c("grad-microbatches-min", ("grad_microbatches",),
       lambda c, s: None if c.grad_microbatches >= 1 else
       f"grad_microbatches must be >= 1, got {c.grad_microbatches}"),
    _c("local-steps-min", ("local_steps",),
       lambda c, s: None if c.local_steps >= 1 else
       f"local_steps must be >= 1, got {c.local_steps} "
       "(each sampled client runs at least one local step)"),
    _c("rounds-min", ("rounds",),
       lambda c, s: None if c.rounds >= 1 else
       f"rounds must be >= 1, got {c.rounds}"),
    _c("straggler-frac-range", ("straggler_frac",),
       lambda c, s: None if 0.0 <= c.straggler_frac <= 1.0 else
       f"straggler_frac must be in [0, 1], got {c.straggler_frac}"),
    _c("straggler-min-steps-range", ("straggler_min_steps", "local_steps"),
       lambda c, s: None
       if 1 <= c.straggler_min_steps <= c.local_steps else
       f"straggler_min_steps must be in [1, local_steps={c.local_steps}], "
       f"got {c.straggler_min_steps} "
       "(a participating client always applies its first step)"),
    _c("dp-clip-nonneg", ("dp_clip",),
       lambda c, s: None if c.dp_clip >= 0.0 else
       f"dp_clip must be >= 0, got {c.dp_clip} (0 disables DP; a "
       "positive value is the per-client L2 bound)"),
    _c("dp-noise-nonneg", ("dp_noise_multiplier",),
       lambda c, s: None if c.dp_noise_multiplier >= 0.0 else
       f"dp_noise_multiplier must be >= 0, got {c.dp_noise_multiplier}"),
    _c("dp-epsilon-nonneg", ("target_epsilon",),
       lambda c, s: None if c.target_epsilon >= 0.0 else
       f"target_epsilon must be >= 0, got {c.target_epsilon}"),
    _c("dp-delta-range", ("dp_delta",),
       lambda c, s: None if 0.0 < c.dp_delta < 1.0 else
       f"dp_delta must be in (0, 1), got {c.dp_delta} "
       "(convention: well below 1/num_clients)"),
    _c("dp-noise-requires-clip",
       ("dp_noise_multiplier", "target_epsilon", "dp_clip"),
       lambda c, s: None
       if not (c.dp_noise_multiplier > 0.0 or c.target_epsilon > 0.0)
       or c.dp_clip > 0.0 else
       "DP noise is calibrated to the clip bound: dp_noise_multiplier / "
       "target_epsilon require dp_clip > 0 (set the per-client L2 clip "
       "norm)"),
    _c("dp-sigma-xor-epsilon", ("dp_noise_multiplier", "target_epsilon"),
       lambda c, s: None
       if not (c.dp_noise_multiplier > 0.0 and c.target_epsilon > 0.0)
       else "set EITHER dp_noise_multiplier (explicit sigma) OR "
       "target_epsilon (inverted into sigma by "
       "repro.privacy.resolve_dp_noise at launch), not both"),
    _c("dp-uniform-weighting", ("dp_clip", "agg_weighting"),
       lambda c, s: None
       if not c.dp_enabled() or c.agg_weighting == "uniform" else
       f"client-level DP calibrates noise to the UNIFORM mean's "
       f"sensitivity dp_clip/S; agg_weighting={c.agg_weighting!r} gives "
       "individual clients larger aggregation weight and breaks that "
       "bound. Set agg_weighting='uniform' (stragglers/availability "
       "remain fine)."),
    _c("clipacc-requires-dp", ("use_pallas_clipacc", "dp_clip"),
       lambda c, s: None if not c.use_pallas_clipacc or c.dp_enabled()
       else "use_pallas_clipacc fuses the DP clip into the aggregation: "
       "it requires dp_clip > 0"),
    _c("clipacc-parallel-only", ("use_pallas_clipacc", "layout"),
       lambda c, s: None
       if not c.use_pallas_clipacc or c.layout == "client_parallel" else
       "use_pallas_clipacc operates on the stacked (S, ...) upload of "
       "the client_parallel layout; client_sequential aggregates one "
       "client at a time inside a scan. Set use_pallas_uploadfuse "
       "instead — the fused upload kernel runs in both layouts"),
    _c("clipacc-no-codec", ("use_pallas_clipacc", "algorithm"),
       lambda c, s: None if not (c.use_pallas_clipacc and s) else
       f"use_pallas_clipacc is incompatible with upload codec {s!r}: DP "
       "clipping must happen BEFORE codec compression (the codec must "
       "encode the bounded values), but the fused kernel clips at "
       "aggregation time, after decode. Set use_pallas_uploadfuse "
       "instead — the fused upload kernel clips before it quantizes, so "
       "DP composes with the int8/int4 codecs on the fast path."),
    _c("uploadfuse-codec-kind", ("use_pallas_uploadfuse", "algorithm"),
       lambda c, s: None
       if not c.use_pallas_uploadfuse or not s or s in ("int8", "int4")
       else
       f"use_pallas_uploadfuse fuses the int8/int4 quantize-pack (or no "
       f"codec suffix at all); codec {s!r} reshapes the payload (sparse "
       "indices / low-rank factors) and cannot ride the fused pass. "
       "Drop the flag for this codec."),
    _c("uploadfuse-xor-clipacc",
       ("use_pallas_uploadfuse", "use_pallas_clipacc"),
       lambda c, s: None
       if not (c.use_pallas_uploadfuse and c.use_pallas_clipacc) else
       "use_pallas_uploadfuse subsumes use_pallas_clipacc (the upload "
       "megakernel clips and accumulates in the same pass); enable only "
       "one of the two"),
    _c("uploadfuse-no-corruption",
       ("use_pallas_uploadfuse", "fault_nan", "fault_scale"),
       lambda c, s: None
       if not c.use_pallas_uploadfuse
       or (c.fault_nan == 0.0 and c.fault_scale == 0.0) else
       "use_pallas_uploadfuse aggregates decoded uploads inside the "
       "kernel, so wire corruption (fault_nan / fault_scale) has no "
       "materialized upload stack to land on; only fault_drop (masked "
       "accumulation weights) rides the fused path. Disable the kernel "
       "for corruption-fault experiments."),
    _c("uploadfuse-no-defense", ("use_pallas_uploadfuse", "robust_agg"),
       lambda c, s: None
       if not c.use_pallas_uploadfuse or not c.defense_enabled() else
       f"use_pallas_uploadfuse folds dropped-upload masking into its "
       f"accumulation weights; robust_agg={c.robust_agg!r} screens a "
       "materialized upload stack the fused kernel never builds. Set "
       "robust_agg='none' or disable the kernel."),
    _c("uploadfuse-sequential-no-drop",
       ("use_pallas_uploadfuse", "layout", "fault_drop"),
       lambda c, s: None
       if not c.use_pallas_uploadfuse or c.layout == "client_parallel"
       or c.fault_drop == 0.0 else
       "use_pallas_uploadfuse under client_sequential pre-weights each "
       "client's fused contribution inside the scan and cannot "
       "renormalize the mean over surviving uploads; run fault_drop "
       "experiments in client_parallel"),
    _c("fault-prob-range", ("fault_drop", "fault_nan", "fault_scale"),
       lambda c, s: next(
           (f"{n} must be a probability in [0, 1], got {p}"
            for n, p in (("fault_drop", c.fault_drop),
                         ("fault_nan", c.fault_nan),
                         ("fault_scale", c.fault_scale))
            if not 0.0 <= p <= 1.0), None)),
    _c("fault-scale-factor-positive", ("fault_scale_factor",),
       lambda c, s: None if c.fault_scale_factor > 0.0 else
       f"fault_scale_factor must be > 0, got {c.fault_scale_factor} "
       "(it multiplies a faulty client's upload norm)"),
    _c("min-quorum-range", ("min_quorum", "clients_per_round"),
       lambda c, s: None
       if 0 <= c.min_quorum <= c.clients_per_round else
       f"min_quorum must be in [0, clients_per_round="
       f"{c.clients_per_round}], got {c.min_quorum} (a round can never "
       "have more surviving uploads than sampled clients, so a larger "
       "quorum would skip every round)"),
    _c("quorum-requires-defense", ("min_quorum", "robust_agg"),
       lambda c, s: None
       if c.min_quorum == 0 or c.defense_enabled() else
       f"min_quorum={c.min_quorum} needs the upload validator to count "
       "survivors: set robust_agg (e.g. 'mean' just validates + masks, "
       "'norm_filter' also screens norm outliers)"),
    _c("robust-rank-parallel-only", ("robust_agg", "layout"),
       lambda c, s: None
       if _robust_kind(c) in ("none", "mean")
       or c.layout == "client_parallel" else
       f"robust_agg={c.robust_agg!r} reduces across the full stacked "
       "(S, ...) upload (rank statistics / the cross-client norm "
       "median); client_sequential accumulates one client at a time "
       "inside a scan and never materializes that stack. Use "
       "robust_agg='mean' there (per-client validity folds into the "
       "online accumulation) or layout='client_parallel'."),
    _c("robust-rank-uniform-weights", ("robust_agg", "agg_weighting"),
       lambda c, s: None
       if _robust_kind(c) not in ("trimmed", "coordinate_median")
       or c.agg_weighting == "uniform" else
       f"robust_agg={c.robust_agg!r} is a rank statistic and ignores "
       f"aggregation weights; agg_weighting={c.agg_weighting!r} would "
       "be silently dropped. Set agg_weighting='uniform' (or use "
       "robust_agg='mean'/'norm_filter', which weight the survivors)."),
    _c("dp-robust-mean-compatible", ("dp_clip", "robust_agg"),
       lambda c, s: None
       if not c.dp_enabled()
       or _robust_kind(c) in ("none", "mean", "norm_filter") else
       f"client-level DP calibrates noise to the MEAN's sensitivity "
       f"dp_clip/S; robust_agg={c.robust_agg!r} releases a rank "
       "statistic whose sensitivity that bound does not cover. Use "
       "robust_agg='mean' or 'norm_filter' with DP (the engine then "
       "scales noise to the surviving cohort)."),
    _c("clipacc-no-faults",
       ("use_pallas_clipacc", "robust_agg", "fault_drop", "fault_nan",
        "fault_scale"),
       lambda c, s: None
       if not c.use_pallas_clipacc
       or not (c.defense_enabled() or c.faults_enabled()) else
       "use_pallas_clipacc fuses a UNIFORM clip+accumulate over the "
       "client stack and cannot mask rejected/faulted uploads; disable "
       "the kernel to use fault injection or a robust_agg defense"),
)
