"""Federated optimization configuration (FedAdamW and baselines)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Hyperparameters of a federated optimization run.

    Defaults follow the paper's experimental section (Appendix C):
    lr 3e-4, weight decay 0.01, alpha 0.5, beta1 0.9, beta2 0.999,
    server lr (gamma) 1.0, K=50 local steps.
    """

    algorithm: str = "fedadamw"
    # fedadamw | fedavg | scaffold | fedcm | fedadam | fedlada
    # | local_adam | local_adamw | local_sgd (alias of fedavg)

    num_clients: int = 64              # N
    clients_per_round: int = 16        # S
    local_steps: int = 50              # K
    rounds: int = 100                  # R

    lr: float = 3e-4                   # local learning rate (eta)
    server_lr: float = 1.0             # gamma
    weight_decay: float = 0.01         # lambda (decoupled)
    alpha: float = 0.5                 # global-update correction strength
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    # FedAdamW aggregation strategy ablation (paper Table 7):
    # mean_v (ours, O(B)) | full_v (Agg-v, O(d)) | full_vm (Agg-vm, O(2d)) | none
    v_aggregation: str = "mean_v"

    # continuous time-step bias correction for v (Algorithm 2 keeps a global t)
    global_t_bias_correction: bool = True

    # ablation A3 (paper Table 4): couple the weight decay into the gradient
    # (Adam-style L2) instead of the decoupled AdamW form
    decoupled_wd: bool = True

    # baseline-specific
    fedcm_alpha: float = 0.1           # FedCM momentum mixing
    fedadam_tau: float = 1e-3          # FedAdam server adaptivity epsilon
    fedadam_server_lr: float = 1e-2
    fedlada_alpha: float = 0.5         # FedLADA mixing

    # block partition controls (Appendix D)
    min_block_size: int = 512
    max_blocks: int = 65536

    # per-client server-side state tables (SCAFFOLD control variates, EF
    # residuals — repro.state.ClientStateStore): how each client's row is
    # stored. dense (exact f32) | blockmean (per-Hessian-block means,
    # O(n_blocks)/client) | int8 (quantized rows, ~4x memory cut)
    client_state_policy: str = "dense"

    # placement: client_parallel | client_sequential (see DESIGN.md §2)
    layout: str = "client_parallel"
    # number of sequential client chunks when layout == client_sequential
    sequential_clients: int = 4

    use_pallas_update: bool = False    # route local update through the Pallas kernel

    # multi-round fusion (repro.launch.pipeline): lax.scan this many
    # consecutive rounds inside ONE jitted call over pre-staged batch
    # stacks, amortizing per-call dispatch/transfer overhead where small
    # models are launch-bound. 1 = one jitted call per round (seed
    # behavior). Trajectories are bit-identical for any value; blocks
    # never cross an eval boundary.
    rounds_per_call: int = 1

    # communication layer (repro.comm): algorithm names take an upload
    # codec suffix ("fedadamw+int4", "fedadamw+topk0.1", ...)
    comm_error_feedback: bool = True   # EF for lossy codecs (client_parallel)
    use_pallas_quantpack: bool = False  # fused quantize-pack kernel for int8/int4

    # --- participation scenario (repro.scenario, docs/scenarios.md):
    # system heterogeneity on top of the Dirichlet data heterogeneity.
    # The defaults describe the degenerate scenario (all clients always
    # available, uniform sampling + weights, every client runs K steps),
    # which is BIT-EXACT with the scenario-free engine.
    availability: str = "always_on"
    # always_on | bernoulli<rate>[:<concentration>] | trace[:<path.npy>]
    sampling: str = "uniform"
    # uniform | weighted (data-size) | available (availability-constrained)
    straggler_frac: float = 0.0        # fraction of clients that straggle
    straggler_min_steps: int = 1       # floor of a straggler's K_i
    agg_weighting: str = "uniform"     # uniform | data_size | inv_steps
    scenario_seed: int = 0             # availability/straggler rng seed

    # gradient micro-batching inside each local step: the per-step batch is
    # split into this many chunks whose gradients are accumulated (identical
    # semantics — the mean of micro-gradients IS the batch gradient) so the
    # activation working set shrinks by the same factor. Required to fit the
    # >30B architectures' train_4k shape in 16 GB HBM (EXPERIMENTS.md
    # §Dry-run memory iteration).
    grad_microbatches: int = 1

    def validate(self) -> None:
        # lazy import: the comm layer depends on this config module
        from repro.comm.codecs import split_algorithm_name
        base, codec_spec = split_algorithm_name(self.algorithm)
        if base not in (
            "fedadamw", "fedavg", "scaffold", "fedcm", "fedadam", "fedlada",
            "local_adam", "local_adamw", "local_sgd",
            "fedlamb", "fedlion",  # beyond-paper (paper conclusion)
        ):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if codec_spec:
            # raises ValueError on unknown codec specs
            from repro.comm.codecs import parse_codec_spec
            parse_codec_spec(codec_spec)
        if self.v_aggregation not in ("mean_v", "full_v", "full_vm", "none"):
            raise ValueError(f"unknown v_aggregation {self.v_aggregation!r}")
        if self.layout not in ("client_parallel", "client_sequential"):
            raise ValueError(f"unknown layout {self.layout!r}")
        if self.client_state_policy not in ("dense", "blockmean", "int8"):
            raise ValueError(
                f"unknown client_state_policy {self.client_state_policy!r}")
        if self.rounds_per_call < 1:
            raise ValueError("rounds_per_call must be >= 1")
        self._validate_participation()

    def _validate_participation(self) -> None:
        """Participation / scenario fields, with actionable messages (the
        raw numpy failure for S > N is a generic 'larger sample than
        population' with no federated context; worse, several fields used
        to pass through unchecked and only blew up rounds into a run)."""
        from repro.data.sampler import get_sampler, validate_participation
        validate_participation(self.num_clients, self.clients_per_round)
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps} "
                "(each sampled client runs at least one local step)")
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        # raises ValueError with the known-spec list on a bad spec; the
        # trace path is validated when the schedule is actually loaded
        from repro.scenario.availability import parse_availability
        if not self.availability.startswith("trace"):
            parse_availability(self.availability, self.num_clients)
        get_sampler(self.sampling)
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(
                f"straggler_frac must be in [0, 1], got "
                f"{self.straggler_frac}")
        if not 1 <= self.straggler_min_steps <= self.local_steps:
            raise ValueError(
                f"straggler_min_steps must be in [1, local_steps="
                f"{self.local_steps}], got {self.straggler_min_steps} "
                "(a participating client always applies its first step)")
        from repro.scenario.weights import WEIGHT_SCHEMES
        if self.agg_weighting not in WEIGHT_SCHEMES:
            raise ValueError(
                f"unknown agg_weighting {self.agg_weighting!r}; "
                f"known: {WEIGHT_SCHEMES}")
