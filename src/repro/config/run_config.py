"""Run configuration: input shapes, mesh layout, precision, remat policy."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    """An assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs beyond the model + fed configs."""

    shape: str = "train_4k"
    mesh_shape: Tuple[int, ...] = (16, 16)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False

    remat: str = "none"                # none | full | dots (checkpoint policy)
    scan_layers: bool = True           # lax.scan over layers vs python unroll
    param_dtype: str = "float32"       # master copy dtype
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"

    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 0          # 0 = disabled
    checkpoint_dir: str = "/tmp/repro_ckpt"

    # decode-specific
    decode_page_seq_shards: bool = True  # seq-sharded KV cache + LSE merge

    def input_shape(self) -> InputShape:
        return INPUT_SHAPES[self.shape]
