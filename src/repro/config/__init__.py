"""Configuration system: model/fed/run configs, arch registry, input shapes."""
from repro.config.model_config import (
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.config.fed_config import FedConfig
from repro.config.run_config import RunConfig, InputShape, INPUT_SHAPES
from repro.config.registry import register_arch, get_arch, list_archs

__all__ = [
    "AttentionConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "FedConfig",
    "RunConfig",
    "InputShape",
    "INPUT_SHAPES",
    "register_arch",
    "get_arch",
    "list_archs",
]
