"""Architecture registry: ``--arch <id>`` resolution.

Every module in ``repro.configs`` registers one architecture (plus optional
variants). Importing :mod:`repro.configs` populates the registry.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.model_config import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str):
    """Decorator registering a zero-arg ModelConfig factory under ``arch_id``."""

    def deco(fn: Callable[[], ModelConfig]):
        if arch_id in _REGISTRY:
            raise ValueError(f"duplicate arch id {arch_id!r}")
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def _ensure_loaded() -> None:
    importlib.import_module("repro.configs")


def get_arch(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[arch_id]()
    cfg.validate()
    return cfg


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
