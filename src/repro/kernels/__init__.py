"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's hot loop is the per-client fused AdamW update executed K*S times
per round over every parameter (DESIGN.md §5):

``fused_adamw``  one-pass moment update + parameter step (memory-bound:
                 fusing 5 HBM round-trips into one read/write pass)
``blockmean``    tiled column-mean reduction used for the O(B) block-mean
                 second-moment upload (paper Eq. 4)
``quantpack``    fused per-tensor scale + int8/int4 quantize-pack for the
                 upload codecs (repro.comm)
``clipacc``      fused per-client L2 clip + weighted accumulate over the
                 (S, model-size) upload stack for client-level DP
                 (repro.privacy)
``uploadfuse``   one-pass upload megakernel: error-feedback fold +
                 per-client DP clip + int8/int4 quantize-pack +
                 decoded-norm re-clip + weighted accumulate over the
                 stacked upload in a single read (subsumes clipacc +
                 quantpack on the upload path; both layouts)

Each kernel ships ``ops.py`` (jit'd wrapper) and ``ref.py`` (pure-jnp
oracle); tests sweep shapes/dtypes with assert_allclose, and the
property harness (tests/test_kernel_properties.py, docs/kernels.md)
fuzzes every kernel against its oracle over generated shape/value
corpora. Kernels target TPU (VMEM BlockSpec tiling) and validate under
``interpret=True`` on CPU.
"""
