"""Tree-level wrapper for the fused upload megakernel.

``tree_upload_fuse`` takes the stacked ``(S, ...)`` raw-delta pytree
(plus optional error-feedback stack and per-client PRNG keys), lays the
leaves out as one ``(S, R, LANES)`` block — each leaf padded to a whole
number of row-block tiles so no tile spans a leaf boundary — and runs
the one-pass clip / fold / quantize / accumulate kernel over it.

The zero padding is invariant-safe by construction: pads contribute 0 to
the squared norms, 0 to the absmax, quantize to code 0 (int4: code 8,
the same zero code ``pack_nibbles`` pads odd tails with) and add 0 to
the accumulate.

Stochastic-rounding noise for int4 reproduces the jnp codec bit stream
exactly: per (client, leaf), ``uniform(fold_in(client_key, leaf_index),
(leaf_size,))`` — the same per-leaf fold ``leafwise_codec`` applies, and
Threefry draws are row-major so the flat draw equals the leaf-shaped
draw of the unfused path.

``force_impl("ref")`` reroutes every call (including the engine's) to
the bit-exact chained oracle — the composition parity tests run whole
training trajectories under both implementations and compare bytes.
"""
from __future__ import annotations

import contextlib
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .ref import upload_fuse_ref
from .uploadfuse import BLOCK_ROWS, LANES, upload_fuse_3d

_IMPL = "kernel"


@contextlib.contextmanager
def force_impl(impl: str):
    """Reroute tree_upload_fuse to ``impl`` ("kernel" | "ref") within
    the context (test hook for engine-level bit-parity runs)."""
    assert impl in ("kernel", "ref"), impl
    global _IMPL
    prev, _IMPL = _IMPL, impl
    try:
        yield
    finally:
        _IMPL = prev


class UploadFuseResult(NamedTuple):
    mean: Any                       # weighted-accumulated delta tree
    residual: Optional[Any]         # (S, ...) new error-feedback stack
    clip_factors: jax.Array         # (S,) DP clip factor (1.0 when off)
    reclip_factors: jax.Array       # (S,) decoded-norm re-clip factor
    scales: Optional[jax.Array]     # (S, n_leaves) quantization scales
    codes: Optional[jax.Array]      # raw (S, R, LANES[/2]) wire codes


def _layout(leaves):
    sizes = [int(np.prod(leaf.shape[1:])) for leaf in leaves]
    rows = []
    for sz in sizes:
        nr = -(-sz // LANES)
        rows.append(max(-(-nr // BLOCK_ROWS) * BLOCK_ROWS, BLOCK_ROWS))
    return sizes, rows


def _stack3d(leaves, sizes, rows, s_n):
    blocks = []
    for leaf, sz, nr in zip(leaves, sizes, rows):
        flat = leaf.reshape(s_n, -1).astype(jnp.float32)
        pad = nr * LANES - sz
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        blocks.append(flat.reshape(s_n, nr, LANES))
    return jnp.concatenate(blocks, axis=1)


def tree_upload_fuse(stacked, ef_stacked=None, *, bits: int, clip,
                     weights: jax.Array, keys: Optional[jax.Array] = None,
                     interpret: bool = True,
                     impl: Optional[str] = None) -> UploadFuseResult:
    """Fused upload over a stacked ``(S, ...)`` delta pytree.

    bits: 0 (no codec) | 8 | 4; clip: static Python float L2 bound
    (<= 0 disables the DP clip stages); weights: (S,) f32 final
    accumulation coefficients (aggregation weights x validity masks,
    already renormalized); keys: (S, ...) stacked PRNG keys, required
    for ``bits == 4`` (stochastic rounding).
    """
    impl = impl or _IMPL
    clip = float(clip) if clip is not None else 0.0
    dp = clip > 0.0
    ef = ef_stacked is not None
    if bits not in (0, 4, 8):
        raise ValueError(f"uploadfuse: unsupported bit width {bits}")
    if bits == 4 and keys is None:
        raise ValueError("uploadfuse: int4 stochastic rounding needs "
                         "per-client keys")

    leaves, treedef = jax.tree.flatten(stacked)
    s_n = leaves[0].shape[0]
    sizes, rows = _layout(leaves)
    n_leaves = len(leaves)
    x3 = _stack3d(leaves, sizes, rows, s_n)
    ef_leaves = None
    e3 = None
    if ef:
        ef_leaves = jax.tree.leaves(ef_stacked)
        assert len(ef_leaves) == n_leaves
        e3 = _stack3d(ef_leaves, sizes, rows, s_n)
    u3 = None
    if bits == 4:
        ublocks = []
        for i, (sz, nr) in enumerate(zip(sizes, rows)):
            ui = jax.vmap(lambda k, i=i, sz=sz: jax.random.uniform(
                jax.random.fold_in(k, i), (sz,), jnp.float32))(keys)
            pad = nr * LANES - sz
            if pad:
                ui = jnp.pad(ui, ((0, 0), (0, pad)))
            ublocks.append(ui.reshape(s_n, nr, LANES))
        u3 = jnp.concatenate(ublocks, axis=1)
    seg = np.repeat(np.arange(n_leaves, dtype=np.int32),
                    [nr // BLOCK_ROWS for nr in rows])

    kw = dict(bits=bits, dp=dp, ef=ef, n_leaves=n_leaves)
    if impl == "kernel":
        acc, stats, codes, res = upload_fuse_3d(
            x3, e3, u3, weights, clip, seg, interpret=interpret, **kw)
    else:
        acc, stats, codes, res = upload_fuse_ref(
            x3, e3, u3, weights, clip, seg, **kw)

    mean_leaves, row0 = [], 0
    for leaf, sz, nr in zip(leaves, sizes, rows):
        flat = acc[row0:row0 + nr].reshape(-1)[:sz]
        mean_leaves.append(flat.reshape(leaf.shape[1:]).astype(leaf.dtype))
        row0 += nr
    mean = jax.tree.unflatten(treedef, mean_leaves)

    residual = None
    if ef:
        res_leaves, row0 = [], 0
        for leaf, sz, nr in zip(ef_leaves, sizes, rows):
            flat = res[:, row0:row0 + nr].reshape(s_n, -1)[:, :sz]
            res_leaves.append(flat.reshape(leaf.shape).astype(leaf.dtype))
            row0 += nr
        residual = jax.tree.unflatten(treedef, res_leaves)

    return UploadFuseResult(
        mean=mean, residual=residual, clip_factors=stats[:, 0],
        reclip_factors=stats[:, 1],
        scales=stats[:, 2:] if bits else None, codes=codes)


def wire_payloads(stacked, result: UploadFuseResult, *, bits: int
                  ) -> List[List[dict]]:
    """Slice the kernel's raw code block into per-client, per-leaf wire
    payloads ({"q", "scale"}) matching the jnp codec format — int8 codes
    flat per leaf, int4 packed low-nibble-first with the odd-tail zero
    code. Used by the wire-parity tests and byte accounting checks."""
    assert bits in (4, 8) and result.codes is not None
    leaves, _ = jax.tree.flatten(stacked)
    s_n = leaves[0].shape[0]
    sizes, rows = _layout(leaves)
    out = []
    for s in range(s_n):
        per_leaf, row0 = [], 0
        for i, (sz, nr) in enumerate(zip(sizes, rows)):
            flat = result.codes[s, row0:row0 + nr].reshape(-1)
            n = sz if bits == 8 else (sz + 1) // 2
            per_leaf.append({"q": flat[:n], "scale": result.scales[s, i]})
            row0 += nr
        out.append(per_leaf)
    return out
