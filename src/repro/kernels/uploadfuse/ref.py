"""Reference implementations for the fused upload megakernel.

Two oracles with different jobs:

* :func:`upload_fuse_ref` — the parity oracle. It replays the kernel's
  exact operation sequence (per-tile chained f32 sum-of-squares, the
  same quantize/decode formulas, one cross-client reduction per output
  tile) with plain jnp ops, so the Pallas kernel must match it
  BIT-EXACTLY. Tests compare raw bytes against this.
* :func:`upload_fuse_semantic` — the costing oracle. The same pipeline
  written the natural unfused way (whole-array clip, per-leaf quantize,
  decoded copy materialized, re-clip, weighted mean), i.e. the
  multi-stage program XLA sees without the fusion. The roofline report
  costs this one, and tests check it agrees with the kernel to float
  tolerance (it sums in a different order, so bit-equality is not
  expected).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .uploadfuse import (BLOCK_ROWS, INV_QMAX4, INV_QMAX8, LANES,
                         NORM_FLOOR, SCALE_FLOOR, n_phases_for)


def upload_fuse_ref(x: jax.Array, e: Optional[jax.Array],
                    u: Optional[jax.Array], w: jax.Array, clip, seg,
                    *, bits: int, dp: bool, ef: bool, n_leaves: int
                    ) -> Tuple[jax.Array, jax.Array,
                               Optional[jax.Array], Optional[jax.Array]]:
    """Bit-exact oracle for ``upload_fuse_3d`` (same signature minus
    ``interpret``); ``seg`` must be a host-side int sequence."""
    x = x.astype(jnp.float32)
    s_n, r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (s_n, r, c)
    n_blocks = r // BLOCK_ROWS
    seg = [int(s) for s in np.asarray(seg)]
    assert len(seg) == n_blocks, (len(seg), n_blocks)
    clip = jnp.asarray(clip, jnp.float32)
    w = w.astype(jnp.float32)
    tgt = x + e.astype(jnp.float32) if ef else x
    inv_qmax = INV_QMAX8 if bits == 8 else INV_QMAX4

    def tile(a, i):
        return a[:, i * BLOCK_ROWS:(i + 1) * BLOCK_ROWS, :]

    # pin mirrors the kernel: bounce each product through the int32
    # domain (plus a runtime-opaque zero derived from the tile's first
    # raw element, exactly as the kernel does, so the simplifier cannot
    # cancel the bitcast pair) to force its rounded f32 value. Without
    # it XLA contracts a product feeding an add/subtract into an FMA in
    # one program but not the other — the contraction choice is
    # contextual, so it must be foreclosed on BOTH sides, including the
    # products feeding the norm and accumulate reductions.
    def tile_pin(i):
        v0 = tile(x, i)[0, 0, 0]
        pz = (v0 != v0).astype(jnp.int32)

        def pin(v):
            b = jax.lax.bitcast_convert_type(v, jnp.int32) + pz
            return jax.lax.bitcast_convert_type(b, jnp.float32)

        return pin

    # phase 0: chained per-tile stats, in tile order (f32 sums are
    # order-sensitive; the kernel walks tiles sequentially)
    sumsq = jnp.zeros((s_n,), jnp.float32)
    absmax = jnp.zeros((s_n, n_leaves), jnp.float32)
    for i in range(n_blocks):
        t = tile(tgt, i)
        pin = tile_pin(i)
        if dp:
            sumsq = sumsq + jnp.sum(pin(t * t), axis=(1, 2))
        if bits:
            am = jnp.max(jnp.abs(t), axis=(1, 2))
            absmax = absmax.at[:, seg[i]].set(
                jnp.maximum(absmax[:, seg[i]], am))

    if dp:
        cf = jnp.minimum(1.0, clip / jnp.maximum(jnp.sqrt(sumsq),
                                                 NORM_FLOOR))
    else:
        cf = jnp.ones((s_n,), jnp.float32)
    if bits:
        scales = jnp.maximum(cf[:, None] * absmax, SCALE_FLOOR) * inv_qmax
    else:
        scales = jnp.zeros((s_n, n_leaves), jnp.float32)

    def decode_tile(i):
        t = tile(tgt, i)
        pin = tile_pin(i)
        ctgt = pin(cf[:, None, None] * t) if dp else t
        if not bits:
            return None, ctgt, ctgt
        sc = scales[:, seg[i]][:, None, None]
        if bits == 8:
            q = jnp.clip(jnp.round(ctgt / sc), -127.0, 127.0)
        else:
            q = jnp.clip(jnp.floor(ctgt / sc + tile(u, i)), -8.0, 7.0)
        return q, ctgt, pin(q * sc)

    # phase 1 stats (dp + codec only): chained decoded sum-of-squares
    n_phases = n_phases_for(bits, dp)
    if n_phases == 3:
        dsq = jnp.zeros((s_n,), jnp.float32)
        for i in range(n_blocks):
            _, _, dec = decode_tile(i)
            dsq = dsq + jnp.sum(tile_pin(i)(dec * dec), axis=(1, 2))
        rf = jnp.minimum(1.0, clip / jnp.maximum(jnp.sqrt(dsq),
                                                 NORM_FLOOR))
    else:
        rf = jnp.ones((s_n,), jnp.float32)

    # final phase: codes / accumulate / residual, one reduction per tile
    acc_tiles, code_tiles, res_tiles = [], [], []
    for i in range(n_blocks):
        q, ctgt, dec = decode_tile(i)
        pin = tile_pin(i)
        if n_phases == 3:
            final = pin(rf[:, None, None] * dec)
        else:
            final = dec
        acc_tiles.append(jnp.sum(pin(w[:, None, None] * final), axis=0))
        if ef:
            res_tiles.append(ctgt - final)
        if bits == 8:
            code_tiles.append(q.astype(jnp.int8))
        elif bits == 4:
            c8 = (q + 8.0).astype(jnp.uint8)
            pairs = c8.reshape(s_n, BLOCK_ROWS, -1, 2)
            code_tiles.append(pairs[..., 0] | (pairs[..., 1] << 4))

    acc = jnp.concatenate(acc_tiles, axis=0)
    codes = jnp.concatenate(code_tiles, axis=1) if bits else None
    res = jnp.concatenate(res_tiles, axis=1) if ef else None
    stats = jnp.concatenate([cf[:, None], rf[:, None], scales], axis=1)
    return acc, stats, codes, res


def upload_fuse_semantic(x: jax.Array, e: Optional[jax.Array],
                         u: Optional[jax.Array], w: jax.Array, clip, seg,
                         *, bits: int, dp: bool, ef: bool, n_leaves: int
                         ) -> jax.Array:
    """The unfused multi-stage pipeline (what the engine runs without the
    kernel): fold, whole-stack clip, per-leaf quantize + decoded copy,
    re-clip, weighted accumulate. Returns the accumulated mean only —
    this is the program the roofline costs against the fused kernel.
    """
    x = x.astype(jnp.float32)
    s_n, r, c = x.shape
    seg = np.asarray(seg)
    clip = jnp.asarray(clip, jnp.float32)
    tgt = x + e.astype(jnp.float32) if ef else x

    def clip_stack(a):
        if not dp:
            return a
        norm = jnp.sqrt(jnp.sum(a * a, axis=(1, 2)))
        f = jnp.minimum(1.0, clip / jnp.maximum(norm, NORM_FLOOR))
        return f[:, None, None] * a

    ctgt = clip_stack(tgt)
    if bits:
        inv_qmax = INV_QMAX8 if bits == 8 else INV_QMAX4
        parts = []
        for leaf in range(n_leaves):
            rows = np.nonzero(np.repeat(seg, BLOCK_ROWS) == leaf)[0]
            lo, hi = int(rows[0]), int(rows[-1]) + 1
            sl = ctgt[:, lo:hi, :]
            scale = jnp.maximum(jnp.max(jnp.abs(sl), axis=(1, 2)),
                                SCALE_FLOOR) * inv_qmax
            sc = scale[:, None, None]
            if bits == 8:
                q = jnp.clip(jnp.round(sl / sc), -127.0, 127.0)
            else:
                q = jnp.clip(jnp.floor(sl / sc + u[:, lo:hi, :]),
                             -8.0, 7.0)
            parts.append(q * sc)             # materialized decoded copy
        dec = jnp.concatenate(parts, axis=1)
        final = clip_stack(dec) if dp else dec
    else:
        final = ctgt
    return jnp.sum(w.astype(jnp.float32)[:, None, None] * final, axis=0)
