"""One-pass fused upload path: clip + error-feedback fold + int8/int4
quantize-pack + weighted accumulate over the stacked (S, ...) upload in
a single Pallas kernel (``FedConfig.use_pallas_uploadfuse``)."""
from .ops import (UploadFuseResult, force_impl, tree_upload_fuse,
                  wire_payloads)
from .ref import upload_fuse_ref, upload_fuse_semantic
from .uploadfuse import (BLOCK_ROWS, LANES, NORM_FLOOR, SCALE_FLOOR,
                         upload_fuse_3d)

__all__ = [
    "BLOCK_ROWS", "LANES", "NORM_FLOOR", "SCALE_FLOOR",
    "UploadFuseResult", "force_impl", "tree_upload_fuse",
    "upload_fuse_3d", "upload_fuse_ref", "upload_fuse_semantic",
    "wire_payloads",
]
