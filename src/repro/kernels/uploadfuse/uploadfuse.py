"""Pallas TPU megakernel: one-pass fused upload path for federated rounds.

The upload hot path of a DP + compressed round crosses, per client s and
round (paper Algorithm 2's aggregate step; DP-FedAdamW composes the clip
into the same pipeline):

    target_s  = delta_s + ef_s                       (error-feedback fold)
    ctgt_s    = min(1, C/||target_s||) * target_s    (per-client DP clip)
    q_s       = quantize(ctgt_s / scale_{s,l})       (per-leaf int8/int4)
    dec_s     = q_s * scale_{s,l}                    (what the wire carries)
    dec_s     = min(1, C/||dec_s||) * dec_s          (DP re-clip of decoded)
    ef'_s     = ctgt_s - dec_s                       (residual commit)
    out       = sum_s w_s * dec_s                    (weighted accumulate)

Unfused that is three separate Pallas kernels (clipacc, quantpack) plus
XLA reductions, each re-reading the full (S, model-size) upload stack and
materializing the decoded f32 copy (PR 6's roofline measured bytes_ratio
55x for clipacc + 3.4x for quantpack against the analytic minimum). This
kernel runs the whole pipeline in ONE pallas_call with a multi-phase
sequential grid — the clipacc accumulator idiom widened to a per-client
stats row — so the stack is read at most three times and the decoded
copy never exists in HBM:

* phase 0 walks the row-block tiles accumulating, per client, the
  squared L2 norm of the fold target (for the clip factor) AND the
  per-(client, leaf) absmax (for the quantization scales) into one
  SMEM-resident ``(S, n_leaves + 2)`` stats block — a single read
  produces both because ``absmax(f * x) == f * absmax(x)`` bit-exactly
  for the nonnegative clip factor f;
* phase 1 derives the clip factor and per-leaf scales from the stats
  block, quantizes, writes the packed wire codes, and — when the DP
  re-clip is needed (dp AND a lossy codec) — accumulates the decoded
  squared norm into the stats block; otherwise it is the final phase and
  writes the weighted accumulate + the new error-feedback residual;
* phase 2 (dp + codec only) recomputes the quantization deterministically
  (same ops, same operands — bit-identical), applies the decoded-norm
  re-clip factor, and writes the accumulate + residual.

Leaf boundaries are static: every row-block tile belongs to exactly one
leaf (the ``ops.py`` wrapper pads each leaf to a tile multiple), and the
tile's leaf index rides in as a tiny SMEM ``seg`` operand, so per-leaf
scale selection is a where-mask over the stats columns — no gathers.

After the last tile of the last phase the stats output holds, per
client: column 0 the clip factor, column 1 the re-clip factor (1.0 when
unused), columns 2+ the final per-leaf scales — the wire payload's scale
row and the diagnostics clipped-fraction in one block.

Tiles are (S, BLOCK_ROWS, LANES) with BLOCK_ROWS = 8 (one f32 sublane
group — the fine granularity keeps per-leaf padding small), VMEM ~32 KiB
x S per operand.

Bit-exactness vs ``ref.py``: the oracle replicates the kernel's exact
operation sequence — per-tile chained sum-of-squares (f32 sums are
order-sensitive), order-invariant maxes, identical quantize/decode
formulas and the same single cross-client reduction per output tile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import numpy as np

LANES = 1024          # last-dim tile (multiple of 128)
BLOCK_ROWS = 8        # rows per grid step (f32 sublane group)
NORM_FLOOR = 1e-12    # guards all-zero client updates (repro.privacy)
SCALE_FLOOR = 1e-12   # guards all-zero leaves (repro.comm.codecs)
# f32-rounded reciprocals: a single multiply is bit-deterministic across
# the jnp codec and kernel paths (the quantpack convention)
INV_QMAX8 = float(np.float32(1.0 / 127.0))
INV_QMAX4 = float(np.float32(1.0 / 7.0))


def n_phases_for(bits: int, dp: bool) -> int:
    """3 when the decoded-norm re-clip is needed (dp + lossy codec),
    else 2 (stats pass + compute pass)."""
    return 3 if (dp and bits) else 2


# NOTE: every pl.program_id call is hoisted to the top of the kernel
# body — calling it inside a pl.when branch breaks interpret mode (the
# cond branch is lowered outside the grid axis environment).

def _kernel(clip_ref, w_ref, seg_ref, x_ref, *refs, n_row_blocks: int,
            n_leaves: int, bits: int, dp: bool, ef: bool):
    n_phases = n_phases_for(bits, dp)
    phase = pl.program_id(0)
    blk = pl.program_id(1)
    is_first = (phase == 0) & (blk == 0)
    is_last = (phase == n_phases - 1) & (blk == n_row_blocks - 1)

    refs = list(refs)
    e_ref = refs.pop(0) if ef else None
    u_ref = refs.pop(0) if bits == 4 else None
    acc_ref = refs.pop(0)
    stats_ref = refs.pop(0)
    codes_ref = refs.pop(0) if bits else None
    res_ref = refs.pop(0) if ef else None

    clip = clip_ref[0]
    leaf = seg_ref[blk]
    w = w_ref[...]                     # (S,)
    x = x_ref[...]                     # (S, BLOCK_ROWS, LANES)
    s_n = x.shape[0]
    tgt = x + e_ref[...] if ef else x
    cols = jax.lax.broadcasted_iota(jnp.int32, (s_n, n_leaves + 2), 1)
    leaf_col = cols == leaf + 2
    inv_qmax = INV_QMAX8 if bits == 8 else INV_QMAX4

    def clip_factor(stats):
        if not dp:
            return jnp.ones((s_n,), jnp.float32)
        norm = jnp.sqrt(stats[:, 0])
        return jnp.minimum(1.0, clip / jnp.maximum(norm, NORM_FLOOR))

    # pin(v): force v to its ROUNDED f32 value by bouncing it through
    # the integer domain with a runtime-opaque zero added, so the
    # simplifier cannot cancel the bitcast pair. Without this, XLA:CPU
    # freely contracts a product feeding an add/subtract into an FMA —
    # differently in the kernel and ref.py programs — breaking
    # bit-parity. Neither lax.optimization_barrier nor an opaque select
    # stops that contraction; the integer bounce does, deterministically,
    # because FMA formation cannot cross the int32 domain. The zero must
    # come from the DATA: clip/weights reach the engine trace as
    # compile-time constants, where (clip < 0) would fold and the pin
    # with it. (v != v) is 0 for every non-NaN input and unprovable for
    # a runtime tensor; a NaN input perturbs pinned values by one ulp —
    # identically on both sides, so parity holds even then.
    v0 = x[0, 0, 0]
    pin_zero = (v0 != v0).astype(jnp.int32)

    def pin(v):
        b = jax.lax.bitcast_convert_type(v, jnp.int32) + pin_zero
        return jax.lax.bitcast_convert_type(b, jnp.float32)

    def decode(stats):
        """-> (cf, scale, q, ctgt, dec) for THIS tile; called with
        identical operands in phases 1 and 2, so the recompute is
        bit-identical to the first pass."""
        cf = clip_factor(stats)
        ctgt = pin(cf[:, None, None] * tgt) if dp else tgt
        if not bits:
            return cf, None, None, ctgt, ctgt
        absmax = jnp.max(jnp.where(leaf_col, stats, 0.0), axis=1)  # (S,)
        scale = jnp.maximum(cf * absmax, SCALE_FLOOR) * inv_qmax
        sc = scale[:, None, None]
        if bits == 8:
            q = jnp.clip(jnp.round(ctgt / sc), -127.0, 127.0)
        else:
            q = jnp.clip(jnp.floor(ctgt / sc + u_ref[...]), -8.0, 7.0)
        return cf, scale, q, ctgt, pin(q * sc)

    def write_codes(q):
        if bits == 8:
            codes_ref[...] = q.astype(jnp.int8)
        else:
            c8 = (q + 8.0).astype(jnp.uint8)
            # consecutive lane pairs -> one byte, low nibble first
            # (matches repro.comm.codecs.pack_nibbles on the flat leaf)
            pairs = c8.reshape(s_n, c8.shape[1], -1, 2)
            codes_ref[...] = pairs[..., 0] | (pairs[..., 1] << 4)

    def final_stats(stats, cf, rf):
        if bits:
            scales = jnp.maximum(cf[:, None] * stats[:, 2:],
                                 SCALE_FLOOR) * inv_qmax
        else:
            scales = stats[:, 2:]
        return jnp.concatenate([cf[:, None], rf[:, None], scales], axis=1)

    @pl.when(is_first)
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    @pl.when(phase == 0)
    def _phase0():
        upd = stats_ref[...]
        if dp:
            ssq = jnp.sum(pin(tgt * tgt), axis=(1, 2))       # (S,)
            upd = upd + jnp.where(cols == 0, ssq[:, None], 0.0)
        if bits:
            am = jnp.max(jnp.abs(tgt), axis=(1, 2))          # (S,)
            upd = jnp.where(leaf_col, jnp.maximum(upd, am[:, None]), upd)
        stats_ref[...] = upd
        # outputs must be written every visit; later phases overwrite
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if bits:
            codes_ref[...] = jnp.zeros_like(codes_ref)
        if ef:
            res_ref[...] = jnp.zeros_like(res_ref)

    @pl.when(phase == 1)
    def _phase1():
        stats = stats_ref[...]
        cf, scale, q, ctgt, dec = decode(stats)
        if bits:
            write_codes(q)
        if n_phases == 3:
            # intermediate: the re-clip needs ||dec|| over the whole
            # stack before any output can be finalized
            dsq = jnp.sum(pin(dec * dec), axis=(1, 2))
            stats_ref[...] = stats + jnp.where(cols == 1, dsq[:, None], 0.0)
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if ef:
                res_ref[...] = jnp.zeros_like(res_ref)
        else:
            acc_ref[...] = jnp.sum(pin(w[:, None, None] * dec), axis=0)
            if ef:
                res_ref[...] = ctgt - dec

            @pl.when(is_last)
            def _store():
                stats_ref[...] = final_stats(stats, cf,
                                             jnp.ones((s_n,), jnp.float32))

    if n_phases_for(bits, dp) == 3:
        @pl.when(phase == 2)
        def _phase2():
            stats = stats_ref[...]
            cf, scale, q, ctgt, dec = decode(stats)
            dnorm = jnp.sqrt(stats[:, 1])
            rf = jnp.minimum(1.0, clip / jnp.maximum(dnorm, NORM_FLOOR))
            final = pin(rf[:, None, None] * dec)
            acc_ref[...] = jnp.sum(pin(w[:, None, None] * final), axis=0)
            write_codes(q)                     # identical recompute
            if ef:
                res_ref[...] = ctgt - final

            @pl.when(is_last)
            def _store():
                stats_ref[...] = final_stats(stats, cf, rf)


@functools.partial(jax.jit,
                   static_argnames=("bits", "dp", "ef", "n_leaves",
                                    "interpret"))
def upload_fuse_3d(x: jax.Array, e: Optional[jax.Array],
                   u: Optional[jax.Array], w: jax.Array, clip, seg,
                   *, bits: int, dp: bool, ef: bool, n_leaves: int,
                   interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array,
                              Optional[jax.Array], Optional[jax.Array]]:
    """x: (S, R, LANES) f32 stacked raw deltas (per-leaf tile-padded, R %
    BLOCK_ROWS == 0); e: matching error-feedback residual stack (``ef``)
    or None; u: matching U[0,1) rounding noise (``bits == 4``) or None;
    w: (S,) f32 final accumulation coefficients (validity and aggregation
    weights pre-folded); clip: scalar f32 L2 bound (read iff ``dp``);
    seg: (R // BLOCK_ROWS,) int32 leaf index per row block.

    Returns ``(acc (R, LANES) f32, stats (S, n_leaves + 2) f32,
    codes | None, residual | None)`` where ``acc = sum_s w[s] *
    decoded[s]``, stats columns are (clip factor, re-clip factor,
    per-leaf scales), codes is (S, R, LANES) int8 or (S, R, LANES // 2)
    packed uint8, and residual is the (S, R, LANES) f32 new
    error-feedback stack.
    """
    s_n, r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (s_n, r, c)
    assert w.shape == (s_n,), (w.shape, s_n)
    assert bits in (0, 4, 8), bits
    n_blocks = r // BLOCK_ROWS
    grid = (n_phases_for(bits, dp), n_blocks)
    stack_spec = pl.BlockSpec((s_n, BLOCK_ROWS, LANES),
                              lambda p, i: (0, i, 0))
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),      # clip scalar
        pl.BlockSpec(memory_space=pltpu.SMEM),      # weights (S,)
        pl.BlockSpec(memory_space=pltpu.SMEM),      # seg (n_blocks,)
        stack_spec,                                 # x
    ]
    operands = [jnp.asarray(clip, jnp.float32).reshape(1),
                w.astype(jnp.float32),
                jnp.asarray(seg, jnp.int32),
                x.astype(jnp.float32)]
    if ef:
        in_specs.append(stack_spec)
        operands.append(e.astype(jnp.float32))
    if bits == 4:
        in_specs.append(stack_spec)
        operands.append(u.astype(jnp.float32))
    out_specs = [
        pl.BlockSpec((BLOCK_ROWS, LANES), lambda p, i: (i, 0)),
        pl.BlockSpec((s_n, n_leaves + 2), lambda p, i: (0, 0),
                     memory_space=pltpu.SMEM),
    ]
    out_shape = [jax.ShapeDtypeStruct((r, c), jnp.float32),
                 jax.ShapeDtypeStruct((s_n, n_leaves + 2), jnp.float32)]
    if bits == 8:
        out_specs.append(pl.BlockSpec((s_n, BLOCK_ROWS, LANES),
                                      lambda p, i: (0, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((s_n, r, c), jnp.int8))
    elif bits == 4:
        out_specs.append(pl.BlockSpec((s_n, BLOCK_ROWS, LANES // 2),
                                      lambda p, i: (0, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((s_n, r, c // 2), jnp.uint8))
    if ef:
        out_specs.append(stack_spec)
        out_shape.append(jax.ShapeDtypeStruct((s_n, r, c), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_kernel, n_row_blocks=n_blocks,
                          n_leaves=n_leaves, bits=bits, dp=dp, ef=ef),
        grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(*operands)
    outs = list(outs)
    acc, stats = outs.pop(0), outs.pop(0)
    codes = outs.pop(0) if bits else None
    res = outs.pop(0) if ef else None
    return acc, stats, codes, res
