"""Pure-jnp oracle for the fused clip-accumulate kernel.

Replicates the kernel's operation sequence exactly — per-tile
``jnp.sum(x * x, axis=(1, 2))`` squared sums chained left-to-right over
row blocks, the factor formula, and the identical single-reduction
weighted accumulate per tile — because f32 sum reductions are
order-sensitive (unlike quantpack's max): parity is bit-exact only for
the identical operation sequence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.clipacc.clipacc import BLOCK_ROWS, NORM_FLOOR


def clip_accumulate_ref(x: jax.Array, w: jax.Array, clip
                        ) -> Tuple[jax.Array, jax.Array]:
    """x: (S, R, LANES) f32, w: (S,) f32 -> (acc (R, LANES), factors
    (S, 1)) — same contract as ``clip_accumulate_3d``."""
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    clip = jnp.asarray(clip, jnp.float32)
    s_n, r, _ = x.shape
    n_blocks = r // BLOCK_ROWS

    def block(i):
        return x[:, i * BLOCK_ROWS:(i + 1) * BLOCK_ROWS, :]

    sumsq = jnp.zeros((s_n, 1), jnp.float32)
    for i in range(n_blocks):
        xb = block(i)
        sumsq = sumsq + jnp.sum(xb * xb, axis=(1, 2)).reshape(s_n, 1)
    norm = jnp.sqrt(sumsq)
    factor = jnp.minimum(1.0, clip / jnp.maximum(norm, NORM_FLOOR))
    coef = w * factor[:, 0]
    tiles = [jnp.sum(coef[:, None, None] * block(i), axis=0)
             for i in range(n_blocks)]
    return jnp.concatenate(tiles, axis=0), factor
