"""Fused per-client L2 clip + weighted accumulate for the DP hot path
(repro.privacy, FedConfig.use_pallas_clipacc)."""
from repro.kernels.clipacc.clipacc import (
    BLOCK_ROWS,
    LANES,
    NORM_FLOOR,
    clip_accumulate_3d,
)
from repro.kernels.clipacc.ops import tree_clip_accumulate

__all__ = ["BLOCK_ROWS", "LANES", "NORM_FLOOR", "clip_accumulate_3d",
           "tree_clip_accumulate"]
