"""Pallas TPU kernel: fused per-client L2 clip + weighted accumulate.

The DP hot path of the round engine needs, per round:

    norm_s  = ||x_s||_2                            (one pass over S x d)
    out     = sum_s w_s * min(1, C/norm_s) * x_s   (a second pass)

Unfused that is two full passes over the (S, model-size) upload stack
plus a materialized scaled copy. This kernel does both in one
pallas_call with a two-phase sequential grid — the quantpack absmax
idiom with the accumulator widened to one SMEM row per client:

* phase 0 walks the row-block tiles, each carrying ALL S clients'
  (BLOCK_ROWS, LANES) slices, accumulating every client's sum of
  squares into an SMEM-resident (S, 1) accumulator (pinned by its index
  map, initialized on the first tile);
* phase 1 converts the accumulator to the per-client clip factors and
  writes each output tile as ONE cross-client weighted reduction
  ``sum_s (w_s * factor_s) * x_s`` — no tile is ever revisited, so no
  read-modify-write accumulation whose multiply-add fusion could round
  differently from the reference.

HBM traffic: 2 reads of x + 1 write of the d-sized accumulate; the
scaled per-client copy never exists. The (S, 1) accumulator doubles as
the second output: after the last phase-1 tile it holds each client's
clip factor (1.0 = not clipped), which the caller can log as the
clipped fraction.

Tiles are (S, BLOCK_ROWS, LANES): BLOCK_ROWS is 8 (one f32 sublane
group) so VMEM stays ~32 KiB x S per operand — comfortable to S ~ 256
clients per round.

Bit-exactness vs ``ref.py``: the oracle replicates the kernel's exact
operation sequence — per-tile ``jnp.sum(x*x, axis=(1, 2))`` chained
left-to-right over row blocks, the factor formula, and the same
single-reduction weighted accumulate per tile — because f32 sum
reductions are order-sensitive (unlike quantpack's max).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024          # last-dim tile (multiple of 128)
BLOCK_ROWS = 8        # rows per grid step (f32 sublane group)
NORM_FLOOR = 1e-12    # guards all-zero client updates


# NOTE: every pl.program_id call is hoisted to the top of the kernel
# body — calling it inside a pl.when branch breaks interpret mode (the
# cond branch is lowered outside the grid axis environment).

def _kernel(s_ref, w_ref, x_ref, acc_ref, f_ref, *, n_row_blocks: int):
    phase = pl.program_id(0)
    blk = pl.program_id(1)
    is_first = (phase == 0) & (blk == 0)
    is_last = (phase == 1) & (blk == n_row_blocks - 1)
    clip = s_ref[0]
    x = x_ref[...]                    # (S, BLOCK_ROWS, LANES)
    s_n = x.shape[0]

    @pl.when(is_first)
    def _init_sumsq():
        f_ref[...] = jnp.zeros_like(f_ref)

    @pl.when(phase == 0)
    def _phase0():
        f_ref[...] += jnp.sum(x * x, axis=(1, 2)).reshape(s_n, 1)
        # outputs must be written every visit; phase 1 overwrites
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(phase == 1)
    def _phase1():
        norm = jnp.sqrt(f_ref[...])                          # (S, 1)
        factor = jnp.minimum(1.0, clip / jnp.maximum(norm, NORM_FLOOR))
        coef = w_ref[...] * factor[:, 0]                     # (S,)
        acc_ref[...] = jnp.sum(coef[:, None, None] * x, axis=0)

        @pl.when(is_last)
        def _store_factors():
            f_ref[...] = factor


@functools.partial(jax.jit, static_argnames=("interpret",))
def clip_accumulate_3d(x: jax.Array, w: jax.Array, clip: jax.Array, *,
                       interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array]:
    """x: (S, R, LANES) f32 stacked per-client updates, R % BLOCK_ROWS
    == 0; w: (S,) f32 aggregation weights; clip: scalar f32 L2 bound.

    Returns ``(acc (R, LANES) f32, factors (S, 1) f32)`` with
    ``acc = sum_s w[s] * min(1, clip/||x[s]||) * x[s]``.
    """
    s_n, r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (s_n, r, c)
    assert w.shape == (s_n,), (w.shape, s_n)
    grid = (2, r // BLOCK_ROWS)
    return pl.pallas_call(
        functools.partial(_kernel, n_row_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),      # clip scalar
            pl.BlockSpec(memory_space=pltpu.SMEM),      # weights (S,)
            pl.BlockSpec((s_n, BLOCK_ROWS, LANES),
                         lambda p, i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda p, i: (i, 0)),
            pl.BlockSpec((s_n, 1), lambda p, i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((s_n, 1), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(clip, jnp.float32).reshape(1),
      w.astype(jnp.float32), x.astype(jnp.float32))
