"""jit'd wrapper: run a stacked (S, ...) upload pytree through the fused
clip-accumulate kernel (per-client flatten + concat -> pad to (R, LANES)
tiles -> kernel -> slice + unflatten the accumulated mean).

Zero padding is norm- and output-correct by construction: pads add
nothing to a client's squared norm, accumulate to zeros, and are sliced
off before the tree is rebuilt.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.clipacc.clipacc import (BLOCK_ROWS, LANES,
                                           clip_accumulate_3d)

TILE = BLOCK_ROWS * LANES
Tree = Any


def tree_clip_accumulate(stacked: Tree, *, clip, weights: jax.Array,
                         interpret: bool = True) -> Tuple[Tree, jax.Array]:
    """``stacked``: pytree whose leaves carry a leading (S,) client axis;
    ``weights``: (S,) f32 (uniform DP aggregation passes ``1/S``).

    Returns ``(mean_tree, factors (S, 1))`` where ``mean_tree`` has the
    per-leaf structure/dtype of one client's upload entry and equals
    ``sum_s w_s * min(1, clip/||upload_s||) * upload_s`` with the JOINT
    L2 norm taken across ALL leaves of client s.
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    s_n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [leaf.astype(jnp.float32).reshape(s_n, -1) for leaf in leaves],
        axis=1)
    total = flat.shape[1]
    pad = (-total) % TILE
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((s_n, pad), jnp.float32)], axis=1)
    x3d = flat.reshape(s_n, -1, LANES)
    acc, factors = clip_accumulate_3d(x3d, weights, clip,
                                      interpret=interpret)
    acc = acc.reshape(-1)[:total]
    out, offset = [], 0
    for leaf in leaves:
        size = leaf[0].size
        out.append(acc[offset:offset + size]
                   .reshape(leaf.shape[1:]).astype(leaf.dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, out), factors
