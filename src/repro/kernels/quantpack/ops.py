"""jit'd wrapper: run arbitrary leaves through the fused quantize-pack
kernel (flatten -> pad to (R, LANES) tiles -> kernel -> slice to the
exact wire length).

Zero padding is mask-correct by construction: pads cannot raise the
absmax, quantize to code 0 (int8) / 8 (int4 offset) and are sliced off —
except the shared final nibble of an odd-length int4 tensor, which holds
the same zero code the jnp codec writes, so the wire bytes are identical.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.quantpack.quantpack import (
    BLOCK_ROWS, LANES, quantpack_int4_2d, quantpack_int8_2d)

TILE = BLOCK_ROWS * LANES


def _pad_to_tiles(flat: jax.Array) -> jax.Array:
    pad = (-flat.size) % TILE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(-1, LANES)


def quantpack_leaf(x: jax.Array, *, bits: int,
                   key: Optional[jax.Array] = None,
                   interpret: bool = True) -> Dict[str, jax.Array]:
    """One tensor -> wire payload dict, same format as the jnp codec path
    (``repro.comm.codecs``): int8 -> {"q": int8 (n,), "scale": ()};
    int4 -> {"q": packed uint8 (ceil(n/2),), "scale": ()}."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.size
    x2d = _pad_to_tiles(flat)
    if bits == 8:
        q, scale = quantpack_int8_2d(x2d, interpret=interpret)
        return {"q": q.reshape(-1)[:n], "scale": scale[0, 0]}
    if bits != 4:
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    packed, scale = quantpack_int4_2d(x2d, u, interpret=interpret)
    return {"q": packed.reshape(-1)[:(n + 1) // 2], "scale": scale[0, 0]}
