"""Fused quantize-pack kernel for the upload codecs (repro.comm)."""
from repro.kernels.quantpack.quantpack import (
    BLOCK_ROWS,
    LANES,
    quantpack_int4_2d,
    quantpack_int8_2d,
)
from repro.kernels.quantpack.ops import quantpack_leaf

__all__ = ["BLOCK_ROWS", "LANES", "quantpack_int4_2d", "quantpack_int8_2d",
           "quantpack_leaf"]
