"""Pallas TPU kernel: fused per-tensor quantize + pack for upload codecs.

The int8/int4 upload codecs (repro.comm.codecs) need, per tensor:

    absmax -> scale -> q = round/clip(x / scale) -> packed codes

Unfused, XLA materializes the scaled f32 tensor and the int32 codes in
HBM between stages (>= 8 extra bytes/elem). This kernel computes the
per-tensor scale and emits the packed wire bytes in one pallas_call:
a two-phase sequential grid walks the row tiles twice — phase 0
accumulates the global absmax into a VMEM-resident (1, 1) accumulator
(the scale output block, pinned by its index map, exactly the blockmean
accumulator idiom), phase 1 reads it, quantizes and packs. HBM traffic:
2 reads of x + 1 write of the (1-4x smaller) codes; the f32 intermediate
never exists.

int8: round-to-nearest, one int8 code per element.
int4: stochastic rounding q = floor(x/scale + u) against caller-supplied
uniform noise u (unbiased; bits ride in as an operand rather than the
in-kernel PRNG so interpret mode and the jnp reference see identical
randomness), two offset-8 nibbles packed per byte — element 2i in the
low nibble, matching ``repro.comm.codecs.pack_nibbles``.

Scales are bit-exact vs ``ref.py`` (max-reductions are order-invariant
and the scale formula is identical); codes match exactly for the same
noise.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import numpy as np

LANES = 1024          # last-dim tile (multiple of 128)
BLOCK_ROWS = 64       # rows per grid step (multiple of 8 for f32 sublanes)
SCALE_FLOOR = 1e-12   # guards all-zero tensors
INV_QMAX8 = float(np.float32(1.0 / 127.0))
INV_QMAX4 = float(np.float32(1.0 / 7.0))


# NOTE: every pl.program_id call is hoisted to the top of the kernel
# bodies — calling it inside a pl.when branch breaks interpret mode
# (the cond branch is lowered outside the grid axis environment).

def _phase_flags(n_row_blocks: int):
    phase = pl.program_id(0)
    blk = pl.program_id(1)
    return phase, (phase == 0) & (blk == 0), \
        (phase == 1) & (blk == n_row_blocks - 1)


def _accumulate_absmax(x_ref, acc_ref, is_first):
    @pl.when(is_first)
    def _init():
        acc_ref[0, 0] = 0.0

    acc_ref[0, 0] = jnp.maximum(acc_ref[0, 0],
                                jnp.max(jnp.abs(x_ref[...])))


def _finalize_scale(acc_ref, inv_qmax: float, is_last):
    """Convert the absmax accumulator into the scale on the last visit
    (earlier phase-1 steps still need to read the raw absmax).

    ``inv_qmax`` is the f32-rounded reciprocal: a single multiply is
    bit-deterministic, whereas ``/ qmax`` is rewritten by XLA into a
    reciprocal-multiply whose rounding differs from true division."""
    scale = jnp.maximum(acc_ref[0, 0], SCALE_FLOOR) * inv_qmax

    @pl.when(is_last)
    def _store():
        acc_ref[0, 0] = scale

    return scale


def _int8_kernel(x_ref, q_ref, scale_ref, *, n_row_blocks: int):
    phase, is_first, is_last = _phase_flags(n_row_blocks)

    @pl.when(phase == 0)
    def _phase0():
        _accumulate_absmax(x_ref, scale_ref, is_first)
        q_ref[...] = jnp.zeros_like(q_ref)

    @pl.when(phase == 1)
    def _phase1():
        scale = _finalize_scale(scale_ref, INV_QMAX8, is_last)
        q = jnp.clip(jnp.round(x_ref[...] / scale), -127, 127)
        q_ref[...] = q.astype(jnp.int8)


def _int4_kernel(x_ref, u_ref, q_ref, scale_ref, *, n_row_blocks: int):
    phase, is_first, is_last = _phase_flags(n_row_blocks)

    @pl.when(phase == 0)
    def _phase0():
        _accumulate_absmax(x_ref, scale_ref, is_first)
        q_ref[...] = jnp.zeros_like(q_ref)

    @pl.when(phase == 1)
    def _phase1():
        scale = _finalize_scale(scale_ref, INV_QMAX4, is_last)
        q = jnp.clip(jnp.floor(x_ref[...] / scale + u_ref[...]), -8, 7)
        codes = (q + 8).astype(jnp.uint8)
        # consecutive lane pairs -> one byte, low nibble first
        pairs = codes.reshape(codes.shape[0], -1, 2)
        q_ref[...] = pairs[..., 0] | (pairs[..., 1] << 4)


def _common_specs(r: int):
    grid = (2, r // BLOCK_ROWS)
    x_spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda p, i: (i, 0))
    scale_spec = pl.BlockSpec((1, 1), lambda p, i: (0, 0),
                              memory_space=pltpu.SMEM)
    return grid, x_spec, scale_spec


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantpack_int8_2d(x: jax.Array, *, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array]:
    """x: (R, LANES) f32, R % BLOCK_ROWS == 0 -> (codes int8 (R, LANES),
    scale f32 (1, 1))."""
    r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (r, c)
    grid, x_spec, scale_spec = _common_specs(r)
    return pl.pallas_call(
        functools.partial(_int8_kernel, n_row_blocks=grid[1]),
        grid=grid,
        in_specs=[x_spec],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda p, i: (i, 0)),
                   scale_spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int8),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantpack_int4_2d(x: jax.Array, u: jax.Array, *, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array]:
    """x, u: (R, LANES) f32 (u ~ U[0,1) rounding noise), R % BLOCK_ROWS
    == 0 -> (packed uint8 (R, LANES // 2), scale f32 (1, 1))."""
    r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (r, c)
    assert u.shape == x.shape, (u.shape, x.shape)
    grid, x_spec, scale_spec = _common_specs(r)
    return pl.pallas_call(
        functools.partial(_int4_kernel, n_row_blocks=grid[1]),
        grid=grid,
        in_specs=[x_spec, x_spec],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES // 2),
                                lambda p, i: (i, 0)),
                   scale_spec],
        out_shape=[jax.ShapeDtypeStruct((r, c // 2), jnp.uint8),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), u.astype(jnp.float32))
