"""Pure-jnp oracle for the fused quantize-pack kernel.

Same scale formula and rounding as the kernel so parity is exact: scales
bit-exact (max-reduction order cannot change the result, the division is
the same op), codes exact for identical noise.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantpack.quantpack import (INV_QMAX4, INV_QMAX8,
                                               SCALE_FLOOR)


def quantpack_int8_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) f32 -> (codes int8 (R, C), scale f32 ())."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), SCALE_FLOOR) * INV_QMAX8
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantpack_int4_ref(x: jax.Array, u: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """x, u: (R, C) f32, C even -> (packed uint8 (R, C // 2), scale ())."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), SCALE_FLOOR) * INV_QMAX4
    q = jnp.clip(jnp.floor(x32 / scale + u), -8, 7)
    codes = (q + 8).astype(jnp.uint8)
    pairs = codes.reshape(codes.shape[0], -1, 2)
    return pairs[..., 0] | (pairs[..., 1] << 4), scale
