from repro.kernels.fused_adamw import ops, ref
from repro.kernels.fused_adamw.fused_adamw import fused_adamw_2d

__all__ = ["ops", "ref", "fused_adamw_2d"]
