"""jit'd wrapper: route arbitrary parameter pytrees through the fused
FedAdamW Pallas kernel (flatten -> pad to (R, LANES) -> kernel -> unflatten).

Small leaves (< one tile) are batched together into a single packed buffer
so the kernel never launches on degenerate shapes; the pack/unpack is pure
reshape/concat (no HBM blowup — XLA fuses it with the surrounding scan).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_adamw.fused_adamw import (
    BLOCK_ROWS, LANES, fused_adamw_2d)

TILE = BLOCK_ROWS * LANES


def _pack(tree) -> Tuple[jax.Array, Any]:
    leaves = jax.tree.leaves(tree)
    flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    total = sum(l.size for l in flat)
    pad = (-total) % TILE
    if pad:
        flat.append(jnp.zeros((pad,), jnp.float32))
    packed = jnp.concatenate(flat).reshape(-1, LANES)
    return packed, None


def _unpack(packed: jax.Array, template) -> Any:
    leaves, treedef = jax.tree.flatten(template)
    flat = packed.reshape(-1)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def tree_fused_adamw_step(params, grads, m, v, delta_g, *, beta1, beta2,
                          c1, c2, lr, alpha, lam, eps,
                          interpret: bool = True):
    """One fused FedAdamW local step over a whole parameter pytree.

    Returns (params', m', v'). Scalars may be python floats or traced."""
    scalars = jnp.stack([
        jnp.asarray(beta1, jnp.float32), jnp.asarray(beta2, jnp.float32),
        jnp.asarray(c1, jnp.float32), jnp.asarray(c2, jnp.float32),
        jnp.asarray(lr, jnp.float32), jnp.asarray(alpha, jnp.float32),
        jnp.asarray(lam, jnp.float32), jnp.asarray(eps, jnp.float32)])
    xp, _ = _pack(params)
    gp, _ = _pack(grads)
    mp, _ = _pack(m)
    vp, _ = _pack(v)
    dgp, _ = _pack(delta_g)
    x2, m2, v2 = fused_adamw_2d(xp, gp, mp, vp, dgp, scalars,
                                interpret=interpret)
    return (_unpack(x2, params), _unpack(m2, m), _unpack(v2, v))


def tree_fused_adamw_apply(params, m, v, delta_g, *, c1, c2, lr, alpha, lam,
                           eps, interpret: bool = True):
    """Apply-only variant (moments already updated): used when the caller
    computed (m, v) separately. Implemented by running the fused kernel with
    beta1 = beta2 = 1 so the moment updates are identity."""
    zeros = jax.tree.map(jnp.zeros_like, m)
    x2, _, _ = tree_fused_adamw_step(
        params, zeros, m, v, delta_g, beta1=1.0, beta2=1.0,
        c1=c1, c2=c2, lr=lr, alpha=alpha, lam=lam, eps=eps,
        interpret=interpret)
    return x2
