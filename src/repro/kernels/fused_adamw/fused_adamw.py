"""Pallas TPU kernel: fused FedAdamW local update (paper Algorithm 2 l.8-15).

One VMEM pass per tile computes

    m' = b1*m + (1-b1)*g
    v' = b2*v + (1-b2)*g*g
    x' = x - lr*( (m'/c1) / (sqrt(v'/c2) + eps) + alpha*dg + lam*x )

Roofline motivation (DESIGN.md §5): the update does ~14 flops per element
while touching 5 input + 3 output streams. Unfused, XLA on this pattern
materializes m', v', m_hat, v_hat and the step separately (>= 20 bytes/elem
extra HBM traffic); the fused kernel moves exactly
read(x,g,m,v,dg) + write(x,m,v) = 32 bytes/elem fp32 — the hard floor.

TPU mapping: parameters are flattened and padded to (R, 128*LANES) tiles;
a 1-D grid walks row-blocks. Scalars (b1, b2, c1, c2, lr, alpha, lam, eps)
ride in SMEM, so one compiled kernel serves every (k, t) bias-correction
step inside the K-step ``lax.scan``. Tile (64, 1024) f32: 8 operands *
256 KiB = 2 MiB live in VMEM — comfortable double-buffering headroom in
16 MiB v5e VMEM; last dim 1024 = 8 * 128 lanes, rows 64 = 8 sublanes * 8.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 1024          # last-dim tile (multiple of 128)
BLOCK_ROWS = 64       # rows per grid step (multiple of 8 for f32 sublanes)


def _kernel(s_ref, x_ref, g_ref, m_ref, v_ref, dg_ref,
            x_out, m_out, v_out):
    b1, b2, c1, c2, lr, alpha, lam, eps = (s_ref[i] for i in range(8))
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    x = x_ref[...]
    step = (m / c1) / (jnp.sqrt(v / c2) + eps) + alpha * dg_ref[...] + lam * x
    x_out[...] = x - lr * step
    m_out[...] = m
    v_out[...] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_adamw_2d(x: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                   dg: jax.Array, scalars: jax.Array, *,
                   interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All operands (R, LANES) f32 with R % BLOCK_ROWS == 0.

    scalars: (8,) f32 = [beta1, beta2, c1, c2, lr, alpha, lam, eps].
    Returns (x', m', v').
    """
    r, c = x.shape
    assert c == LANES and r % BLOCK_ROWS == 0, (r, c)
    grid = (r // BLOCK_ROWS,)
    tile = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((r, c), jnp.float32)] * 3
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + [tile] * 5,
        out_specs=[tile] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(scalars, x, g, m, v, dg)
