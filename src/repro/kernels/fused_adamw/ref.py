"""Pure-jnp oracle for the fused FedAdamW update kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fused_adamw_ref(x: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                    dg: jax.Array, scalars: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Same contract as fused_adamw_2d, any shape/dtype (computed in f32)."""
    b1, b2, c1, c2, lr, alpha, lam, eps = [scalars[i] for i in range(8)]
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    m2 = b1 * m.astype(jnp.float32) + (1.0 - b1) * gf
    v2 = b2 * v.astype(jnp.float32) + (1.0 - b2) * gf * gf
    step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps) \
        + alpha * dg.astype(jnp.float32) + lam * xf
    x2 = xf - lr * step
    return x2, m2, v2
