"""Pallas TPU kernel: tiled column-mean reduction for the block-mean
second-moment upload (paper Eq. 4, ``v_bar_b = mean(v_b)``).

The dominant partition classes (Class 2/3: per-output-neuron blocks of
``attn.proj``/MLP/value matrices) reduce a (d_in, d_out) leaf over d_in —
a *column* mean, strided in memory. A naive XLA reduce on the transposed
layout materializes a transpose; this kernel streams row-tiles through
VMEM and accumulates per-column partial sums into a single resident
(1, C)-tile output across sequential grid steps — one HBM read of the
operand, no transpose, 4 bytes/elem moved (the floor).

Grid: (C // BLOCK_COLS, R // BLOCK_ROWS) — column tiles outer, row tiles
inner, so each output tile is initialized once (row step 0) and stays in
VMEM for the whole inner walk (TPU grids iterate minor-most fastest).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
BLOCK_COLS = 512


def _kernel(x_ref, o_ref, *, r_total: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].sum(axis=0, keepdims=True) / r_total


@functools.partial(jax.jit, static_argnames=("interpret",))
def column_mean_2d(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x: (R, C) f32, R % BLOCK_ROWS == 0, C % BLOCK_COLS == 0 -> (C,)."""
    r, c = x.shape
    assert r % BLOCK_ROWS == 0 and c % BLOCK_COLS == 0, (r, c)
    grid = (c // BLOCK_COLS, r // BLOCK_ROWS)
    out = pl.pallas_call(
        functools.partial(_kernel, r_total=r),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS),
                               lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, BLOCK_COLS), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))
    return out[0]
