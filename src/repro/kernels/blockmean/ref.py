"""Pure-jnp oracle for the column-mean block reduction."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def column_mean_ref(x: jax.Array) -> jax.Array:
    """x: (R, C) any float dtype -> (C,) f32 column means."""
    return x.astype(jnp.float32).mean(axis=0)
