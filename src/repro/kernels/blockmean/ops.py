"""jit'd wrapper: block means for (rows, blocks)-shaped views.

``block_means_2d`` pads both dims to kernel tile multiples with a
mask-correct scheme: row padding contributes zeros to the sums and the
divisor uses the true row count; column padding is sliced off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.blockmean.blockmean import (
    BLOCK_COLS, BLOCK_ROWS, column_mean_2d)


def block_means_2d(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x: (R, C) -> (C,) column means via the Pallas kernel, any R/C."""
    r, c = x.shape
    rp = (-r) % BLOCK_ROWS
    cp = (-c) % BLOCK_COLS
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp), (0, cp)))
    # kernel divides by the padded row count; rescale to the true mean
    means = column_mean_2d(xp, interpret=interpret) * ((r + rp) / r)
    return means[:c]
