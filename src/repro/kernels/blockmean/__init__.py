from repro.kernels.blockmean import ops, ref
from repro.kernels.blockmean.blockmean import column_mean_2d

__all__ = ["ops", "ref", "column_mean_2d"]
