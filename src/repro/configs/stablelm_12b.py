"""StableLM-2-12B [hf:stabilityai/stablelm-2-1_6b family card]: dense
decoder with GQA. 40L, d_model 5120, 32 heads / 8 KV, d_ff 13824,
vocab 100352."""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("stablelm-12b")
def stablelm_12b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        d_ff=13824,
        vocab_size=100352,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8,
                                  rope_theta=10000.0),
        norm_type="layernorm",
        mlp_type="swiglu",
        fl_layout="client_parallel",
        source="StableLM 2 [hf:stabilityai/stablelm-2-1_6b model card]",
    )
