"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family
card]: MoE decoder, 128 experts top-1 + one always-on shared expert,
early-fusion multimodal (text path modeled; the fusion frontend follows the
VLM stub carve-out but this assignment lists the language backbone).

48L, d_model 5120, 40 heads / 8 KV, expert d_ff 8192, vocab 202048.
128 experts % 16 chips == 0 -> expert-parallel sharding. ~400B total
parameters, ~17B active -> client_sequential layout + MoE FLOP accounting
uses N_active (DESIGN.md roofline notes)."""
from repro.config import AttentionConfig, MoEConfig, ModelConfig, register_arch


@register_arch("llama4-maverick-400b-a17b")
def llama4_maverick() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        d_ff=8192,
        vocab_size=202048,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8,
                                  head_dim=128,
                                  rope_theta=500000.0),
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      capacity_factor=1.25, aux_loss_weight=0.01,
                      num_shared_experts=1),
        norm_type="rmsnorm",
        mlp_type="swiglu",
        moe_shard="ep",
        fl_layout="client_sequential",
        source="Llama 4 [hf:meta-llama/Llama-4-Scout-17B-16E model card]",
    )
