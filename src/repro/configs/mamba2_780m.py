"""Mamba2-780m [arXiv:2405.21060]: attention-free SSD (state-space duality)
stack. 48L, d_model 1536 (d_inner 3072, 48 SSD heads of 64), state 128,
vocab 50280.

FedAdamW applicability (DESIGN.md §Arch-applicability): the paper's
attention-specific Hessian partition classes (query/key per head, value per
neuron) are inapplicable; SSD tensors fall back to Appendix D Algorithm 4
per-tensor blocks refined per head where a head dimension exists
(A_log/D/dt_bias) and per channel for conv/projections."""
from repro.config import AttentionConfig, ModelConfig, SSMConfig, register_arch


@register_arch("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        d_ff=0,                           # attention-free: no MLP blocks
        vocab_size=50280,
        attention=AttentionConfig(num_heads=1, num_kv_heads=1),  # unused
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256,
                      conv_width=4, ngroups=1),
        norm_type="rmsnorm",
        tie_embeddings=True,
        fl_layout="client_parallel",
        source="Mamba2 / SSD [arXiv:2405.21060]",
    )
