"""Mixtral-8x7B [arXiv:2401.04088]: sparse MoE decoder, 8 experts top-2,
sliding-window attention (4096). 32L, d_model 4096, 32 heads / 8 KV,
expert d_ff 14336, vocab 32000.

8 experts < 16 model-axis chips -> tensor-parallel expert sharding
(``moe_shard="tp"``: the expert F dim shards over ``model``); native SWA
means the ``long_500k`` decode shape runs with a windowed KV cache."""
from repro.config import AttentionConfig, MoEConfig, ModelConfig, register_arch


@register_arch("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8,
                                  sliding_window=4096,
                                  rope_theta=1000000.0),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336,
                      capacity_factor=1.25, aux_loss_weight=0.01),
        norm_type="rmsnorm",
        mlp_type="swiglu",
        moe_shard="tp",
        fl_layout="client_sequential",
        source="Mixtral of Experts [arXiv:2401.04088]",
    )
