"""OLMo-1B [arXiv:2402.00838]: dense decoder, non-parametric LayerNorm.

16L, d_model 2048, 16 heads (MHA: kv=16), d_ff 8192, vocab 50304.
The ``olmo-1b-swa`` variant adds a 4096-token sliding window so at least
one *dense* architecture exercises the ``long_500k`` decode path
(beyond-paper; DESIGN.md §4 shape-skip table).
"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, register_arch


def _base() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        d_ff=8192,
        vocab_size=50304,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                                  rope_theta=10000.0),
        norm_type="nonparam_ln",
        mlp_type="swiglu",
        tie_embeddings=True,          # OLMo-1B ties embeddings
        fl_layout="client_parallel",
        source="OLMo: Accelerating the Science of LMs [arXiv:2402.00838]",
    )


@register_arch("olmo-1b")
def olmo_1b() -> ModelConfig:
    return _base()


@register_arch("olmo-1b-swa")
def olmo_1b_swa() -> ModelConfig:
    cfg = _base()
    return dataclasses.replace(
        cfg, name="olmo-1b-swa",
        attention=dataclasses.replace(cfg.attention, sliding_window=4096),
        source=cfg.source + " + sliding-window variant (this work)",
    )
