"""Qwen2-VL-2B [arXiv:2409.12191]: VLM language tower with M-RoPE and
dynamic-resolution patch input. 28L, d_model 1536, 12 heads / 2 KV
(head_dim 128), d_ff 8960, vocab 151936.

The vision encoder is the allowed stub: ``input_specs`` provides
precomputed patch embeddings (1280-d, the ViT output dim) consumed through
a linear projector; the language tower interleaves them with text tokens
and rotates positions with the (t, h, w)-split M-RoPE."""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("qwen2-vl-2b")
def qwen2_vl_2b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        d_ff=8960,
        vocab_size=151936,
        attention=AttentionConfig(num_heads=12, num_kv_heads=2,
                                  head_dim=128, qkv_bias=True,
                                  use_mrope=True,
                                  mrope_sections=(16, 24, 24),
                                  rope_theta=1000000.0),
        norm_type="rmsnorm",
        mlp_type="swiglu",
        frontend_embed_dim=1280,          # ViT patch-embedding dim (stub)
        frontend_tokens_per_sample=64,    # one 8x8 patch grid per sample
        fl_layout="client_parallel",
        source="Qwen2-VL [arXiv:2409.12191]",
    )
