"""Assigned architecture configs (``--arch <id>``). Importing this package
registers every architecture with the registry. Each module cites its
source paper / model card."""
from repro.configs import (  # noqa: F401
    olmo_1b,
    stablelm_12b,
    qwen2_72b,
    qwen3_32b,
    qwen2_vl_2b,
    mixtral_8x7b,
    zamba2_2p7b,
    llama4_maverick,
    seamless_m4t,
    mamba2_780m,
    paper_models,
)
