"""SeamlessM4T-Large-v2 [arXiv:2308.11596]: encoder-decoder speech/text
model. 24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA),
d_ff 8192, vocab 256206.

The speech frontend (mel-spectrogram + conformer conv feature extractor)
is the allowed stub: ``input_specs`` provides precomputed 1024-d frame
embeddings; we implement the transformer encoder over those frames and the
causal decoder with per-layer cross-attention. Decode shapes run the
*decoder* step against a fixed encoder memory (DESIGN.md §4)."""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("seamless-m4t-large-v2")
def seamless_m4t() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,
        encoder_layers=24,
        d_model=1024,
        d_ff=8192,
        vocab_size=256206,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                                  rope_theta=10000.0),
        norm_type="layernorm",
        mlp_type="gelu",
        frontend_embed_dim=1024,           # conformer frame embedding (stub)
        frontend_tokens_per_sample=160,    # ~10 s of 16 Hz frames
        fl_layout="client_parallel",
        source="SeamlessM4T [arXiv:2308.11596]",
    )
