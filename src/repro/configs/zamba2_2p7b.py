"""Zamba2-2.7B [arXiv:2411.15242]: hybrid Mamba2 backbone with a *shared*
attention block inserted periodically. 54L, d_model 2560, Mamba2 state 64;
the shared attention block uses 32 heads (MHA), d_ff 10240.

TPU/long-context adaptation (DESIGN.md §4): the shared attention block gets
a 4096 sliding window so ``long_500k`` decode keeps O(window) memory —
Zamba2 itself uses full attention at 4k train lengths; the window is a
beyond-paper serving adaptation, recorded in EXPERIMENTS.md."""
from repro.config import AttentionConfig, ModelConfig, SSMConfig, register_arch


@register_arch("zamba2-2.7b")
def zamba2_2p7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        d_ff=10240,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=32,
                                  sliding_window=4096,
                                  rope_theta=10000.0),
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256,
                      conv_width=4, ngroups=1),
        hybrid_attn_every=6,
        hybrid_shared_attn=True,
        norm_type="rmsnorm",
        mlp_type="swiglu",
        fl_layout="client_parallel",
        source="Zamba2 [arXiv:2411.15242]",
    )
