"""The paper's own experimental models, as registry entries.

``vit-tiny-fl``   the paper's ViT-Tiny-on-CIFAR-100 setting, mapped to the
                  synthetic class_lm task (DESIGN.md §6 assumption #1): a
                  6-layer, d=192, 3-head dense transformer matching the
                  paper's Appendix C ViT-Tiny dims.
``roberta-base-fl`` proxy for the paper's RoBERTa-Base+LoRA GLUE setting:
                  12L, d=768, 12 heads, GELU MLP, LayerNorm (RoPE instead
                  of learned positions — noted deviation).
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("vit-tiny-fl")
def vit_tiny_fl() -> ModelConfig:
    return ModelConfig(
        name="vit-tiny-fl",
        family="dense",
        num_layers=6,
        d_model=192,
        d_ff=768,
        vocab_size=128,                 # synthetic class_lm vocab
        attention=AttentionConfig(num_heads=3, num_kv_heads=3),
        norm_type="layernorm",
        mlp_type="gelu",
        fl_layout="client_parallel",
        source="paper Appendix C ViT-Tiny (synthetic-task analogue)",
    )


@register_arch("roberta-base-fl")
def roberta_base_fl() -> ModelConfig:
    return ModelConfig(
        name="roberta-base-fl",
        family="dense",
        num_layers=12,
        d_model=768,
        d_ff=3072,
        vocab_size=50304,
        attention=AttentionConfig(num_heads=12, num_kv_heads=12),
        norm_type="layernorm",
        mlp_type="gelu",
        fl_layout="client_parallel",
        source="paper Appendix C RoBERTa-Base (+LoRA) proxy",
    )
