"""Qwen3-32B [hf:Qwen/Qwen3-8B card, scaled per assignment]: dense decoder,
GQA + per-head q/k RMSNorm (qk_norm). 64L, d_model 5120, 64 heads / 8 KV
(head_dim 128 as in the Qwen3 family), d_ff 25600, vocab 151936."""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("qwen3-32b")
def qwen3_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        d_ff=25600,
        vocab_size=151936,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8,
                                  head_dim=128, qk_norm=True,
                                  rope_theta=1000000.0),
        norm_type="rmsnorm",
        mlp_type="swiglu",
        fl_layout="client_sequential",
        source="Qwen3 [hf:Qwen/Qwen3-8B model card]",
    )
