"""Qwen2-72B [arXiv:2407.10671]: dense decoder, GQA with QKV bias.

80L, d_model 8192, 64 heads / 8 KV (head_dim 128), d_ff 29568,
vocab 152064. AdamW state for 72B params cannot fit the client_parallel
layout's 16-chip replicas -> client_sequential (FSDPxTP; DESIGN.md §2).
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


@register_arch("qwen2-72b")
def qwen2_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        family="dense",
        num_layers=80,
        d_model=8192,
        d_ff=29568,
        vocab_size=152064,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8,
                                  head_dim=128, qkv_bias=True,
                                  rope_theta=1000000.0),
        norm_type="rmsnorm",
        mlp_type="swiglu",
        fl_layout="client_sequential",
        source="Qwen2 Technical Report [arXiv:2407.10671]",
    )
