import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay the very first statements — jax locks
# the device count on first init — which is also why this module has no
# `from __future__ import annotations`.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh, with ZERO real allocation (ShapeDtypeStruct inputs).

The two lines above MUST precede any other import (jax locks the device
count on first init); 512 placeholder host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Per combination this prints/records:
  * ``compiled.memory_analysis()``  -> bytes per device (proves it fits)
  * ``compiled.cost_analysis()``    -> HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the compiled HLO (per collective kind)

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import (FedConfig, INPUT_SHAPES, InputShape, ModelConfig,
                          get_arch)
from repro.core.rounds import make_round_fn
from repro.core.serve import make_serve_step
from repro.launch import input_specs as ispecs
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline import model_flops, roofline_terms
from repro.roofline.analysis import count_params
from repro.roofline.hlo_counter import analyze_hlo
from repro.sharding import specs as shspecs
from jax.sharding import NamedSharding, PartitionSpec as P

ASSIGNED_ARCHS = [
    "olmo-1b", "stablelm-12b", "qwen2-72b", "qwen3-32b", "qwen2-vl-2b",
    "mixtral-8x7b", "zamba2-2.7b", "llama4-maverick-400b-a17b",
    "seamless-m4t-large-v2", "mamba2-780m",
]
EXTRA_ARCHS = ["olmo-1b-swa"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# Architectures that can serve a 524288-token context (sub-quadratic or
# windowed decode memory); the rest skip long_500k — DESIGN.md §4.
def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    if shape == "long_500k" and not cfg.supports_long_context_decode:
        return ("full-attention KV cache at 524288 tokens is quadratic-cost "
                "/ O(seq) memory per request; arch has no sliding-window or "
                "state-space decode path (see olmo-1b-swa for the dense SWA "
                "variant)")
    return None


def _dryrun_fed(cfg: ModelConfig, local_steps: int,
                microbatches: int = 1) -> FedConfig:
    return FedConfig(
        algorithm="fedadamw",
        layout=cfg.fl_layout,
        local_steps=local_steps,
        sequential_clients=2,
        grad_microbatches=microbatches,
        num_clients=1024, clients_per_round=32,  # bookkeeping only
    )


def auto_microbatches(b: int, seq: int, batch_shard: int,
                      target_tokens_per_chip: int = 8192) -> int:
    """Largest micro split that (a) divides the batch, (b) keeps the
    sharded batch sub-dim divisible by its mesh extent, (c) brings the
    per-chip per-micro-step token count near the target."""
    b_chip = max(1, b // batch_shard)
    mb = max(1, (b_chip * seq) // target_tokens_per_chip)
    mb = min(mb, b)
    while mb > 1 and (b % mb or (b // mb) % batch_shard):
        mb -= 1
    return mb


def lower_train(cfg: ModelConfig, mesh, ishape: InputShape, *,
                local_steps: int, remat: str, param_dtype,
                microbatches: int = 0) -> Any:
    # FSDP layout: anchor activations at block boundaries with batch over
    # the client axes AND sequence over `model` (sequence parallelism) —
    # batch-only constraints leave an 80-layer boundary-checkpoint stack
    # unsharded over `model` (16 GB/chip for qwen2-72b); seq-parallel
    # shards it 16x at the cost of per-layer all-gathers (the trade-off is
    # quantified in EXPERIMENTS.md §Dry-run).
    if cfg.fl_layout == "client_sequential":
        # d-model-sharded boundaries: feed row/column-parallel projections
        # directly. Measured on qwen2-72b train_4k multi (vs seq-parallel
        # boundaries at equal micro-batching): collective 2.0e4 -> 3.8e3 s,
        # HBM 8.0e3 -> 3.5e3 s, temp 12.8 -> 7.8 GB (EXPERIMENTS.md §Perf
        # pair 1). The same spec REGRESSES the client_parallel layout 4-8x
        # (measured on olmo-1b) and archs whose head count does not divide
        # the model axis (llama4 40H: HBM 5.5e3 -> 2.4e4 s) — those keep
        # sequence-parallel boundaries.
        cax = shspecs.client_axes(mesh)
        cax = cax if len(cax) > 1 else cax[0]
        if cfg.attention.num_heads % mesh.shape["model"] == 0:
            act_pspec = P(cax, None, "model")
        else:
            act_pspec = P(cax, "model", None)
    else:
        # client_parallel: per-client activations (under the client vmap)
        # are otherwise REPLICATED over `model` — sequence-parallel
        # boundaries shard the remat checkpoint stack 16x (hypothesis
        # validated in EXPERIMENTS.md §Perf memory iteration; holds for
        # every parallel-layout arch including non-divisible-head VLM:
        # 31.9 -> 17.9 GB/chip temp on qwen2-vl train_4k).
        act_pspec = P(None, "model", None)
    model = build_model(cfg, scan_layers=True, remat=remat,
                        compute_dtype=jnp.bfloat16, act_pspec=act_pspec)
    if microbatches <= 0:  # auto
        probe = _dryrun_fed(cfg, local_steps)
        _, b = ispecs.fed_geometry(cfg, mesh, probe, ishape)
        import numpy as np
        shard = (int(np.prod([mesh.shape[a]
                              for a in shspecs.client_axes(mesh)]))
                 if probe.layout == "client_sequential" else 1)
        microbatches = auto_microbatches(b, ishape.seq_len, shard)
    fed = _dryrun_fed(cfg, local_steps, microbatches)
    params, specs, alg, sstate = ispecs.abstract_fed_state(
        model, cfg, fed, param_dtype=param_dtype)
    round_fn = make_round_fn(model, fed, specs, alg=alg)

    param_ps = shspecs.param_pspecs(params, cfg, mesh, fed)
    state_ps = shspecs.state_pspecs(sstate, param_ps, params, cfg, mesh, fed)
    batch = ispecs.train_batch_specs(cfg, mesh, fed, ishape)
    nbatch = jax.tree.map(
        lambda s: NamedSharding(
            mesh, shspecs.batch_pspec(mesh, fed, rank=s.ndim)),
        batch)
    s_clients = jax.tree.leaves(batch)[0].shape[0]
    in_sh = (shspecs.named(mesh, param_ps), shspecs.named(mesh, state_ps),
             nbatch, NamedSharding(mesh, P(None)), NamedSharding(mesh, P()))
    out_sh = (shspecs.named(mesh, param_ps), shspecs.named(mesh, state_ps),
              None)
    # donate params + server state: the round updates them in place
    jitted = jax.jit(round_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    with mesh:
        lowered = jitted.lower(
            params, sstate, batch,
            jax.ShapeDtypeStruct((s_clients,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    # tokens processed per round program (for MODEL_FLOPS accounting)
    tok_shape = jax.tree.leaves(batch)[0].shape
    per_step_batch = (tok_shape[2] * tok_shape[3]
                      if fed.grad_microbatches > 1 else tok_shape[2])
    tokens = s_clients * fed.local_steps * per_step_batch * ishape.seq_len
    return lowered, tokens, {"K": fed.local_steps, "S": s_clients,
                             "layout": fed.layout,
                             "microbatches": fed.grad_microbatches}


def lower_prefill(cfg: ModelConfig, mesh, ishape: InputShape, *,
                  remat: str, param_dtype) -> Any:
    cax = shspecs.client_axes(mesh)
    bax = cax if len(cax) > 1 else cax[0]
    model = build_model(cfg, scan_layers=True, remat="none",
                        compute_dtype=jnp.bfloat16,
                        act_pspec=P(bax, None, None))
    params = ispecs.abstract_params(model, param_dtype)
    fed = _dryrun_fed(cfg, 1)
    param_ps = shspecs.param_pspecs(params, cfg, mesh, fed)
    batch = ispecs.prefill_batch_specs(cfg, ishape)
    nbatch = jax.tree.map(
        lambda s: NamedSharding(mesh, P(bax, *([None] * (s.ndim - 1)))),
        batch)

    def prefill(p, b):
        logits, _ = model.forward(p, b)
        return logits

    jitted = jax.jit(prefill,
                     in_shardings=(shspecs.named(mesh, param_ps), nbatch),
                     out_shardings=NamedSharding(mesh, P(bax, None, "model")))
    with mesh:
        lowered = jitted.lower(params, batch)
    tokens = ishape.global_batch * ishape.seq_len
    return lowered, tokens, {"layout": "inference"}


def lower_decode(cfg: ModelConfig, mesh, ishape: InputShape, *,
                 param_dtype) -> Any:
    import numpy as np
    cax = shspecs.client_axes(mesh)
    bax = cax if len(cax) > 1 else cax[0]
    bsz = int(np.prod([mesh.shape[a] for a in cax]))
    batch_shardable = ishape.global_batch % bsz == 0
    model = build_model(
        cfg, scan_layers=True, compute_dtype=jnp.bfloat16,
        act_pspec=P(bax, None, None) if batch_shardable else None)
    params = ispecs.abstract_params(model, param_dtype)
    fed = _dryrun_fed(cfg, 1)
    param_ps = shspecs.param_pspecs(params, cfg, mesh, fed)
    dspec = ispecs.decode_input_specs(model, cfg, ishape)
    cache_ps = shspecs.cache_pspecs(dspec["cache"], cfg, mesh)
    tok_ps = P(bax, None) if batch_shardable else P(None, None)

    serve = make_serve_step(model)
    has_memory = cfg.family == "audio"

    if has_memory:
        def step(p, tok, cache, memory):
            return serve(p, tok, cache, memory=memory)
        in_sh = (shspecs.named(mesh, param_ps),
                 NamedSharding(mesh, tok_ps),
                 shspecs.named(mesh, cache_ps),
                 NamedSharding(mesh, P(tok_ps[0], None, None)))
        args = (params, dspec["tokens"], dspec["cache"], dspec["memory"])
    else:
        def step(p, tok, cache):
            return serve(p, tok, cache)
        in_sh = (shspecs.named(mesh, param_ps),
                 NamedSharding(mesh, tok_ps),
                 shspecs.named(mesh, cache_ps))
        args = (params, dspec["tokens"], dspec["cache"])

    # donate the cache: serving updates it in place (alias in = alias out)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=None,
                     donate_argnums=(2,))
    with mesh:
        lowered = jitted.lower(*args)
    tokens = ishape.global_batch  # one new token per request
    return lowered, tokens, {"layout": "decode"}


def run_one(arch: str, shape: str, mesh_kind: str, *, local_steps: int = 8,
            remat: str = "full", param_dtype=jnp.bfloat16,
            microbatches: int = 0, out_dir: Optional[str] = None,
            save_hlo: bool = False) -> Dict[str, Any]:
    cfg = get_arch(arch)
    ishape = INPUT_SHAPES[shape]
    reason = skip_reason(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if reason:
        rec["status"] = "skip"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    if ishape.kind == "train":
        lowered, tokens, extra = lower_train(
            cfg, mesh, ishape, local_steps=local_steps, remat=remat,
            param_dtype=param_dtype, microbatches=microbatches)
        fwd_bwd = True
    elif ishape.kind == "prefill":
        lowered, tokens, extra = lower_prefill(
            cfg, mesh, ishape, remat=remat, param_dtype=param_dtype)
        fwd_bwd = False
    else:
        lowered, tokens, extra = lower_decode(
            cfg, mesh, ishape, param_dtype=param_dtype)
        fwd_bwd = False
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-aware HLO analysis (cost_analysis counts scan bodies once;
    # see repro.roofline.hlo_counter) — this is the roofline source of truth
    hc = analyze_hlo(hlo)

    mflops = model_flops(cfg, tokens)
    if not fwd_bwd:
        mflops /= 3.0
    # The compiled SPMD module is the PER-PARTITION program (every chip runs
    # it), so hc[...] are per-chip quantities: pass chips=1 to get per-chip
    # roofline seconds directly; the global total is per-chip * chips.
    terms = roofline_terms(
        {"flops": hc["flops"], "bytes accessed": hc["bytes"]},
        hc["collective_bytes"], 1)

    rec.update({
        "status": "ok",
        "chips": chips,
        "tokens_per_program": tokens,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis_raw": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "collective_bytes": {k.replace("collective_", ""): v
                             for k, v in hc.items()
                             if k.startswith("collective_")},
        "roofline": terms.as_dict(),
        "model_flops_6ND": mflops,
        "useful_flops_ratio": (mflops / (terms.flops * chips))
        if terms.flops else None,
        "params": count_params(cfg),
        **extra,
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}__{shape}__{mesh_kind}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        if save_hlo:
            with open(os.path.join(
                    out_dir, f"{arch}__{shape}__{mesh_kind}.hlo.txt"),
                    "w") as f:
                f.write(hlo)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=SHAPES + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="gradient micro-batches per local step (0 = auto)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args(argv)

    combos = []
    archs = (ASSIGNED_ARCHS + EXTRA_ARCHS) if args.all or not args.arch \
        else [args.arch]
    shapes = SHAPES if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    failures = 0
    for a, s, m in combos:
        try:
            rec = run_one(a, s, m, local_steps=args.local_steps,
                          remat=args.remat, microbatches=args.microbatch,
                          out_dir=args.out, save_hlo=args.save_hlo)
        except Exception:
            failures += 1
            print(f"[FAIL] {a} x {s} x {m}")
            traceback.print_exc()
            continue
        if rec["status"] == "skip":
            print(f"[SKIP] {a} x {s} x {m}: {rec['reason'][:80]}...")
        else:
            r = rec["roofline"]
            print(f"[OK]   {a} x {s} x {m}: compile {rec['compile_s']}s "
                  f"flops={r['flops']:.3g} hbmB={r['hbm_bytes']:.3g} "
                  f"collB={r['collective_bytes']:.3g} "
                  f"bottleneck={r['bottleneck']}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
