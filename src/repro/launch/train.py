"""Federated training driver (CPU-scale simulation of the paper's setup).

Runs R communication rounds of any registered algorithm on a synthetic
Dirichlet non-iid task, logging train loss / test accuracy / communication
bytes per round — the engine is the SAME jitted ``round_fn`` the multi-pod
dry-run lowers, just on the host mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch vit-tiny-fl \
      --algorithm fedadamw --rounds 30 --clients 16 --sample 8 \
      --local-steps 10 --dirichlet 0.1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import codec_for, upload_wire_bytes
from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import build_fed_state, make_round_fn, upload_shape_spec
from repro.data import make_task, round_batches, sample_clients
from repro.metrics import CSVLogger, Meter
from repro.models import build_model


def make_eval_fn(model):
    """One jitted loss for ALL eval rounds. ``jax.jit(model.loss)`` inside
    the eval call would build a fresh wrapper — and recompile — per round
    (bound methods compare unequal across accesses, so jit's cache never
    hits)."""
    return jax.jit(model.loss)


def evaluate(model, params, task, batch_size: int = 256,
             loss_fn=None) -> Dict[str, float]:
    loss_fn = loss_fn if loss_fn is not None else make_eval_fn(model)
    batch = task.test_batch(batch_size)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, metrics = loss_fn(params, batch)
    return {"test_loss": float(loss),
            "test_acc": float(metrics["accuracy"])}


def run_training(*, arch: str = "vit-tiny-fl", algorithm: str = "fedadamw",
                 rounds: int = 30, num_clients: int = 16,
                 clients_per_round: int = 8, local_steps: int = 10,
                 batch_size: int = 16, lr: Optional[float] = None,
                 weight_decay: float = 0.01, alpha: float = 0.5,
                 dirichlet: float = 0.6, seed: int = 0,
                 v_aggregation: str = "mean_v", decoupled_wd: bool = True,
                 reduce_model: bool = True,
                 task_kind: str = "class_lm", seq_len: int = 32,
                 log_path: str = "", eval_every: int = 5,
                 cosine: bool = True, use_pallas: bool = False,
                 layout: str = "client_parallel",
                 comm_error_feedback: bool = True,
                 use_pallas_quantpack: bool = False,
                 client_state_policy: str = "dense") -> Dict[str, list]:
    cfg = get_arch(arch)
    if reduce_model:
        cfg = reduced_variant(cfg)
    if lr is None:
        lr = 3e-4 if ("adam" in algorithm or algorithm == "fedlada") else 3e-2
    fed = FedConfig(
        algorithm=algorithm, num_clients=num_clients,
        clients_per_round=clients_per_round, local_steps=local_steps,
        rounds=rounds, lr=lr, weight_decay=weight_decay, alpha=alpha,
        v_aggregation=v_aggregation, decoupled_wd=decoupled_wd,
        layout=layout,
        sequential_clients=clients_per_round,
        use_pallas_update=use_pallas,
        comm_error_feedback=comm_error_feedback,
        use_pallas_quantpack=use_pallas_quantpack,
        client_state_policy=client_state_policy)
    model = build_model(cfg, compute_dtype=jnp.float32)
    task = make_task(task_kind, vocab_size=cfg.vocab_size, seq_len=seq_len,
                     num_samples=max(2048, 64 * num_clients),
                     num_clients=num_clients, dirichlet_alpha=dirichlet,
                     seed=seed)

    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(seed))
    round_fn = jax.jit(make_round_fn(
        model, fed, specs, alg=alg,
        cosine_total_rounds=rounds if cosine else 0))

    rng = np.random.default_rng(seed + 1)
    # declare the eval-only columns up front so every CSV carries them
    # even before the first eval round lands
    logger = CSVLogger(log_path, fieldnames=[
        "round", "train_loss", "upload_mbytes", "test_loss", "test_acc",
    ]) if log_path else None
    meter = Meter()
    eval_loss = make_eval_fn(model)
    history = {"round": [], "train_loss": [], "test_acc": [],
               "test_loss": [], "upload_mbytes": []}

    # per-client wire bytes (paper Table 7 accounting, codec-aware): the
    # delta entry is costed through the codec's packed payload, not its
    # dense dequantized f32 shape; EF residuals are client-resident and
    # cost nothing. Payload sizes are shape-static, so one abstract
    # evaluation prices every round.
    codec = codec_for(fed.algorithm)
    comm_bytes = upload_wire_bytes(
        upload_shape_spec(alg, params, sstate, specs, fed), codec)
    for r in range(rounds):
        cids = sample_clients(fed.num_clients, fed.clients_per_round, rng)
        batches = round_batches(task, cids, fed.local_steps, batch_size, rng)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        params, sstate, metrics = round_fn(
            params, sstate, batches, jnp.asarray(cids), jnp.asarray(r))
        loss = float(metrics["loss_mean"])
        meter.update(loss)
        rec = {"round": r, "train_loss": loss,
               "upload_mbytes": comm_bytes / 1e6}
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            rec.update(evaluate(model, params, task, loss_fn=eval_loss))
            history["round"].append(r)
            history["train_loss"].append(loss)
            history["test_acc"].append(rec["test_acc"])
            history["test_loss"].append(rec["test_loss"])
            history["upload_mbytes"].append(rec["upload_mbytes"])
        if logger:
            logger.log(rec)
    if logger:
        logger.close()
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-tiny-fl")
    ap.add_argument("--algorithm", default="fedadamw")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--weight-decay", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--dirichlet", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-model", action="store_true")
    ap.add_argument("--log", default="")
    ap.add_argument("--layout", default="client_parallel")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable error feedback for lossy upload codecs")
    ap.add_argument("--pallas-quantpack", action="store_true",
                    help="route int8/int4 encoding through the fused "
                         "quantize-pack kernel")
    ap.add_argument("--client-state-policy", default="dense",
                    choices=["dense", "blockmean", "int8"],
                    help="storage policy for per-client server state "
                         "tables (SCAFFOLD control variates, EF residuals)")
    args = ap.parse_args()
    t0 = time.time()
    hist = run_training(
        arch=args.arch, algorithm=args.algorithm, rounds=args.rounds,
        num_clients=args.clients, clients_per_round=args.sample,
        local_steps=args.local_steps, batch_size=args.batch_size,
        lr=args.lr, weight_decay=args.weight_decay, alpha=args.alpha,
        dirichlet=args.dirichlet, seed=args.seed,
        reduce_model=not args.full_model, log_path=args.log,
        layout=args.layout, use_pallas=args.pallas,
        comm_error_feedback=not args.no_error_feedback,
        use_pallas_quantpack=args.pallas_quantpack,
        client_state_policy=args.client_state_policy)
    print(json.dumps({
        "final_train_loss": hist["train_loss"][-1],
        "final_test_acc": hist["test_acc"][-1],
        "upload_mbytes_per_client_round": hist["upload_mbytes"][-1],
        "wall_s": round(time.time() - t0, 1),
    }, indent=1))


if __name__ == "__main__":
    main()
