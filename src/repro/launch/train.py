"""Federated training driver (CPU-scale simulation of the paper's setup).

Runs R communication rounds of any registered algorithm on a synthetic
Dirichlet non-iid task, logging train loss / test accuracy / communication
bytes per round — the engine is the SAME jitted ``round_fn`` the multi-pod
dry-run lowers, just on the host mesh.

Execution is pipelined (``repro.launch.pipeline``): params/server-state
buffers are donated into the jitted round, a background producer
assembles and stages round r+1's batches while round r computes, scalar
metrics are spooled on device and fetched in blocks at eval boundaries,
and ``--rounds-per-call M`` fuses M rounds into one ``lax.scan``-ed
dispatch. All of it is bit-exact against the eager loop
(``--prefetch-depth 0 --rounds-per-call 1``).

Participation scenarios (``repro.scenario``, docs/scenarios.md) model
system heterogeneity: ``--availability bernoulli0.7:2 --sampling
available`` skews who shows up, ``--straggler-frac 0.5`` cuts clients off
after K_i < K local steps, ``--agg-weighting data_size|inv_steps`` swaps
the uniform upload mean for a weighted reduction. The defaults are the
degenerate scenario — bit-exact with the pre-scenario engine.

Client-level DP (``repro.privacy``, docs/privacy.md): ``--dp-clip 1.0
--dp-noise-multiplier 1.0`` clips every client upload and noises the
aggregate; ``--target-epsilon 8`` instead derives the noise multiplier
from the privacy budget at launch. The RDP accountant consumes the
ACTUAL per-round cohorts and reports cumulative ``(eps, delta)`` into
the history / CSV at every eval round.

Fault injection + graceful degradation (``repro.faults``,
docs/faults.md): ``--fault-nan 0.1 --fault-drop 0.1`` corrupts/drops a
seeded per-round subset of uploads; ``--robust-agg
trimmed0.1|coordinate_median|norm_filter`` screens and robustly
aggregates them server-side; ``--min-quorum 4`` freezes rounds with too
few valid uploads; ``--watchdog`` finite-checks the global state every
block and rolls back to the newest checksum-valid checkpoint on
corruption. Everything-off is bit-exact with the fault-free engine.

Long (DP) sweeps survive preemption via ``--ckpt-dir out/ckpt
--ckpt-every 50``; ``--resume`` restores the latest checkpoint and
replays the data stream's rng for the completed rounds, so a resumed
run is trajectory-identical to an uninterrupted one.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch vit-tiny-fl \
      --algorithm fedadamw --rounds 30 --clients 16 --sample 8 \
      --local-steps 10 --dirichlet 0.1
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.checkpoint import (CorruptCheckpointError, restore_checkpoint,
                              save_checkpoint)
from repro.comm import codec_for, upload_wire_bytes
from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import build_fed_state, upload_shape_spec
from repro.data import RoundBatchGenerator, make_task
from repro.faults import FaultModel, NaNWatchdog, WatchdogRollback
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,
                                   eval_boundaries, plan_round_blocks,
                                   sample_memory_gauges)
from repro.metrics import CSVLogger, Meter, MetricsSpool
from repro.telemetry.ledger import LEDGER_METRIC_KEY, FlightRecorder
from repro.models import build_model
from repro.privacy import (RDPAccountant, released_entry_count,
                           resolve_dp_noise)
from repro.scenario import ParticipationScenario


def make_eval_fn(model, loss_fn: Optional[Callable] = None) -> Callable:
    """One jitted full-test-split eval for ALL eval rounds.

    ``eval_fn(params, stacked)`` scans the ``(nb, batch, ...)`` stacks of
    ``task.test_split_batches``, weighting each batch's masked CE and
    accuracy by its valid-label count, so padding rows (labels all -1)
    carry zero weight and both are the EXACT split-level masked means —
    identical to evaluating the whole split in one giant batch. Any
    auxiliary loss (MoE load-balance) is combined as the same weighted
    mean of per-batch values; it is zero for dense models and only
    approximate under MoE (padding rows still pass through the router).

    Built once per run: ``jax.jit(model.loss)`` per eval round would
    re-trace every time (bound methods compare unequal across accesses,
    so jit's cache never hits)."""
    loss_fn = loss_fn if loss_fn is not None else model.loss

    def eval_split(params, stacked):
        def body(carry, batch):
            _loss, metrics = loss_fn(params, batch)
            n = (batch["labels"] >= 0).sum().astype(jnp.float32)
            ces, auxs, accs, ns = carry
            return (ces + metrics["ce"] * n, auxs + metrics["aux"] * n,
                    accs + metrics["accuracy"] * n, ns + n), None

        zeros = jnp.zeros((), jnp.float32)
        (ces, auxs, accs, ns), _ = jax.lax.scan(
            body, (zeros, zeros, zeros, zeros), stacked)
        den = jnp.maximum(ns, 1.0)
        return (ces + auxs) / den, accs / den

    return jax.jit(eval_split)


def evaluate(model, params, task, batch_size: int = 256,
             eval_fn: Optional[Callable] = None,
             stacked=None) -> Dict[str, float]:
    """Exact test_loss / test_acc over the FULL test split (batched scan;
    a single-batch subsample is not measured anywhere anymore). Pass
    ``stacked`` (device-resident ``test_split_batches`` stacks) to skip
    re-transferring the split every eval round."""
    eval_fn = eval_fn if eval_fn is not None else make_eval_fn(model)
    if stacked is None:
        stacked = {k: jnp.asarray(v)
                   for k, v in task.test_split_batches(batch_size).items()}
    loss, acc = eval_fn(params, stacked)
    return {"test_loss": float(loss), "test_acc": float(acc)}


def _trim_history(history: Dict[str, list], resume_round: int) -> None:
    """Drop every logged row from rounds >= ``resume_round`` (watchdog
    rollback): the per-round lists are index-aligned with the round
    number, the eval-aligned lists are filtered by the recorded round
    column (eval rounds are ordered, so the kept set is a prefix). The
    CSV, if any, is append-only — replayed rounds log again, and the
    duplicated rows are the watchdog's visible audit trail."""
    for k in ("train_loss", "client_drift_rms", "v_bar_variance",
              "agg_survivors", "quorum_ok"):
        if k in history:
            del history[k][resume_round:]
    n_eval = sum(1 for r in history["round"] if r < resume_round)
    for k in ("round", "test_acc", "test_loss", "upload_mbytes",
              "epsilon", "host_blocked_frac"):
        if k in history:
            del history[k][n_eval:]


def run_training(*, arch: str = "vit-tiny-fl", algorithm: str = "fedadamw",
                 rounds: int = 30, num_clients: int = 16,
                 clients_per_round: int = 8, local_steps: int = 10,
                 batch_size: int = 16, lr: Optional[float] = None,
                 weight_decay: float = 0.01, alpha: float = 0.5,
                 dirichlet: float = 0.6, seed: int = 0,
                 v_aggregation: str = "mean_v", decoupled_wd: bool = True,
                 reduce_model: bool = True,
                 task_kind: str = "class_lm", seq_len: int = 32,
                 log_path: str = "", eval_every: int = 5,
                 cosine: bool = True, use_pallas: bool = False,
                 layout: str = "client_parallel",
                 comm_error_feedback: bool = True,
                 use_pallas_quantpack: bool = False,
                 client_state_policy: str = "dense",
                 prefetch_depth: int = 2, rounds_per_call: int = 1,
                 donate: bool = True,
                 availability: str = "always_on", sampling: str = "uniform",
                 straggler_frac: float = 0.0, straggler_min_steps: int = 1,
                 agg_weighting: str = "uniform",
                 scenario_seed: Optional[int] = None,
                 availability_trace=None,
                 dp_clip: float = 0.0, dp_noise_multiplier: float = 0.0,
                 target_epsilon: float = 0.0, dp_delta: float = 1e-5,
                 dp_seed: Optional[int] = None,
                 use_pallas_clipacc: bool = False,
                 use_pallas_uploadfuse: bool = False,
                 ckpt_dir: str = "", ckpt_every: int = 0,
                 resume: bool = False,
                 fault_drop: float = 0.0, fault_nan: float = 0.0,
                 fault_scale: float = 0.0,
                 fault_scale_factor: float = 1e3,
                 fault_seed: Optional[int] = None,
                 robust_agg: str = "none", robust_norm_mult: float = 5.0,
                 min_quorum: int = 0,
                 watchdog: bool = False, watchdog_max_rollbacks: int = 2,
                 trace_dir: str = "",
                 telemetry_diagnostics: bool = False,
                 telemetry_ledger: bool = False,
                 ledger_dir: str = "") -> Dict[str, list]:
    # a --ledger-dir implies the device-side recorder, like --trace-dir
    # implies the host session
    telemetry_ledger = telemetry_ledger or bool(ledger_dir)
    cfg = get_arch(arch)
    if reduce_model:
        cfg = reduced_variant(cfg)
    if lr is None:
        lr = 3e-4 if ("adam" in algorithm or algorithm == "fedlada") else 3e-2
    fed = FedConfig(
        algorithm=algorithm, num_clients=num_clients,
        clients_per_round=clients_per_round, local_steps=local_steps,
        rounds=rounds, lr=lr, weight_decay=weight_decay, alpha=alpha,
        v_aggregation=v_aggregation, decoupled_wd=decoupled_wd,
        layout=layout,
        sequential_clients=clients_per_round,
        use_pallas_update=use_pallas,
        comm_error_feedback=comm_error_feedback,
        use_pallas_quantpack=use_pallas_quantpack,
        client_state_policy=client_state_policy,
        rounds_per_call=rounds_per_call,
        availability=availability, sampling=sampling,
        straggler_frac=straggler_frac,
        straggler_min_steps=straggler_min_steps,
        agg_weighting=agg_weighting,
        scenario_seed=seed if scenario_seed is None else scenario_seed,
        dp_clip=dp_clip, dp_noise_multiplier=dp_noise_multiplier,
        target_epsilon=target_epsilon, dp_delta=dp_delta,
        dp_seed=seed if dp_seed is None else dp_seed,
        use_pallas_clipacc=use_pallas_clipacc,
        use_pallas_uploadfuse=use_pallas_uploadfuse,
        fault_drop=fault_drop, fault_nan=fault_nan,
        fault_scale=fault_scale, fault_scale_factor=fault_scale_factor,
        fault_seed=seed if fault_seed is None else fault_seed,
        robust_agg=robust_agg, robust_norm_mult=robust_norm_mult,
        min_quorum=min_quorum,
        telemetry_diagnostics=telemetry_diagnostics,
        telemetry_ledger=telemetry_ledger)
    model = build_model(cfg, compute_dtype=jnp.float32)
    task = make_task(task_kind, vocab_size=cfg.vocab_size, seq_len=seq_len,
                     num_samples=max(2048, 64 * num_clients),
                     num_clients=num_clients, dirichlet_alpha=dirichlet,
                     seed=seed)

    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(seed))
    upload_spec = upload_shape_spec(alg, params, sstate, specs, fed)

    # client-level DP (repro.privacy, docs/privacy.md): resolve a
    # --target-epsilon budget into the noise multiplier at launch (at
    # this run's own q = S/N, R, delta, and number of separately noised
    # aggregates), then track the cumulative (eps, delta) spend over the
    # ACTUAL per-round cohorts
    accountant = None
    if fed.dp_enabled():
        entries = released_entry_count(upload_spec)
        fed = resolve_dp_noise(fed, released_entries=entries)
        accountant = RDPAccountant(
            fed.dp_noise_multiplier, fed.num_clients, delta=fed.dp_delta,
            released_entries=entries)
    engine = RoundEngine(model, fed, specs, alg=alg,
                         cosine_total_rounds=rounds if cosine else 0,
                         donate=donate)

    # participation scenario (repro.scenario, docs/scenarios.md): the
    # degenerate default is inert — no payload keys, identical rng stream
    scenario = ParticipationScenario.from_fed(
        fed, task=task, trace=availability_trace)
    # fault injection (repro.faults, docs/faults.md): same reserved-key
    # pattern; None when every fault probability is zero
    fault_model = FaultModel.from_fed(fed)

    def fresh_gen(skip_rounds: int = 0) -> RoundBatchGenerator:
        # one seeded stream per (re)start: resume and watchdog rollback
        # both rebuild the generator and burn the completed rounds, so
        # replayed data is bit-identical to an uninterrupted run (the
        # prefetcher may have consumed the old stream arbitrarily far
        # ahead, so the old generator cannot be rewound in place)
        g = RoundBatchGenerator(
            task, num_clients=fed.num_clients,
            clients_per_round=fed.clients_per_round,
            local_steps=fed.local_steps, batch_size=batch_size,
            rng=np.random.default_rng(seed + 1), scenario=scenario,
            faults=fault_model)
        for _ in range(skip_rounds):
            g.next_round()
        return g

    gen = fresh_gen()
    blocks = plan_round_blocks(rounds, eval_every, fed.rounds_per_call)
    eval_rounds = set(eval_boundaries(rounds, eval_every))
    if ckpt_dir and ckpt_every:
        # checkpoints can only be written where a block ends; a
        # ckpt_every that never lands on one would silently write
        # nothing for the whole sweep — fail at launch instead
        ends = {s + z for s, z in blocks}
        missed = [r for r in range(ckpt_every, rounds + 1, ckpt_every)
                  if r not in ends]
        if missed:
            raise ValueError(
                f"ckpt_every={ckpt_every} does not land on block "
                f"boundaries (first miss: round {missed[0]}; block ends "
                f"are set by eval_every={eval_every} and "
                f"rounds_per_call={fed.rounds_per_call}). Use a "
                "multiple of eval_every, or adjust rounds_per_call so "
                "blocks end on the checkpoint rounds.")

    # --- checkpoint restore (repro.checkpoint): long sweeps survive
    # preemption. Resume replays the generator's rng stream for the
    # completed rounds, so the data of round r is identical whether or
    # not the run was interrupted — trajectory parity by construction.
    start_round = 0
    if ckpt_dir and resume and os.path.exists(
            os.path.join(ckpt_dir, "latest")):
        restored_params, restored_state, start_round = restore_checkpoint(
            ckpt_dir, params_template=params, state_template=sstate)
        params = jax.device_put(restored_params)
        sstate = jax.device_put(restored_state)
        gen = fresh_gen(start_round)            # burn the rng stream
        if accountant is not None:
            # completed rounds already spent budget (cohorts are the
            # static S — the top-up sampler keeps every round full)
            accountant.step(fed.clients_per_round, rounds=start_round)
        if start_round < rounds and not any(
                s == start_round for s, _ in blocks):
            raise ValueError(
                f"checkpoint at round {start_round} does not align with "
                f"the block plan (eval_every={eval_every}, "
                f"rounds_per_call={fed.rounds_per_call}): resume with "
                "the settings the checkpoint was written under "
                "(checkpoints land on block boundaries)")
        blocks = [(s, z) for s, z in blocks if s >= start_round]

    # declare the eval-only columns up front so every CSV carries them
    # even before the first eval round lands
    fieldnames = ["round", "train_loss", "upload_mbytes", "test_loss",
                  "test_acc"] + (["epsilon"] if accountant else [])
    track_faults = fed.faults_enabled() or fed.defense_enabled()
    if track_faults:
        fieldnames.append("agg_survivors")
    if fed.min_quorum > 0:
        fieldnames.append("quorum_ok")
    if fed.telemetry_diagnostics:
        fieldnames.append("client_drift_rms")
        if any(k in upload_spec for k in ("v_mean", "v_full")):
            fieldnames.append("v_bar_variance")
    fieldnames.append("host_blocked_frac")  # eval rounds only
    logger = CSVLogger(log_path, fieldnames=fieldnames) if log_path else None
    meter = Meter()
    eval_fn = make_eval_fn(model)
    # stage the full test split on device ONCE — every eval round scans
    # the same arrays
    eval_stacked = jax.device_put(task.test_split_batches(256))
    history = {"round": [], "train_loss": [], "test_acc": [],
               "test_loss": [], "upload_mbytes": [],
               "host_blocked_frac": []}
    if accountant is not None:
        history["epsilon"] = []

    # per-client wire bytes (paper Table 7 accounting, codec-aware): the
    # delta entry is costed through the codec's packed payload, not its
    # dense dequantized f32 shape; EF residuals are client-resident and
    # cost nothing. Payload sizes are shape-static, so one abstract
    # evaluation prices every round.
    codec = codec_for(fed.algorithm)
    comm_bytes = upload_wire_bytes(upload_spec, codec)

    # per-client flight recorder (repro.telemetry.ledger,
    # docs/observability.md): the engine attaches an (S, n_stats) block
    # per round under LEDGER_METRIC_KEY; it rides the spool with the
    # scalar metrics and is drained into the recorder at every flush.
    # The on-device wire column is a 0/1 arrival indicator — the
    # recorder scales it by the static per-client wire bytes here.
    recorder = None
    if fed.telemetry_ledger and ledger_dir:
        recorder = FlightRecorder(
            ledger_dir, wire_bytes_per_client=comm_bytes,
            meta={"arch": arch, "algorithm": fed.algorithm,
                  "layout": fed.layout, "seed": seed,
                  "clients_per_round": fed.clients_per_round,
                  "num_clients": fed.num_clients})

    def _new_spool() -> MetricsSpool:
        # the ledger block is the one non-scalar metric: rank 2 per
        # round, so the spool returns it as an ndarray instead of float
        return MetricsSpool(array_ndim={LEDGER_METRIC_KEY: 2})

    def _drain_ledger(flushed):
        # strip the block off every flushed record (scalar consumers
        # below never see it) and feed the recorder when one is active
        for r, m in flushed:
            block = m.pop(LEDGER_METRIC_KEY, None)
            if block is not None and recorder is not None:
                recorder.record(r, block)
        return flushed

    # telemetry session (repro.telemetry, docs/observability.md): when a
    # --trace-dir is given, install the session BEFORE the prefetcher is
    # built so its wait/produce counters register in the session's
    # registry and the producer thread's assemble/stage spans record.
    # Without one, span() is a shared no-op and every counter below is a
    # free-floating accumulator — host behavior is otherwise identical,
    # and the device program never depends on the session at all.
    tele = telemetry.session(trace_dir) if trace_dir else None
    if tele is not None:
        telemetry.install(tele)
    # NaN-watchdog (repro.faults, docs/faults.md): finite-check the
    # committed global state once per block; on corruption roll back to
    # the newest VALID checkpoint and replay, at most max_rollbacks
    # times, then abort with the telemetry trace exported
    wd = NaNWatchdog(watchdog_max_rollbacks) if watchdog else None
    spool = _new_spool()
    prefetcher = None
    resume_round = start_round
    static_s = fed.clients_per_round
    t0 = time.perf_counter()
    try:
        while True:
            run_blocks = [(s, z) for s, z in blocks if s >= resume_round]
            prefetcher = HostPrefetcher(gen, run_blocks,
                                        depth=prefetch_depth,
                                        stacked=engine.stacked)
            try:
                for start, size, batches, cids in prefetcher:
                    params, sstate, metrics = engine.run_block(
                        params, sstate, batches, cids, start, size)
                    r_end = start + size - 1
                    if wd is not None:
                        # one device->host sync per block (why the
                        # watchdog is opt-in); raising HERE keeps the
                        # poisoned state out of the checkpoint and the
                        # block's metrics out of the spool
                        wd.check(r_end, params, sstate)
                    spool.append(start, metrics, size)
                    telemetry.add("comm/wire_bytes_total",
                                  comm_bytes * int(np.shape(cids)[-1]) * size)
                    if ckpt_dir and ckpt_every \
                            and (r_end + 1) % ckpt_every == 0:
                        with telemetry.span("commit"):
                            save_checkpoint(
                                ckpt_dir, r_end + 1, params=params,
                                server_state=sstate,
                                extra={"algorithm": fed.algorithm})
                    if r_end not in eval_rounds:
                        continue
                    # eval boundary: one blocking fetch of everything
                    # spooled, then the exact full-split eval on the
                    # current params
                    with telemetry.span("eval"):
                        eval_rec = evaluate(model, params, task,
                                            eval_fn=eval_fn,
                                            stacked=eval_stacked)
                    # fraction of wall time the consumer spent blocked on
                    # host batch assembly/staging — same counter the
                    # prefetcher and the round-throughput benchmark read
                    hbf = prefetcher.wait_s / max(
                        time.perf_counter() - t0, 1e-9)
                    eval_rec["host_blocked_frac"] = hbf
                    history["host_blocked_frac"].append(hbf)
                    with telemetry.span("flush"):
                        flushed = _drain_ledger(spool.flush())
                    # the host blocks here anyway — sample allocator
                    # stats while the sync is free (no-op on CPU)
                    sample_memory_gauges()
                    if track_faults:
                        # canonical defense counters, fed from the
                        # per-round survivor metric the engine emitted
                        telemetry.add("faults/rejected_uploads", sum(
                            static_s - int(round(float(
                                m.get("agg_survivors", static_s))))
                            for _, m in flushed))
                        telemetry.add("rounds/quorum_skipped", sum(
                            1 for _, m in flushed
                            if float(m.get("quorum_ok", 1.0)) == 0.0))
                    if accountant is not None:
                        # charge each round at the cohort the aggregation
                        # ACTUALLY averaged: the validator may have
                        # rejected uploads, and the noise std already
                        # scales to the survivors (repro.privacy.dp)
                        for _, m in flushed:
                            cohort = int(round(float(
                                m.get("agg_survivors", static_s))))
                            if cohort > 0:
                                accountant.step(cohort, rounds=1)
                        eval_rec["epsilon"] = accountant.epsilon()
                        telemetry.set_gauge("dp/epsilon",
                                            eval_rec["epsilon"])
                    for r, m in flushed:
                        loss = m["loss_mean"]
                        meter.update(loss)
                        history["train_loss"].append(loss)  # EVERY round
                        rec = {"round": r, "train_loss": loss,
                               "upload_mbytes": comm_bytes / 1e6}
                        for k in ("client_drift_rms", "v_bar_variance",
                                  "agg_survivors", "quorum_ok"):
                            if k in m:
                                rec[k] = m[k]
                                history.setdefault(k, []).append(m[k])
                        if r == r_end:
                            rec.update(eval_rec)
                            history["round"].append(r)
                            history["test_acc"].append(rec["test_acc"])
                            history["test_loss"].append(rec["test_loss"])
                            history["upload_mbytes"].append(
                                rec["upload_mbytes"])
                            if accountant is not None:
                                history["epsilon"].append(rec["epsilon"])
                        if logger:
                            logger.log(rec)
                break                       # every block committed
            except WatchdogRollback as exc:
                prefetcher.close()
                telemetry.add("watchdog/rollbacks", 1)
                wd.rollbacks += 1
                if not (ckpt_dir and ckpt_every):
                    raise RuntimeError(
                        "watchdog: non-finite global state after round "
                        f"{exc.round_index} ({exc.bad_leaves} corrupt "
                        "leaves) and no --ckpt-dir/--ckpt-every to roll "
                        "back to") from exc
                if wd.rollbacks > wd.max_rollbacks:
                    raise RuntimeError(
                        "watchdog: rollback budget exhausted "
                        f"({wd.max_rollbacks}) — still corrupt at round "
                        f"{exc.round_index}; aborting") from exc
                try:
                    # newest VALID checkpoint: restore_checkpoint skips
                    # corrupt payloads by checksum (repro.checkpoint)
                    rest_p, rest_s, resume_round = restore_checkpoint(
                        ckpt_dir, params_template=params,
                        state_template=sstate)
                except (FileNotFoundError, CorruptCheckpointError) as e:
                    raise RuntimeError(
                        "watchdog: no valid checkpoint to roll back to "
                        f"after round {exc.round_index}: {e}") from exc
                params = jax.device_put(rest_p)
                sstate = jax.device_put(rest_s)
                gen = fresh_gen(resume_round)
                spool = _new_spool()  # poisoned block's rows discarded
                _trim_history(history, resume_round)
                if recorder is not None:
                    # replayed rounds re-record; drop the rolled-back
                    # ledger rows exactly like the history trim
                    recorder.trim(resume_round)
                if accountant is not None:
                    # replayed rounds must not double-charge: restart
                    # the ledger and charge the completed rounds at the
                    # static S (>= any survivor count, so conservative)
                    accountant = RDPAccountant(
                        fed.dp_noise_multiplier, fed.num_clients,
                        delta=fed.dp_delta,
                        released_entries=accountant.released_entries)
                    accountant.step(static_s, rounds=resume_round)
    finally:
        if prefetcher is not None:
            prefetcher.close()
        try:
            # salvage rounds computed since the last eval boundary (an
            # interrupt mid-interval must not drop logged rows the
            # device already produced); no-op on a clean exit
            for r, m in _drain_ledger(spool.flush()):
                history["train_loss"].append(m["loss_mean"])
                if logger:
                    logger.log({"round": r, "train_loss": m["loss_mean"],
                                "upload_mbytes": comm_bytes / 1e6})
        except Exception:
            pass  # never mask the original in-flight exception
        if logger:
            logger.close()
        if recorder is not None:
            try:
                # same crash-export contract as the trace files: the
                # partial flight recording survives the wreck
                recorder.export()
            except Exception:
                pass  # never mask the original in-flight exception
        if tele is not None:
            # export even on a crashed run: the partial trace is often
            # exactly what you need to debug the crash
            telemetry.uninstall(tele)
            tele.export()
    history["engine"] = {
        "rounds": rounds, "wall_s": time.perf_counter() - t0,
        "prefetch_depth": prefetch_depth,
        "rounds_per_call": fed.rounds_per_call, "donate": donate,
        "host_wait_s": prefetcher.wait_s, "produce_s": prefetcher.produce_s,
        "start_round": start_round,
        "trace_dir": trace_dir,
        "ledger_dir": ledger_dir,
        # compile-event accounting (docs/observability.md): a healthy
        # run compiles each program signature once — steady-state
        # recompiles mean shape churn is silently eating throughput
        "jit_compiles": engine.compiles,
        "jit_compile_s": engine.compile_s,
        "jit_steady_state_recompiles": engine.steady_state_recompiles,
    }
    if wd is not None:
        history["engine"]["watchdog_rollbacks"] = wd.rollbacks
    if fed.dp_enabled():
        history["engine"]["dp"] = {
            "clip": fed.dp_clip,
            "noise_multiplier": fed.dp_noise_multiplier,
            "delta": fed.dp_delta,
            "released_entries": accountant.released_entries,
        }
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-tiny-fl")
    ap.add_argument("--algorithm", default="fedadamw")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--weight-decay", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--dirichlet", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-model", action="store_true")
    ap.add_argument("--log", default="")
    ap.add_argument("--layout", default="client_parallel")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable error feedback for lossy upload codecs")
    ap.add_argument("--pallas-quantpack", action="store_true",
                    help="route int8/int4 encoding through the fused "
                         "quantize-pack kernel")
    ap.add_argument("--client-state-policy", default="dense",
                    choices=["dense", "blockmean", "int8"],
                    help="storage policy for per-client server state "
                         "tables (SCAFFOLD control variates, EF residuals)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="round blocks staged ahead by the background "
                         "producer (0 = synchronous eager loop)")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="fuse this many rounds into one jitted "
                         "lax.scan dispatch (bit-exact for any value)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable params/state buffer donation into the "
                         "jitted round")
    ap.add_argument("--availability", default="always_on",
                    help="client availability process: always_on | "
                         "bernoulli<rate>[:<conc>] | trace:<path.npy>")
    ap.add_argument("--sampling", default="uniform",
                    choices=["uniform", "weighted", "available"],
                    help="client sampling strategy (weighted = data-size "
                         "weighted, available = availability-constrained)")
    ap.add_argument("--straggler-frac", type=float, default=0.0,
                    help="fraction of clients that straggle (run "
                         "K_i <= K local steps per round)")
    ap.add_argument("--straggler-min-steps", type=int, default=1,
                    help="floor of a straggler's per-round K_i")
    ap.add_argument("--agg-weighting", default="uniform",
                    choices=["uniform", "data_size", "inv_steps"],
                    help="aggregation weights for the cross-client "
                         "upload reduction")
    ap.add_argument("--scenario-seed", type=int, default=None,
                    help="availability/straggler process seed "
                         "(defaults to --seed)")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="client-level DP: per-client L2 clip norm of "
                         "every aggregated upload entry (0 = DP off)")
    ap.add_argument("--dp-noise-multiplier", type=float, default=0.0,
                    help="Gaussian noise multiplier sigma (noise std "
                         "sigma*clip on the clipped sum)")
    ap.add_argument("--target-epsilon", type=float, default=0.0,
                    help="derive the noise multiplier from this privacy "
                         "budget at launch (mutually exclusive with "
                         "--dp-noise-multiplier)")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="delta of the (eps, delta) guarantee")
    ap.add_argument("--dp-seed", type=int, default=None,
                    help="server noise seed (defaults to --seed)")
    ap.add_argument("--pallas-clipacc", action="store_true",
                    help="route the DP clip + aggregation of the delta "
                         "entry through the fused clip-accumulate kernel "
                         "(client_parallel, codec-free)")
    ap.add_argument("--pallas-uploadfuse", action="store_true",
                    help="route the whole upload path — error-feedback "
                         "fold, DP clip, int8/int4 quantize, decoded "
                         "re-clip, weighted accumulate — through the "
                         "one-pass fused upload kernel (both layouts; "
                         "composes DP with the upload codecs)")
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (empty = no checkpoints)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N rounds (block-aligned; "
                         "0 = never)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir "
                         "and continue; trajectory-identical to an "
                         "uninterrupted run")
    ap.add_argument("--fault-drop", type=float, default=0.0,
                    help="per-round probability a sampled client's "
                         "upload never arrives (fault injection)")
    ap.add_argument("--fault-nan", type=float, default=0.0,
                    help="per-round probability a client's upload is "
                         "corrupted to NaN")
    ap.add_argument("--fault-scale", type=float, default=0.0,
                    help="per-round probability a client's upload is "
                         "inflated by --fault-scale-factor")
    ap.add_argument("--fault-scale-factor", type=float, default=1e3,
                    help="multiplier applied by the norm-inflation fault")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="fault schedule seed (defaults to --seed); the "
                         "schedule is a pure function of (seed, round)")
    ap.add_argument("--robust-agg", default="none",
                    help="server-side defense: none | mean | "
                         "trimmed<frac> | coordinate_median | "
                         "norm_filter (docs/faults.md)")
    ap.add_argument("--robust-norm-mult", type=float, default=5.0,
                    help="norm_filter rejects uploads with norm > this "
                         "multiple of the cohort median norm")
    ap.add_argument("--min-quorum", type=int, default=0,
                    help="rounds with fewer valid uploads than this "
                         "commit no state change (0 = no quorum)")
    ap.add_argument("--watchdog", action="store_true",
                    help="finite-check global state every block and "
                         "roll back to the newest valid checkpoint on "
                         "corruption (costs one sync per block)")
    ap.add_argument("--watchdog-max-rollbacks", type=int, default=2,
                    help="abort after this many watchdog rollbacks")
    ap.add_argument("--trace-dir", default="",
                    help="write a Chrome-trace/Perfetto trace.json plus "
                         "counters.json of the run here (empty = no "
                         "tracing; see docs/observability.md)")
    ap.add_argument("--diagnostics", action="store_true",
                    help="compute per-round client-drift RMS and v-bar "
                         "cross-client variance on device (the paper's "
                         "Figure-2 quantities) and log them per round")
    ap.add_argument("--ledger-dir", default="",
                    help="record the per-client flight recorder (steps, "
                         "upload norm, drift contribution, DP clip, "
                         "wire bytes, fault/defense verdicts per client "
                         "per round) and export ledger.npz + manifest "
                         "here (docs/observability.md)")
    args = ap.parse_args()
    t0 = time.time()
    hist = run_training(
        arch=args.arch, algorithm=args.algorithm, rounds=args.rounds,
        num_clients=args.clients, clients_per_round=args.sample,
        local_steps=args.local_steps, batch_size=args.batch_size,
        lr=args.lr, weight_decay=args.weight_decay, alpha=args.alpha,
        dirichlet=args.dirichlet, seed=args.seed,
        reduce_model=not args.full_model, log_path=args.log,
        layout=args.layout, use_pallas=args.pallas,
        comm_error_feedback=not args.no_error_feedback,
        use_pallas_quantpack=args.pallas_quantpack,
        client_state_policy=args.client_state_policy,
        prefetch_depth=args.prefetch_depth,
        rounds_per_call=args.rounds_per_call,
        donate=not args.no_donate,
        availability=args.availability, sampling=args.sampling,
        straggler_frac=args.straggler_frac,
        straggler_min_steps=args.straggler_min_steps,
        agg_weighting=args.agg_weighting,
        scenario_seed=args.scenario_seed,
        dp_clip=args.dp_clip,
        dp_noise_multiplier=args.dp_noise_multiplier,
        target_epsilon=args.target_epsilon, dp_delta=args.dp_delta,
        dp_seed=args.dp_seed, use_pallas_clipacc=args.pallas_clipacc,
        use_pallas_uploadfuse=args.pallas_uploadfuse,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume,
        fault_drop=args.fault_drop, fault_nan=args.fault_nan,
        fault_scale=args.fault_scale,
        fault_scale_factor=args.fault_scale_factor,
        fault_seed=args.fault_seed,
        robust_agg=args.robust_agg,
        robust_norm_mult=args.robust_norm_mult,
        min_quorum=args.min_quorum,
        watchdog=args.watchdog,
        watchdog_max_rollbacks=args.watchdog_max_rollbacks,
        trace_dir=args.trace_dir,
        telemetry_diagnostics=args.diagnostics,
        ledger_dir=args.ledger_dir)
    out = {"wall_s": round(time.time() - t0, 1)}
    if hist["train_loss"]:
        out.update(
            final_train_loss=hist["train_loss"][-1],
            final_test_acc=hist["test_acc"][-1],
            upload_mbytes_per_client_round=hist["upload_mbytes"][-1])
    else:
        # --resume found the run already complete (start_round ==
        # rounds): a supervisor re-running the same command until it
        # succeeds must see a clean exit, not an IndexError
        out["note"] = (f"nothing to do: checkpoint already at round "
                       f"{hist['engine']['start_round']}")
    if hist.get("epsilon"):
        out["epsilon"] = hist["epsilon"][-1]
        out["dp"] = hist["engine"]["dp"]
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
