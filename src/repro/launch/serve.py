"""Serving driver: batched greedy decoding with a KV cache / SSM state.

Small-scale host execution of the same ``serve_step`` the decode dry-run
shapes lower. Usage:

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch
from repro.config.model_config import reduced_variant
from repro.core.serve import make_serve_step
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-tiny-fl")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full-model", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full_model:
        cfg = reduced_variant(cfg)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)

    memory = None
    if cfg.family == "audio":
        feats = jnp.asarray(rng.normal(size=(
            args.batch, cfg.frontend_tokens_per_sample,
            cfg.frontend_embed_dim)), jnp.float32)
        memory = model.encode(params, feats)

    max_len = args.prompt_len + args.new_tokens
    cache = model.init_cache(args.batch, max_len)
    step = jax.jit(make_serve_step(model))

    # prefill token-by-token (host-scale), then timed decode
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        tok, _, cache = step(params, prompt[:, i:i + 1], cache,
                             memory=memory)
    jax.block_until_ready(tok)
    t0 = time.perf_counter()
    out = [tok]
    for _ in range(args.new_tokens - 1):
        tok, _, cache = step(params, out[-1], cache, memory=memory)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(json.dumps({
        "arch": cfg.name,
        "batch": args.batch,
        "new_tokens": args.new_tokens,
        "decode_ms_per_token": round(1e3 * dt / max(args.new_tokens - 1, 1), 2),
        "sample_tokens": np.asarray(gen[0, :8]).tolist(),
    }, indent=1))


if __name__ == "__main__":
    main()
