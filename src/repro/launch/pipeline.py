"""Pipelined federated round execution.

The eager seed loop serialized four things per round: host batch
assembly, the host->device transfer, the jitted round dispatch, and a
blocking scalar fetch of the round's loss. This module overlaps all of
them:

``plan_round_blocks``
    Partitions the round range into scan blocks of at most
    ``FedConfig.rounds_per_call`` rounds that never cross an eval
    boundary, so fused execution preserves eval-every semantics exactly.

``HostPrefetcher``
    A bounded background producer: while the device runs round r's
    block, a daemon thread samples clients and assembles the NEXT
    block's ``(batches, client_ids)`` and stages the host->device
    transfer, double-buffering up to ``depth`` blocks. ``depth=0``
    degrades to the synchronous eager behavior (useful as the parity /
    benchmark baseline).

``RoundEngine``
    Wraps the donated single-round and multi-round jitted callables and
    dispatches whichever matches the block size. With donation the
    global params, ``delta_g``/``v_bar``, and the num_clients-row client
    state tables are updated in place instead of copied every round.

The three pieces compose with ``repro.metrics.MetricsSpool`` (deferred
scalar fetches) in ``repro.launch.train.run_training``; trajectories are
bit-identical across eager / prefetched / fused execution because the
data stream (``RoundBatchGenerator``) and the round program are shared.

Usage — plan blocks, then stream them through a prefetcher (any object
with ``next_round``/``next_rounds`` works as the generator; runs under
``python -m doctest``):

>>> from repro.launch.pipeline import HostPrefetcher, plan_round_blocks
>>> plan_round_blocks(6, eval_every=3, rounds_per_call=4)
... # fusion never crosses an eval boundary
[(0, 3), (3, 3)]
>>> import numpy as np
>>> class CountingGen:                     # stands in for RoundBatchGenerator
...     def __init__(self): self.calls = 0
...     def next_round(self):
...         self.calls += 1
...         return {"tokens": np.zeros((2, 1, 4), np.int32)}, np.arange(2)
>>> pre = HostPrefetcher(CountingGen(), [(0, 1), (1, 1)], depth=1,
...                      to_device=False)
>>> [(start, size) for start, size, batches, cids in pre]
[(0, 1), (1, 1)]
>>> pre.gen.calls                          # every block produced exactly once
2

The consumer drives ``RoundEngine.run_block`` with each yielded block;
``depth=0`` degrades to inline assembly (the eager baseline).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.config import FedConfig
from repro.core import make_multi_round_fn, make_round_fn
from repro.scenario import STEP_MASK_KEY


def eval_boundaries(rounds: int, eval_every: int) -> List[int]:
    """Rounds r after which evaluation runs: (r+1) % eval_every == 0,
    plus always the final round."""
    ends = [r for r in range(rounds) if (r + 1) % max(eval_every, 1) == 0]
    if rounds and (not ends or ends[-1] != rounds - 1):
        ends.append(rounds - 1)
    return ends


def plan_round_blocks(rounds: int, eval_every: int,
                      rounds_per_call: int = 1
                      ) -> List[Tuple[int, int]]:
    """Partition ``range(rounds)`` into ``(start, size)`` blocks with
    ``size <= rounds_per_call`` that never straddle an eval boundary —
    evaluation (and the metric flush) happens exactly where the eager
    loop evaluated."""
    if rounds_per_call < 1:
        raise ValueError(f"rounds_per_call must be >= 1, got {rounds_per_call}")
    ends = eval_boundaries(rounds, eval_every)
    blocks: List[Tuple[int, int]] = []
    r = 0
    for end in ends:
        while r <= end:
            size = min(rounds_per_call, end + 1 - r)
            blocks.append((r, size))
            r += size
    return blocks


class HostPrefetcher:
    """Iterate ``(start, size, batches, client_ids)`` over round blocks,
    assembling and device-staging each block ahead of consumption.

    gen:      a ``RoundBatchGenerator`` (consumed only by the producer,
              in block order — the rng stream matches eager assembly).
    blocks:   the ``plan_round_blocks`` output.
    depth:    how many blocks may be staged ahead (bounded queue).
              ``0`` = assemble inline on the consumer thread (eager).
    stacked:  produce (M, S, K, ...) stacks for the multi-round engine
              instead of (S, K, ...) single-round batches.

    Properties ``wait_s`` (time the consumer spent blocked obtaining the
    next block — the host-blocked critical path) and ``produce_s``
    (total assembly + staging time wherever it ran) are backed by the
    ``prefetch/wait_s`` / ``prefetch/produce_s`` telemetry counters —
    registered with the active :mod:`repro.telemetry` session when one
    is installed — so the training driver, the round-throughput
    benchmark, and the run-summary report all read ONE accumulator.
    """

    _SENTINEL = object()

    def __init__(self, gen, blocks: List[Tuple[int, int]], *, depth: int = 2,
                 stacked: bool = False, to_device: bool = True):
        self.gen = gen
        self.blocks = list(blocks)
        self.depth = depth
        self.stacked = stacked
        self.to_device = to_device
        # session-registered when a telemetry session is active at
        # construction time, free-floating (still functional) otherwise
        self._wait = telemetry.counter("prefetch/wait_s")
        self._produce_c = telemetry.counter("prefetch/produce_s")
        self._stop = threading.Event()
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def wait_s(self) -> float:
        return self._wait.value

    @property
    def produce_s(self) -> float:
        return self._produce_c.value

    def _produce(self, start: int, size: int):
        t0 = time.perf_counter()
        with telemetry.span("assemble"):
            if self.stacked:
                batches, cids = self.gen.next_rounds(size)
            else:
                assert size == 1, "single-round engine got a fused block"
                batches, cids = self.gen.next_round()
        if telemetry.active() is not None and isinstance(batches, dict) \
                and STEP_MASK_KEY in batches:
            # straggler step-validity fraction, measured on the host
            # numpy mask BEFORE staging (no device sync)
            telemetry.set_gauge("scenario/valid_step_frac",
                                float(np.mean(batches[STEP_MASK_KEY])))
        with telemetry.span("stage"):
            if self.to_device:
                batches = jax.device_put(batches)
                cids = jax.device_put(cids)
            else:
                batches = {k: jnp.asarray(v) for k, v in batches.items()}
                cids = jnp.asarray(cids)
        self._produce_c.add(time.perf_counter() - t0)
        return start, size, batches, cids

    # -- background producer --------------------------------------------
    def _put(self, item, deadline: Optional[float] = None) -> bool:
        """Enqueue honoring the stop flag (and an optional monotonic
        deadline), in bounded 0.1 s waits so a full queue can never pin
        the producer thread. Returns False when abandoned."""
        while not self._stop.is_set():
            if deadline is not None and time.monotonic() >= deadline:
                return False
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _producer_loop(self) -> None:
        try:
            for start, size in self.blocks:
                if self._stop.is_set():
                    return
                item = self._produce(start, size)
                if not self._put(item):
                    return
                telemetry.set_gauge("prefetch/queue_depth",
                                    self._queue.qsize())
            self._put(self._SENTINEL)
        except BaseException as e:  # surfaced on the consumer thread
            # bounded: if the consumer is already gone (it crashed, or
            # close() raced us), give up after 5 s instead of pinning
            # this thread on a blocking put forever — the regression
            # test in tests/test_pipeline.py holds this line
            self._put(e, deadline=time.monotonic() + 5.0)

    def __iter__(self) -> Iterator[Tuple[int, int, dict, jax.Array]]:
        if self.depth <= 0:
            for start, size in self.blocks:
                t0 = time.perf_counter()
                item = self._produce(start, size)
                self._wait.add(time.perf_counter() - t0)
                yield item
            return
        self._queue = queue.Queue(maxsize=self.depth)
        self._thread = threading.Thread(
            target=self._producer_loop, name="round-prefetcher", daemon=True)
        self._thread.start()
        try:
            while True:
                t0 = time.perf_counter()
                item = self._queue.get()
                self._wait.add(time.perf_counter() - t0)
                if item is self._SENTINEL:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            self.close()

    def close(self, timeout: float = 5.0) -> None:
        """Graceful shutdown with a hard deadline.

        Sets the stop flag, drains the queue (so a producer blocked in
        ``put`` observes the flag within one 0.1 s wait), and joins the
        thread. A producer wedged inside ``_produce`` (a hung
        ``device_put``, a generator bug) cannot hang the caller: past
        ``timeout`` seconds the daemon thread is abandoned — interpreter
        exit reaps it — and the shutdown still returns. Runs under a
        telemetry ``shutdown`` span so interrupted runs export how long
        teardown took instead of vanishing into a hang."""
        self._stop.set()
        if self._thread is None:
            return
        with telemetry.span("shutdown"):
            deadline = time.monotonic() + timeout
            while self._thread.is_alive() and time.monotonic() < deadline:
                try:
                    self._queue.get_nowait()
                except queue.Empty:
                    self._thread.join(timeout=0.1)
            if self._thread.is_alive():
                telemetry.set_gauge("prefetch/shutdown_abandoned", 1.0)
        self._thread = None


class RoundEngine:
    """Jitted round dispatch with buffer donation and optional fusion.

    Builds the single-round program, and — when any planned block has
    size > 1 — the scanned multi-round program, both jitted with
    ``donate_argnums=(0, 1)`` (params, sstate) unless ``donate=False``:
    in/out specs match, so XLA reuses the largest live buffers (global
    params, ``delta_g``/``v_bar``, the num_clients-row client-state
    tables) instead of re-copying them every round.
    """

    def __init__(self, model, fed: FedConfig, specs, *, alg=None,
                 cosine_total_rounds: int = 0, donate: bool = True,
                 loss_fn: Optional[Callable] = None):
        donate_argnums = (0, 1) if donate else ()
        self.donate = donate
        self.fed = fed
        self.round_fn = jax.jit(
            make_round_fn(model, fed, specs, alg=alg, loss_fn=loss_fn,
                          cosine_total_rounds=cosine_total_rounds),
            donate_argnums=donate_argnums)
        self.multi_round_fn = jax.jit(
            make_multi_round_fn(model, fed, specs, alg=alg, loss_fn=loss_fn,
                                cosine_total_rounds=cosine_total_rounds),
            donate_argnums=donate_argnums)
        self.stacked = fed.rounds_per_call > 1
        # compile-event accounting (docs/observability.md): every
        # dispatch is keyed by its program signature — (which jitted fn,
        # input treedef, leaf shapes/dtypes). A jit-cache growth on a
        # signature seen before is a STEADY-STATE RECOMPILE, the exact
        # failure mode (shape churn, weak-type flip-flop) that silently
        # multiplies step time on the big sharded configs.
        self.compiles = 0
        self.compile_s = 0.0
        self.steady_state_recompiles = 0
        self._seen_signatures: set = set()

    def _dispatch_signature(self, batches, client_ids):
        leaves, treedef = jax.tree.flatten((batches, client_ids))
        return (self.stacked, str(treedef),
                tuple((tuple(l.shape), str(jnp.asarray(l).dtype))
                      for l in leaves))

    @staticmethod
    def _cache_size(fn) -> int:
        # jax's jitted callables expose a private trace-cache size; fall
        # back to 0 (compile accounting disabled) if the API moves
        try:
            return int(fn._cache_size())
        except Exception:
            return 0

    def run_block(self, params, sstate, batches, client_ids,
                  start: int, size: int):
        """Dispatch one block. Returns ``(params, sstate, metrics)``;
        metric leaves are (size,)-stacked when the engine is fused,
        scalars otherwise. The inputs' params/sstate buffers are donated
        (consumed) when donation is on."""
        with telemetry.span("dispatch"):
            telemetry.add("rounds/completed", size)
            fn = self.multi_round_fn if self.stacked else self.round_fn
            sig = self._dispatch_signature(batches, client_ids)
            cache0 = self._cache_size(fn)
            t0 = time.perf_counter()
            out = fn(params, sstate, batches, client_ids,
                     jnp.asarray(start))
            grown = self._cache_size(fn) - cache0
            if grown > 0:
                # trace+lower+compile run synchronously inside the
                # triggering call, so its wall time IS the compile cost
                # (plus one dispatch, which is noise next to it)
                dt = time.perf_counter() - t0
                self.compiles += grown
                self.compile_s += dt
                telemetry.add("jit/compiles", grown)
                telemetry.add("jit/compile_s", dt)
                if sig in self._seen_signatures:
                    self.steady_state_recompiles += grown
                    telemetry.add("jit/steady_state_recompiles", grown)
            self._seen_signatures.add(sig)
            return out


def sample_memory_gauges(device=None) -> dict:
    """Set ``mem/live_bytes`` / ``mem/peak_bytes`` gauges from
    ``device.memory_stats()`` and return the sampled values.

    Called at eval boundaries (host is already synchronizing there, so
    the query adds no pipeline stall). Backends without allocator stats
    (CPU returns ``None``) are a silent no-op — the gauges simply never
    appear in the counter export.
    """
    dev = device if device is not None else jax.devices()[0]
    stats = getattr(dev, "memory_stats", lambda: None)()
    if not stats:
        return {}
    out = {}
    live = stats.get("bytes_in_use")
    peak = stats.get("peak_bytes_in_use")
    if live is not None:
        telemetry.set_gauge("mem/live_bytes", float(live))
        out["mem/live_bytes"] = float(live)
    if peak is not None:
        telemetry.set_gauge("mem/peak_bytes", float(peak))
        out["mem/peak_bytes"] = float(peak)
    return out
