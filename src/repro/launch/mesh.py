"""Production mesh construction.

Kept as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax initialization, and smoke tests / benches must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: (``pod``,) ``data``, ``model`` — ``data`` hosts clients /
    data-parallel replicas, ``model`` is the tensor-parallel axis, ``pod``
    scales clients across pods (DCN-connected).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Single-host mesh for tests/benches (uses whatever devices exist)."""
    return jax.make_mesh(shape, axes)
