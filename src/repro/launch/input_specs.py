"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

Weak-type-correct, shardable, zero device allocation: everything here is
``jax.ShapeDtypeStruct`` (or ``jax.eval_shape`` results for params / server
state / KV caches). The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import FedConfig, InputShape, ModelConfig
from repro.core.fedadamw import get_algorithm
from repro.core.partition import build_block_specs
from repro.sharding import specs as shspecs

SDS = jax.ShapeDtypeStruct


def _client_axis_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in shspecs.client_axes(mesh)]))


def fed_geometry(cfg: ModelConfig, mesh: Mesh, fed: FedConfig,
                 ishape: InputShape) -> Tuple[int, int]:
    """(clients_in_round_program S, per-client batch b)."""
    cax = _client_axis_size(mesh)
    if fed.layout == "client_parallel":
        s = cax
        b = max(1, ishape.global_batch // s)
    else:
        s = fed.sequential_clients
        b = ishape.global_batch
    return s, b


def train_batch_specs(cfg: ModelConfig, mesh: Mesh, fed: FedConfig,
                      ishape: InputShape) -> Dict[str, SDS]:
    """Leaves are (S, K, b, ...) — or (S, K, mb, b_micro, ...) when
    gradient micro-batching is on (the micro axis is explicit so the batch
    sub-dimension keeps its sharding; see rounds.grad_of)."""
    s, b = fed_geometry(cfg, mesh, fed, ishape)
    k = fed.local_steps
    seq = ishape.seq_len
    mb = fed.grad_microbatches
    if mb > 1:
        assert b % mb == 0, (b, mb)
        lead: Tuple[int, ...] = (s, k, mb, b // mb)
    else:
        lead = (s, k, b)
    batch = {
        "tokens": SDS(lead + (seq,), jnp.int32),
        "labels": SDS(lead + (seq,), jnp.int32),
    }
    if cfg.family == "vlm":
        tf, ef = cfg.frontend_tokens_per_sample, cfg.frontend_embed_dim
        batch["frontend_feats"] = SDS(lead + (tf, ef), jnp.bfloat16)
        batch["mrope_positions"] = SDS(lead + (seq, 3), jnp.int32)
    elif cfg.family == "audio":
        tf, ef = cfg.frontend_tokens_per_sample, cfg.frontend_embed_dim
        batch["frontend_feats"] = SDS(lead + (tf, ef), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, ishape: InputShape
                        ) -> Dict[str, SDS]:
    b, seq = ishape.global_batch, ishape.seq_len
    batch = {"tokens": SDS((b, seq), jnp.int32),
             "labels": SDS((b, seq), jnp.int32)}
    if cfg.family == "vlm":
        tf, ef = cfg.frontend_tokens_per_sample, cfg.frontend_embed_dim
        batch["frontend_feats"] = SDS((b, tf, ef), jnp.bfloat16)
        batch["mrope_positions"] = SDS((b, seq, 3), jnp.int32)
    elif cfg.family == "audio":
        tf, ef = cfg.frontend_tokens_per_sample, cfg.frontend_embed_dim
        batch["frontend_feats"] = SDS((b, tf, ef), jnp.bfloat16)
    return batch


def decode_input_specs(model, cfg: ModelConfig, ishape: InputShape
                       ) -> Dict[str, Any]:
    """tokens (B,1) + KV-cache/SSM-state structs (+ encoder memory)."""
    b, seq = ishape.global_batch, ishape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, seq))
    out = {"tokens": SDS((b, 1), jnp.int32), "cache": cache}
    if cfg.family == "audio":
        out["memory"] = SDS((b, cfg.frontend_tokens_per_sample, cfg.d_model),
                            jnp.bfloat16)
    return out


def abstract_params(model, param_dtype=jnp.bfloat16):
    """Abstract parameter tree (master copy dtype applied)."""
    tree = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    if param_dtype is not None:
        tree = jax.tree.map(
            lambda s: SDS(s.shape, param_dtype)
            if jnp.issubdtype(s.dtype, jnp.floating) else s, tree)
    return tree


def abstract_fed_state(model, cfg: ModelConfig, fed: FedConfig,
                       param_dtype=jnp.bfloat16):
    """(params_sds, block_specs, alg, server_state_sds)."""
    params = abstract_params(model, param_dtype)
    specs = build_block_specs(params, cfg, fed)
    alg = get_algorithm(fed)
    sstate = jax.eval_shape(lambda: alg.init_server(params, specs, fed))
    return params, specs, alg, sstate
