"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts while-loop (scan) bodies exactly ONCE
(verified empirically: a 16-iteration scanned matmul reports 1 matmul of
FLOPs), which silently undercounts every scanned-layer / K-local-step
program by orders of magnitude. The compiled HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on each while op — so
this module re-derives the three roofline inputs exactly:

  * FLOPs: every ``dot`` (2 * prod(output) * prod(contracting dims)),
    recursing through fusions / calls / while bodies with multipliers.
  * HBM bytes: per materialized op, operand bytes + output bytes — the same
    convention as XLA's HloCostAnalysis, but trip-aware.
  * collective bytes: output bytes per collective kind, trip-aware.

Zero-cost ops (parameter, tuple plumbing, bitcast) are excluded. Fusion
bytes are counted at the fusion boundary (operands+outputs), matching what
actually hits HBM; FLOPs recurse inside the fused computation because dots
keep their semantics there.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _split_op_line(stripped: str):
    """'%name = TYPE opcode(args), attrs' -> (name, type, opcode, args_at).

    Char-level because tuple types can contain `/*index=N*/` comments (which
    hold '=') and nested brackets that defeat a regex."""
    s = stripped
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, tail = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par <= 0:
        return None
    opcode = tail[:par]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, type_str, opcode, tail
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_COMPS_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "domain",
    "opt-barrier",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str      # 'opcode(args), attrs' tail — attrs parse against this


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.collective is None:
            self.collective = {k: 0.0 for k in COLLECTIVES}

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.collective[k] += other.collective[k] * mult

    @property
    def collective_total(self) -> float:
        return sum(self.collective.values())


class HloCostCounter:
    def __init__(self, hlo_text: str, collect_top: bool = False):
        self.computations: Dict[str, List[Op]] = {}
        self.shapes: Dict[str, str] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Costs] = {}
        self._collect_top = collect_top
        # (bytes*trips, trips, opcode, metadata-op-name) per materialized op
        self.top: List[tuple] = []

    # -- parsing ------------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                    if stripped.startswith("ENTRY"):
                        self.entry = current
                    continue
            if stripped == "}":
                continue
            parsed = _split_op_line(stripped)
            if parsed and current is not None:
                name, type_str, opcode, tail = parsed
                op = Op(name, type_str, opcode, tail)
                self.computations[current].append(op)
                self.shapes[name] = type_str

    # -- costing ------------------------------------------------------------
    def _operand_names(self, op: Op) -> List[str]:
        # section between the first '(' after opcode and its matching ')'
        start = op.line.index(op.opcode + "(") + len(op.opcode) + 1
        depth = 1
        i = start
        while i < len(op.line) and depth:
            if op.line[i] == "(":
                depth += 1
            elif op.line[i] == ")":
                depth -= 1
            i += 1
        section = op.line[start:i - 1]
        return re.findall(r"%([\w.\-]+)", section)

    def _dot_flops(self, op: Op) -> float:
        out_elems, _ = _shape_elems_bytes(op.type_str)
        m = _LHS_CONTRACT_RE.search(op.line)
        contract = 1
        if m and m.group(1):
            operands = self._operand_names(op)
            if operands:
                lhs_shape = _shape_dims(self.shapes.get(operands[0], ""))
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(lhs_shape):
                        contract *= lhs_shape[di]
        return 2.0 * out_elems * contract

    def _op_bytes(self, op: Op) -> float:
        # dynamic-update-slice updates in place (XLA aliases operand 0):
        # traffic is read+write of the UPDATE slice, not the whole buffer.
        # dynamic-slice similarly reads only the slice it produces.
        if op.opcode == "dynamic-update-slice" or (
                op.opcode == "fusion" and "dynamic_update_slice" in op.line
                and "kLoop" in op.line):
            upds = self._operand_names(op)
            if op.opcode == "dynamic-update-slice" and len(upds) >= 2 \
                    and upds[1] in self.shapes:
                return 2.0 * _shape_elems_bytes(self.shapes[upds[1]])[1]
            # fused DUS: approximate with the smallest operand (the update)
            sizes = [_shape_elems_bytes(self.shapes[n])[1]
                     for n in upds if n in self.shapes]
            sizes = [s for s in sizes if s > 0]
            if sizes:
                return 2.0 * min(sizes)
        if op.opcode == "dynamic-slice":
            return 2.0 * _shape_elems_bytes(op.type_str)[1]
        _, out_b = _shape_elems_bytes(op.type_str)
        in_b = 0
        for name in self._operand_names(op):
            if name in self.shapes:
                in_b += _shape_elems_bytes(self.shapes[name])[1]
        return float(in_b + out_b)

    def comp_costs(self, comp: str) -> Costs:
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        self._memo[comp] = total  # break cycles defensively
        for op in self.computations.get(comp, []):
            oc = op.opcode
            if oc in _ZERO_COST:
                continue
            if oc == "while":
                trips = 1.0
                m = _TRIP_RE.search(op.line)
                if m:
                    trips = float(m.group(1))
                body = _BODY_RE.search(op.line)
                if body:
                    total.add(self.comp_costs(body.group(1)), trips)
                continue
            if oc == "fusion":
                # FLOPs recurse (dots keep semantics inside fusions);
                # bytes counted at the fusion boundary only
                calls = _CALLS_RE.search(op.line)
                if calls:
                    inner = self.comp_costs(calls.group(1))
                    total.flops += inner.flops
                total.bytes += self._op_bytes(op)
                continue
            if oc in ("call", "async-start"):
                tgt = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if tgt:
                    total.add(self.comp_costs(tgt.group(1)))
                continue
            if oc == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                    op.line)
                branches += re.findall(r"%([\w.\-]+)", op.line.split(
                    "branch_computations={")[-1].split("}")[0]) \
                    if "branch_computations" in op.line else []
                if branches:
                    worst = Costs()
                    for b in branches:
                        c = self.comp_costs(b)
                        if c.flops + c.bytes > worst.flops + worst.bytes:
                            worst = c
                    total.add(worst)
                continue
            matched_coll = None
            for c in COLLECTIVES:
                if oc == c or oc.startswith(c + "-"):
                    matched_coll = c
                    break
            if matched_coll:
                _, out_b = _shape_elems_bytes(op.type_str)
                total.collective[matched_coll] += out_b
                total.bytes += self._op_bytes(op)
                continue
            if oc in ("dot", "dot-general"):
                total.flops += self._dot_flops(op)
                total.bytes += self._op_bytes(op)
                continue
            # generic materialized op
            total.bytes += self._op_bytes(op)
        return total

    def entry_costs(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        # reset memo so repeated calls stay correct
        self._memo = {}
        return self.comp_costs(self.entry)


def top_bytes_ops(hlo_text: str, n: int = 20) -> List[tuple]:
    """Heaviest HBM contributors: (total_bytes, trips, opcode, op_name)
    with while-trip multipliers applied — the §Perf profiling view."""
    c = HloCostCounter(hlo_text)
    out: List[tuple] = []

    def walk(comp: str, mult: float, depth: int = 0) -> None:
        if depth > 50:
            return
        for op in c.computations.get(comp, []):
            oc = op.opcode
            if oc in _ZERO_COST:
                continue
            if oc == "while":
                trips = 1.0
                m = _TRIP_RE.search(op.line)
                if m:
                    trips = float(m.group(1))
                body = _BODY_RE.search(op.line)
                if body:
                    walk(body.group(1), mult * trips, depth + 1)
                continue
            if oc in ("call", "async-start"):
                tgt = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(
                    op.line)
                if tgt:
                    walk(tgt.group(1), mult, depth + 1)
                continue
            if oc == "conditional":
                continue
            b = c._op_bytes(op) * mult
            if b > 0:
                meta = ""
                mm = re.search(r'op_name="([^"]+)"', op.line)
                if mm:
                    meta = mm.group(1)[-80:]
                out.append((b, mult, oc, meta or op.name))

    walk(c.entry, 1.0)
    out.sort(key=lambda t: -t[0])
    return out[:n]


_ALIAS_MARK = "input_output_alias={"
_ALIAS_ENTRY_RE = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}")


def parse_input_output_alias(hlo_text: str) -> Dict[int, int]:
    """Donation aliasing as an IR fact: ``{input parameter index ->
    output tuple index}`` from the compiled module header's
    ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` annotation.

    Empty dict when the program donates nothing. Input parameters are in
    jit-flattening order, so for ``donate_argnums=(0, 1)`` over
    ``(params, sstate, ...)`` the donated leaves are parameters
    ``0 .. len(leaves(params)) + len(leaves(sstate)) - 1`` — the static
    analyzer (``repro.analysis.jaxpr_audit``, rule RA204) checks exactly
    that range is aliased, turning the PR 3 ``is_deleted`` buffer
    property into a compile-time assertion."""
    start = hlo_text.find(_ALIAS_MARK)
    if start < 0:
        return {}
    i = start + len(_ALIAS_MARK)
    depth = 1           # the annotation nests {output}: (..., {index}) pairs
    while i < len(hlo_text) and depth:
        depth += {"{": 1, "}": -1}.get(hlo_text[i], 0)
        i += 1
    out: Dict[int, int] = {}
    for entry in _ALIAS_ENTRY_RE.finditer(hlo_text[start:i]):
        out_index = int(entry.group(1).split(",")[0]) if entry.group(1).strip() else 0
        out[int(entry.group(2))] = out_index
    return out


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    c = HloCostCounter(hlo_text).entry_costs()
    out = {"flops": c.flops, "bytes": c.bytes,
           "collective_bytes": c.collective_total}
    out.update({f"collective_{k}": v for k, v in c.collective.items()})
    return out
