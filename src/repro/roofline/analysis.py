"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies HLO_FLOPs / bytes-accessed; collective bytes
are parsed from the (post-SPMD-partitioning) compiled HLO text by summing
the output shapes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op. Scan bodies (while loops) appear once
in the HLO; ``trip_multipliers`` lets the caller scale body-counted ops by
the known static trip counts (K local steps, L scanned layers) — recorded
per experiment in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip), per the assignment spec.
HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s per link (~4 links usable per chip)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO op line: `%name = f32[1,2,3]{...} all-reduce(...)` (possibly a
# tuple type `(f32[2], f32[4])`)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str,
                              trip_multipliers: Optional[Dict[str, float]]
                              = None) -> Dict[str, float]:
    """Sum output bytes per collective kind over the HLO module text.

    ``trip_multipliers``: {computation_name_substring: multiplier} — ops
    inside a while-body computation whose name matches get scaled (scan
    bodies execute trip_count times but appear once in text).
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    current_mult = 1.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like: `%body.123 (arg: ...) -> ... {`
        if (stripped.startswith("%") or stripped.startswith("ENTRY")) \
                and stripped.endswith("{"):
            current_mult = 1.0
            if trip_multipliers:
                for frag, mult in trip_multipliers.items():
                    if frag in stripped.split("(")[0]:
                        current_mult = mult
                        break
            continue
        m = _OP_RE.search(stripped)
        if not m:
            continue
        type_str, op = m.groups()
        for c in _COLLECTIVES:
            if op.startswith(c):
                out[c] += _shape_bytes(type_str) * current_mult
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * HW["peak_flops"])

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HW["hbm_bw"])

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * HW["ici_bw"])

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
        }


def roofline_terms(cost: Dict[str, float], collective_bytes: float,
                   chips: int, *, flops_multiplier: float = 1.0,
                   bytes_multiplier: float = 1.0) -> RooflineTerms:
    """cost: ``compiled.cost_analysis()`` dict. Multipliers fold in scan
    trip counts the HLO-level analysis undercounts (documented per run)."""
    return RooflineTerms(
        flops=float(cost.get("flops", 0.0)) * flops_multiplier,
        hbm_bytes=float(cost.get("bytes accessed", 0.0)) * bytes_multiplier,
        collective_bytes=float(collective_bytes),
        chips=chips,
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE) + param counting
# ---------------------------------------------------------------------------

def count_params(cfg) -> Dict[str, float]:
    """Analytic parameter counts (total and active-per-token) per config."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    a = cfg.attention
    hd = cfg.head_dim
    attn = d * a.num_heads * hd + 2 * d * a.num_kv_heads * hd \
        + a.num_heads * hd * d
    mlp_dense = 3 * d * f if cfg.mlp_type == "swiglu" else 2 * d * f
    total = 0.0
    active = 0.0
    emb = v * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family in ("dense", "vlm", "audio"):
        per_layer = attn + mlp_dense
        total = cfg.num_layers * per_layer
        if cfg.family == "audio":
            total += cfg.encoder_layers * (attn + mlp_dense) \
                + cfg.num_layers * attn  # cross-attention
        active = total
    elif cfg.family == "moe":
        m = cfg.moe
        fe = m.d_ff_expert or f
        expert = 3 * d * fe
        shared = 3 * d * fe * m.num_shared_experts
        router = d * m.num_experts
        per_layer_total = attn + m.num_experts * expert + shared + router
        per_layer_active = attn + m.top_k * expert + shared + router
        total = cfg.num_layers * per_layer_total
        active = cfg.num_layers * per_layer_active
    elif cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * d
        nheads = d_inner // s.head_dim
        d_in_proj = 2 * d_inner + 2 * s.ngroups * s.state_dim + nheads
        ssm_block = d * d_in_proj + d_inner * d \
            + s.conv_width * (d_inner + 2 * s.ngroups * s.state_dim) \
            + 3 * nheads + d_inner
        if cfg.family == "ssm":
            total = cfg.num_layers * ssm_block
        else:
            kinds = cfg.layer_kinds()
            n_ssm = sum(1 for k in kinds if k == "ssm")
            shared_attn = attn + mlp_dense
            total = n_ssm * ssm_block + (
                shared_attn if cfg.hybrid_shared_attn
                else (len(kinds) - n_ssm) * shared_attn)
        active = total
    total += emb
    active += emb
    return {"total": total, "active": active}


def model_flops(cfg, tokens: float) -> float:
    """6 * N_active * D (forward+backward) — the standard training-FLOPs
    yardstick; for forward-only divide by 3."""
    return 6.0 * count_params(cfg)["active"] * tokens
