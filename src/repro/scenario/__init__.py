"""Participation scenarios: *system* heterogeneity on top of the data
heterogeneity (docs/scenarios.md).

The synthetic tasks model WHAT each client's data looks like (Dirichlet
non-iid shards); this package models WHO shows up and HOW MUCH work they
finish:

``availability``   per-round client availability processes
                   (always-on | per-client Bernoulli with skewed rates |
                   trace-driven schedules)
``straggler``      per-(round, client) effective local steps K_i <= K,
                   realized as a static-shape (S, K) step-validity mask
``weights``        aggregation weight schemes applied to the cross-client
                   upload reduction (uniform | data_size | inv_steps)
``engine``         ``ParticipationScenario`` — ties the three together,
                   built from ``FedConfig`` (``ParticipationScenario.from_fed``)

Everything here runs HOST-side and feeds the jitted round engine through
two reserved keys of the round batch pytree (``STEP_MASK_KEY``,
``AGG_WEIGHTS_KEY``) so jit, donation, multi-round fusion, and both
placement layouts keep working unchanged. The degenerate scenario
(all clients available, uniform weights, K_i = K) emits NO reserved keys
and is bit-exact with the scenario-free engine.
"""

# Reserved keys of the round batch pytree. The batch generator adds them
# when a scenario is non-degenerate; core.rounds pops them before the
# local-step scan ever sees the batch dict. Leading underscore keeps them
# out of any model input namespace.
STEP_MASK_KEY = "_step_mask"      # (S, K) bool: step k of client s ran
AGG_WEIGHTS_KEY = "_agg_weights"  # (S,) f32, sums to 1: upload weights

from repro.scenario.availability import (  # noqa: E402
    AlwaysOn,
    Bernoulli,
    Trace,
    parse_availability,
)
from repro.scenario.straggler import StragglerModel, step_validity_mask  # noqa: E402
from repro.scenario.weights import WEIGHT_SCHEMES, aggregation_weights  # noqa: E402
from repro.scenario.engine import ParticipationScenario  # noqa: E402

__all__ = [
    "STEP_MASK_KEY", "AGG_WEIGHTS_KEY",
    "AlwaysOn", "Bernoulli", "Trace", "parse_availability",
    "StragglerModel", "step_validity_mask",
    "WEIGHT_SCHEMES", "aggregation_weights",
    "ParticipationScenario",
]
