"""Aggregation weight schemes for the cross-client upload reduction.

The round engine reduces the S sampled clients' uploads (delta, block-mean
v, SCAFFOLD dc, ...) with weights w (sum 1); the seed engine's uniform
mean is the special case w_i = 1/S. Schemes
(``FedConfig.agg_weighting`` / ``--agg-weighting``):

``uniform``
    w_i = 1/S — paper Algorithms 1-3 as written (every aggregation is an
    unweighted mean over the participating cohort).
``data_size``
    w_i ∝ n_i (the client's sample count) — FedAvg's original weighting;
    the right estimator when client deltas should count in proportion to
    the data that produced them (unequal Dirichlet shards).
``inv_steps``
    w_i ∝ 1/K_i (the client's *effective* local steps this round) —
    FedNova-flavored straggler normalization: a client cut off after
    K_i < K steps produced a delta roughly K_i/K as long, so inverse-step
    weighting re-balances per-step contributions instead of letting slow
    clients be double-penalized (fewer steps AND full averaging weight
    over a shorter walk).

Weights are computed host-side in float64, normalized to sum to 1, then
cast to the f32 the device reduction consumes; they ride the round batch
pytree under ``repro.scenario.AGG_WEIGHTS_KEY``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

WEIGHT_SCHEMES = ("uniform", "data_size", "inv_steps")


def aggregation_weights(scheme: str, client_ids: np.ndarray, *,
                        data_sizes: Optional[np.ndarray] = None,
                        local_steps_per_client: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """``(S,)`` f32 weights for this round's sampled cohort; sums to 1."""
    cids = np.asarray(client_ids)
    s = len(cids)
    if scheme == "uniform":
        w = np.ones(s, dtype=np.float64)
    elif scheme == "data_size":
        if data_sizes is None:
            raise ValueError(
                "agg_weighting='data_size' needs per-client data sizes "
                "(pass data_sizes= / build the scenario from a task)")
        w = np.asarray(data_sizes, dtype=np.float64)[cids]
        if (w <= 0).any():
            raise ValueError("data_size weighting: every sampled client "
                             "must own at least one sample")
    elif scheme == "inv_steps":
        if local_steps_per_client is None:
            raise ValueError(
                "agg_weighting='inv_steps' needs the round's effective "
                "local steps K_i (enable the straggler model or pass "
                "local_steps_per_client=)")
        k_i = np.asarray(local_steps_per_client, dtype=np.float64)
        if (k_i < 1).any():
            raise ValueError("inv_steps weighting: K_i must be >= 1")
        w = 1.0 / k_i
    else:
        raise ValueError(
            f"unknown agg_weighting {scheme!r}; known: {WEIGHT_SCHEMES}")
    return (w / w.sum()).astype(np.float32)
