"""Client availability processes.

An availability process answers one question per round: which of the N
clients could participate this round? It returns a ``(num_clients,)``
bool mask that the sampling strategy (``repro.data.sampler``) consumes.

Determinism contract: ``mask(round_index)`` is a pure function of
``(spec, seed, round_index)`` — every process derives its randomness from
a per-round ``np.random.Generator`` seeded by ``(seed, round_index)``,
NEVER from a shared stream. Eager, host-prefetched, and multi-round-fused
execution therefore see identical availability no matter when (or on
which thread) each round's batch is assembled, and the batch rng stream
stays untouched, keeping the degenerate scenario bit-exact with the
scenario-free engine.

Spec strings (``FedConfig.availability`` / ``--availability``):

``always_on``
    Every client available every round (the idealized seed regime).
``bernoulli<rate>[:<conc>]``
    Independent per-client, per-round coin flips. Plain
    ``bernoulli0.8`` gives every client the same 0.8 rate;
    ``bernoulli0.8:2`` draws per-client rates once from
    ``Beta(rate*conc, (1-rate)*conc)`` — small ``conc`` = heavily skewed
    rates (some clients nearly always on, some nearly always off), large
    ``conc`` = rates concentrated near the mean.
``trace:<path.npy>``
    Replay a recorded ``(rounds, num_clients)`` 0/1 schedule (cycled when
    training runs longer than the trace).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

# salts folded into per-purpose seed sequences so the rate draw, the
# per-round coin flips, and the straggler draws never alias
_RATE_SALT = 0xA11
_FLIP_SALT = 0xB0B


@dataclasses.dataclass(frozen=True)
class AlwaysOn:
    """Every client available every round."""

    num_clients: int
    name: str = "always_on"

    def mask(self, round_index: int) -> np.ndarray:
        return np.ones(self.num_clients, dtype=bool)


class Bernoulli:
    """Independent per-client availability coin flips.

    ``rate`` is the mean availability; ``concentration`` (optional)
    spreads per-client rates with a Beta distribution so availability is
    *skewed* across the population rather than uniform — the regime where
    availability-aware sampling and weighting matter.
    """

    def __init__(self, num_clients: int, rate: float,
                 concentration: Optional[float] = None, seed: int = 0):
        if not 0.0 < rate <= 1.0:
            raise ValueError(
                f"bernoulli availability rate must be in (0, 1], got {rate}")
        if concentration is not None and concentration <= 0:
            raise ValueError(
                f"bernoulli concentration must be > 0, got {concentration}")
        self.num_clients = num_clients
        self.rate = float(rate)
        self.concentration = concentration
        self.seed = int(seed)
        self.name = (f"bernoulli{rate:g}" if concentration is None
                     else f"bernoulli{rate:g}:{concentration:g}")
        if concentration is None:
            self.rates = np.full(num_clients, self.rate)
        else:
            rng = np.random.default_rng([self.seed, _RATE_SALT])
            a = max(rate * concentration, 1e-6)
            b = max((1.0 - rate) * concentration, 1e-6)
            self.rates = rng.beta(a, b, size=num_clients)

    def mask(self, round_index: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, _FLIP_SALT, int(round_index)])
        return rng.random(self.num_clients) < self.rates


class Trace:
    """Replay a recorded ``(rounds, num_clients)`` availability schedule,
    cycled when training outlives the trace."""

    def __init__(self, trace: np.ndarray, num_clients: Optional[int] = None):
        trace = np.asarray(trace)
        if trace.ndim != 2:
            raise ValueError(
                f"availability trace must be (rounds, num_clients), "
                f"got shape {trace.shape}")
        if num_clients is not None and trace.shape[1] != num_clients:
            raise ValueError(
                f"availability trace covers {trace.shape[1]} clients but "
                f"the run has num_clients={num_clients}")
        if len(trace) == 0:
            raise ValueError("availability trace has zero rounds")
        self.trace = trace.astype(bool)
        self.num_clients = trace.shape[1]
        self.name = "trace"

    def mask(self, round_index: int) -> np.ndarray:
        return self.trace[int(round_index) % len(self.trace)]


AvailabilityProcess = Union[AlwaysOn, Bernoulli, Trace]


def parse_availability(spec: str, num_clients: int, *, seed: int = 0,
                       trace: Optional[np.ndarray] = None
                       ) -> AvailabilityProcess:
    """Spec string -> availability process (see module docstring).

    ``trace`` lets programmatic callers pass the schedule array directly
    under the plain ``"trace"`` spec; ``"trace:<path.npy>"`` loads it.
    """
    if spec == "always_on":
        return AlwaysOn(num_clients)
    if spec.startswith("bernoulli"):
        arg = spec[len("bernoulli"):]
        if not arg:
            raise ValueError(
                "bernoulli availability needs a rate, e.g. 'bernoulli0.8' "
                "or 'bernoulli0.8:2' (rate:concentration)")
        rate_s, _, conc_s = arg.partition(":")
        try:
            rate = float(rate_s)
            conc = float(conc_s) if conc_s else None
        except ValueError:
            raise ValueError(
                f"bad bernoulli availability spec {spec!r}; expected "
                "'bernoulli<rate>[:<concentration>]'") from None
        return Bernoulli(num_clients, rate, conc, seed=seed)
    if spec == "trace" or spec.startswith("trace:"):
        if trace is None:
            _, _, path = spec.partition(":")
            if not path:
                raise ValueError(
                    "trace availability needs a schedule: pass "
                    "'trace:<path.npy>' or supply the array via "
                    "ParticipationScenario.from_fed(..., trace=...)")
            trace = np.load(path)
        return Trace(trace, num_clients)
    raise ValueError(
        f"unknown availability spec {spec!r}; known: 'always_on', "
        "'bernoulli<rate>[:<conc>]', 'trace[:<path.npy>]'")
