"""Straggler simulation: per-(round, client) effective local steps.

A straggler is a client that gets cut off before finishing its K local
steps (slow hardware, dropped connection, deadline-based server). The
model here:

* A fixed cohort of ``round(frac * num_clients)`` straggler clients is
  drawn once per scenario (seeded, independent of every other stream).
* Each round, every straggler draws ``K_i ~ Uniform{min_steps, ..., K}``
  from a per-round generator seeded by ``(seed, round_index)``; draws are
  made for ALL clients so a client's K_i for a round does not depend on
  which cohort was sampled. Non-stragglers always run all K steps.

The jitted round program keeps its static ``(S, K)`` batch shape: a
straggler's truncation is a ``(S, K)`` bool *step-validity mask*
(:func:`step_validity_mask`) — step k of client s computes its gradient
like every other step, but a masked step's parameter/optimizer-state
update is discarded (``jnp.where`` carry-through in
``repro.core.rounds.make_local_phase``) and its loss carries zero weight
in the round metrics. The upload therefore reflects exactly the first
K_i steps, at the cost of the masked steps' (wasted) gradient FLOPs —
the price of a shape-static simulation.
"""
from __future__ import annotations

import numpy as np

_COHORT_SALT = 0x57A6
_STEPS_SALT = 0x57E9


def step_validity_mask(local_steps_per_client: np.ndarray,
                       local_steps: int) -> np.ndarray:
    """``(S,)`` per-client step counts -> ``(S, K)`` bool mask with the
    first ``K_i`` steps of row i valid."""
    k_i = np.asarray(local_steps_per_client)
    return np.arange(local_steps)[None, :] < k_i[:, None]


class StragglerModel:
    """Fixed straggler cohort + per-round effective step counts."""

    def __init__(self, num_clients: int, local_steps: int, frac: float,
                 min_steps: int = 1, seed: int = 0):
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"straggler_frac must be in [0, 1], got {frac}")
        if not 1 <= min_steps <= local_steps:
            raise ValueError(
                f"straggler_min_steps must be in [1, local_steps="
                f"{local_steps}], got {min_steps}")
        self.num_clients = num_clients
        self.local_steps = local_steps
        self.frac = float(frac)
        self.min_steps = int(min_steps)
        self.seed = int(seed)
        n_strag = int(round(frac * num_clients))
        rng = np.random.default_rng([self.seed, _COHORT_SALT])
        cohort = rng.choice(num_clients, size=n_strag, replace=False)
        self.is_straggler = np.zeros(num_clients, dtype=bool)
        self.is_straggler[cohort] = True

    def local_steps_for(self, round_index: int,
                        client_ids: np.ndarray) -> np.ndarray:
        """Effective K_i for the sampled clients this round, ``(S,)`` int."""
        cids = np.asarray(client_ids)
        rng = np.random.default_rng([self.seed, _STEPS_SALT,
                                     int(round_index)])
        draws = rng.integers(self.min_steps, self.local_steps + 1,
                             size=self.num_clients)
        return np.where(self.is_straggler[cids], draws[cids],
                        self.local_steps).astype(np.int32)

    def step_mask(self, round_index: int,
                  client_ids: np.ndarray) -> np.ndarray:
        """``(S, K)`` bool step-validity mask for the sampled clients."""
        return step_validity_mask(self.local_steps_for(round_index,
                                                       client_ids),
                                  self.local_steps)
