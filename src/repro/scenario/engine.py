"""``ParticipationScenario``: one object per run tying together
availability, sampling, stragglers, and aggregation weighting.

The scenario runs entirely host-side, inside the batch producer
(``repro.data.sampler.RoundBatchGenerator``), in three steps per round:

1. ``availability.mask(r)`` — which of the N clients could show up;
2. the sampling strategy (``repro.data.sampler`` registry) picks the S
   participants, consuming the generator's shared rng stream exactly like
   the seed engine's uniform sampler does;
3. ``round_payload(r, cids)`` — the straggler step-validity mask and the
   aggregation weights, attached to the round batch pytree under the
   reserved keys (``repro.scenario.STEP_MASK_KEY`` /
   ``AGG_WEIGHTS_KEY``) that ``repro.core.rounds`` pops at trace time.

A degenerate scenario (``always_on`` + ``uniform`` sampling + no
stragglers + ``uniform`` weighting) emits an EMPTY payload and makes
byte-identical rng calls, so the jitted round program and the data stream
are exactly the scenario-free engine's — bit-exactness by construction
(asserted in tests/test_scenario.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.scenario import availability as _availability
from repro.scenario.straggler import StragglerModel
from repro.scenario.weights import WEIGHT_SCHEMES, aggregation_weights


@dataclasses.dataclass(frozen=True)
class ParticipationScenario:
    num_clients: int
    clients_per_round: int
    local_steps: int
    availability: _availability.AvailabilityProcess
    sampling: str = "uniform"
    straggler: Optional[StragglerModel] = None
    weighting: str = "uniform"
    # per-client sample counts (len num_clients); required by the
    # data-size weighted sampler / weighting scheme
    data_sizes: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.weighting not in WEIGHT_SCHEMES:
            raise ValueError(f"unknown agg_weighting {self.weighting!r}; "
                             f"known: {WEIGHT_SCHEMES}")
        # fail at construction, not mid-training
        from repro.data.sampler import get_sampler
        get_sampler(self.sampling)

    # -- per-round host-side products ----------------------------------

    @property
    def is_degenerate(self) -> bool:
        """True when the scenario reproduces the idealized seed regime
        exactly (and the engine takes the scenario-free code path)."""
        return (isinstance(self.availability, _availability.AlwaysOn)
                and self.sampling == "uniform"
                and self.straggler is None
                and self.weighting == "uniform")

    @property
    def needs_payload(self) -> bool:
        """True when rounds carry a step mask / weight vector on device."""
        return self.straggler is not None or self.weighting != "uniform"

    def availability_mask(self, round_index: int) -> np.ndarray:
        return self.availability.mask(round_index)

    def sample_round(self, round_index: int,
                     rng: np.random.Generator) -> np.ndarray:
        """Pick this round's S participants (consumes ``rng``)."""
        from repro.data.sampler import get_sampler
        return get_sampler(self.sampling)(
            self.num_clients, self.clients_per_round, rng,
            data_sizes=self.data_sizes,
            available=self.availability_mask(round_index))

    def local_steps_for(self, round_index: int,
                        client_ids: np.ndarray) -> np.ndarray:
        """Effective K_i of the sampled clients, ``(S,)`` int32."""
        if self.straggler is None:
            return np.full(len(client_ids), self.local_steps, np.int32)
        return self.straggler.local_steps_for(round_index, client_ids)

    def round_payload(self, round_index: int,
                      client_ids: np.ndarray) -> Dict[str, np.ndarray]:
        """Reserved-key entries to merge into the round batch pytree
        (empty for scenarios that don't need one)."""
        from repro.scenario import AGG_WEIGHTS_KEY, STEP_MASK_KEY
        if not self.needs_payload:
            return {}
        k_i = self.local_steps_for(round_index, client_ids)
        payload = {}
        if self.weighting != "uniform":
            # uniform weights are NOT emitted (even under stragglers):
            # the engine's plain mean IS the uniform reduction, and
            # keeping the key out preserves the mean->all-reduce lowering
            # of the client_parallel layout
            payload[AGG_WEIGHTS_KEY] = aggregation_weights(
                self.weighting, client_ids, data_sizes=self.data_sizes,
                local_steps_per_client=k_i)
        if self.straggler is not None:
            from repro.scenario.straggler import step_validity_mask
            payload[STEP_MASK_KEY] = step_validity_mask(
                k_i, self.local_steps)
        return payload

    # -- construction ---------------------------------------------------

    @classmethod
    def from_fed(cls, fed, *, data_sizes=None, task=None,
                 seed: Optional[int] = None,
                 trace: Optional[np.ndarray] = None
                 ) -> "ParticipationScenario":
        """Build the scenario a ``FedConfig`` describes.

        ``task`` (a ``SyntheticTask``) supplies per-client data sizes when
        ``data_sizes`` is not given; ``seed`` defaults to
        ``fed.scenario_seed``; ``trace`` feeds the ``"trace"``
        availability spec directly (otherwise ``trace:<path.npy>`` loads
        from disk).
        """
        seed = fed.scenario_seed if seed is None else seed
        if data_sizes is None and task is not None:
            data_sizes = np.asarray(
                [len(ix) for ix in task.client_indices], np.int64)
        avail = _availability.parse_availability(
            fed.availability, fed.num_clients, seed=seed, trace=trace)
        straggler = None
        if fed.straggler_frac > 0.0:
            straggler = StragglerModel(
                fed.num_clients, fed.local_steps, fed.straggler_frac,
                min_steps=fed.straggler_min_steps, seed=seed)
        return cls(
            num_clients=fed.num_clients,
            clients_per_round=fed.clients_per_round,
            local_steps=fed.local_steps,
            availability=avail, sampling=fed.sampling,
            straggler=straggler, weighting=fed.agg_weighting,
            data_sizes=data_sizes)
