"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

TPU adaptation notes (DESIGN.md §2): the original CUDA kernel interleaves a
chunked intra-block "attention-like" matmul with a cross-chunk recurrence.
We keep exactly that block decomposition — intra-chunk terms are dense
(Q=chunk_size) MXU matmuls, the cross-chunk state carry is a ``lax.scan``
over chunks (O(S/Q) sequential steps) — rather than a token-level scan,
which would serialize the MXU.

Parameter layout (names feed the FedAdamW block partitioner):

    ssm_in_proj : (d_model, d_in_proj)   packed [z, x, B, C, dt]
    ssm_conv    : (conv_width, conv_channels)
    ssm_A_log   : (H,)
    ssm_D       : (H,)
    ssm_dt_bias : (H,)
    ssm_norm    : (d_inner,)
    ssm_out_proj: (d_inner, d_model)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import _dense_init, rms_norm_simple

Array = jax.Array


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    # packed input projection: z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.state_dim + nheads
    conv_channels = d_inner + 2 * s.ngroups * s.state_dim
    return d_inner, nheads, d_in_proj, conv_channels


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, d_in_proj, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ssm_in_proj": _dense_init(ks[0], (cfg.d_model, d_in_proj)),
        "ssm_conv": _dense_init(ks[1], (s.conv_width, conv_ch), scale=s.conv_width ** -0.5),
        "ssm_A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)),
        "ssm_D": jnp.ones((nheads,)),
        "ssm_dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, nheads))),
        "ssm_norm": jnp.ones((d_inner,)),
        "ssm_out_proj": _dense_init(ks[3], (d_inner, cfg.d_model)),
    }


def _split_proj(proj: Array, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, _, _ = ssm_dims(cfg)
    gn = s.ngroups * s.state_dim
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gn], axis=-1)
    return z, xbc, dt  # xbc: conv input channels, dt: (.., H)


def _causal_conv(xbc: Array, weight: Array) -> Array:
    """Depthwise causal conv along seq. xbc: (b, s, ch); weight: (w, ch)."""
    w = weight.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(w):
        out = out + pad[:, i:i + xbc.shape[1], :] * weight[i]
    return jax.nn.silu(out)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, initial_state: Array | None = None,
                cross_chunk: str = "closed") -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x:  (b, s, h, p)   per-head inputs
    dt: (b, s, h)      positive step sizes
    A:  (h,)           negative decay rates
    B:  (b, s, g, n)   input maps (g groups broadcast over h)
    C:  (b, s, g, n)   output maps
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,q,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtc * A  # (b, nc, q, h) negative
    dA_cs = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum
    # intra-chunk: L[i,j] = exp(dA_cs[i] - dA_cs[j]) for i >= j else 0.
    # Mask BEFORE the exp: for j > i the argument is positive and can
    # overflow, and even a masked overflow poisons gradients through the
    # where (inf * 0 -> NaN in the cotangent).
    li = dA_cs[:, :, :, None, :]                       # (b,nc,q,1,h)
    lj = dA_cs[:, :, None, :, :]                       # (b,nc,1,q,h)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    diff = jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf)
    L = jnp.exp(diff)

    dx = xc * dtc[..., None]                           # (b,nc,q,h,p)
    # scores: C_i · B_j  -> (b,nc,q,q,h)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc)
    y_intra = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", scores, L, dx)

    # per-chunk end states: S_c = sum_j exp(dA_cs[end]-dA_cs[j]) B_j dx_j
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # (b,nc,q,h)
    chunk_states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                              decay_to_end, Bc, dx)            # (b,nc,h,p,n)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # (b,nc,h)

    init = (jnp.zeros((b, h, p, n), x.dtype)
            if initial_state is None else initial_state)

    if cross_chunk == "scan":
        # sequential recurrence over chunks (the paper's formulation)
        def carry_fn(state, inp):
            st_c, dec_c = inp                                  # (b,h,p,n), (b,h)
            new = state * dec_c[:, :, None, None] + st_c
            return new, state                                  # state *before* chunk
        final_state, prev_states = jax.lax.scan(
            carry_fn, init,
            (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
        prev_states = prev_states.swapaxes(0, 1)               # (b,nc,h,p,n)
    else:
        # closed form: prev_state[c] = sum_{j<c} exp(cum[c-1]-cum[j]) S_j
        #                            + exp(cum[c-1]) init
        # with cum = cumsum(log chunk decay) and cum[-1] := 0. All decay
        # ratios are <= 1 (arguments masked to -inf BEFORE exp), so this
        # is exactly the scan recurrence with no serial dependency and
        # one (nc x nc) masked einsum instead of nc sequential steps.
        ld = dA_cs[:, :, -1, :]                                # (b,nc,h) <= 0
        cum = jnp.cumsum(ld, axis=1)
        cum_prev = jnp.pad(cum, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # cum[c-1]
        ratio = cum_prev[:, :, None, :] - cum[:, None, :, :]   # (b,c,j,h)
        cj_mask = (jnp.arange(nc)[:, None] > jnp.arange(nc)[None, :])
        ratio = jnp.where(cj_mask[None, :, :, None], ratio, -jnp.inf)
        W = jnp.exp(ratio)                                     # (b,nc,nc,h)
        prev_states = jnp.einsum("bcjh,bjhpn->bchpn", W, chunk_states)
        prev_states = prev_states + (jnp.exp(cum_prev)[..., None, None]
                                     * init[:, None])
        final_state = (jnp.einsum(
            "bjh,bjhpn->bhpn", jnp.exp(cum[:, -1:, :] - cum), chunk_states)
            + jnp.exp(cum[:, -1])[..., None, None] * init)

    # inter-chunk: y_j += C_j exp(dA_cs[j]) S_prev
    decay_from_start = jnp.exp(dA_cs)                          # (b,nc,q,h)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         Cc, decay_from_start, prev_states)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final_state


def apply_ssm(params, x: Array, cfg: ModelConfig) -> Array:
    """Training / prefill forward. x: (b, s, d_model)."""
    s_cfg = cfg.ssm
    d_inner, nheads, _, _ = ssm_dims(cfg)
    dt_ = x.dtype
    b, s, _ = x.shape

    proj = jnp.einsum("bsd,de->bse", x, params["ssm_in_proj"].astype(dt_))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["ssm_conv"].astype(dt_))
    gn = s_cfg.ngroups * s_cfg.state_dim
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["ssm_dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["ssm_A_log"].astype(jnp.float32))

    xh = xs.reshape(b, s, nheads, s_cfg.head_dim).astype(jnp.float32)
    Bh = B.reshape(b, s, s_cfg.ngroups, s_cfg.state_dim).astype(jnp.float32)
    Ch = C.reshape(b, s, s_cfg.ngroups, s_cfg.state_dim).astype(jnp.float32)

    chunk = min(s_cfg.chunk_size, s)
    y, _ = ssd_chunked(xh, dt, A, Bh, Ch, chunk,
                       cross_chunk=s_cfg.cross_chunk)
    y = y + params["ssm_D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, s, d_inner).astype(dt_)
    y = rms_norm_simple(y * jax.nn.silu(z), params["ssm_norm"])
    return jnp.einsum("bse,ed->bsd", y, params["ssm_out_proj"].astype(dt_))


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, nheads, _, conv_ch = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_ssm(params, x: Array, cache: dict, cfg: ModelConfig) -> Tuple[Array, dict]:
    """Single-token decode: O(1) in context length. x: (b, 1, d_model)."""
    s_cfg = cfg.ssm
    d_inner, nheads, _, _ = ssm_dims(cfg)
    dt_ = x.dtype
    b = x.shape[0]

    proj = jnp.einsum("bsd,de->bse", x, params["ssm_in_proj"].astype(dt_))
    z, xbc, dt_raw = _split_proj(proj, cfg)

    # causal conv against cached window
    w = params["ssm_conv"].astype(dt_)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)     # (b, w, ch)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))[:, None, :]
    new_conv = window[:, 1:, :]

    gn = s_cfg.ngroups * s_cfg.state_dim
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["ssm_dt_bias"].astype(jnp.float32))[:, 0]  # (b,h)
    A = -jnp.exp(params["ssm_A_log"].astype(jnp.float32))

    xh = xs[:, 0].reshape(b, nheads, s_cfg.head_dim).astype(jnp.float32)
    Bh = B[:, 0].reshape(b, s_cfg.ngroups, s_cfg.state_dim).astype(jnp.float32)
    Ch = C[:, 0].reshape(b, s_cfg.ngroups, s_cfg.state_dim).astype(jnp.float32)
    rep = nheads // s_cfg.ngroups
    Bh = jnp.repeat(Bh, rep, axis=1)                           # (b,h,n)
    Ch = jnp.repeat(Ch, rep, axis=1)

    dA = jnp.exp(dt * A)                                       # (b,h)
    new_state = (cache["state"] * dA[:, :, None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + params["ssm_D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(dt_)
    y = rms_norm_simple(y * jax.nn.silu(z), params["ssm_norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["ssm_out_proj"].astype(dt_))
    new_cache = {"state": new_state, "conv": new_conv, "index": cache["index"] + 1}
    return out, new_cache
