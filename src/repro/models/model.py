"""Top-level Model: init / train loss / prefill / decode_step for every family.

Batch dictionary contract (all leaves optional except ``tokens``/``labels``):

    tokens          (B, S) int32     input token ids
    labels          (B, S) int32     next-token targets (-1 = ignore)
    frontend_feats  (B, T_f, E_f)    precomputed patch/frame embeddings
                                     (vlm/audio stub frontends)
    mrope_positions (B, S, 3)        Qwen2-VL t/h/w position ids
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as stacks
from repro.models.layers import (
    apply_frontend_projector,
    embed_tokens,
    init_embeddings,
    init_frontend_projector,
    lm_logits,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    scan_layers: bool = True
    remat: str = "none"
    compute_dtype: Any = jnp.bfloat16
    # optional PartitionSpec for (batch, seq, d) activations — re-anchors
    # batch sharding at block boundaries under the FSDP layout
    act_pspec: Any = None

    # ----- parameters -------------------------------------------------------
    def init(self, rng: jax.Array) -> Dict[str, Any]:
        k_emb, k_stack, k_fe = jax.random.split(rng, 3)
        params = {}
        params.update(init_embeddings(k_emb, self.cfg))
        params.update(init_stack(k_stack, self.cfg))
        if self.cfg.family in ("vlm", "audio"):
            params.update(init_frontend_projector(k_fe, self.cfg))
        return params

    # ----- training forward -------------------------------------------------
    def forward(self, params, batch: Dict[str, Array]) -> Tuple[Array, Array]:
        """Full-sequence forward. Returns (logits, aux_loss)."""
        cfg = self.cfg
        dt = self.compute_dtype
        tokens = batch["tokens"]
        x = embed_tokens(params, tokens, cfg, dt)

        memory = None
        mrope_positions = batch.get("mrope_positions")
        if cfg.family == "audio":
            memory = apply_frontend_projector(params, batch["frontend_feats"], dt)
        elif cfg.family == "vlm":
            # prepend projected patch embeddings to the text sequence
            patches = apply_frontend_projector(params, batch["frontend_feats"], dt)
            x = jnp.concatenate([patches, x], axis=1)
            if mrope_positions is not None:
                n_patch = patches.shape[1]
                patch_pos = _vlm_patch_positions(batch, n_patch)
                mrope_positions = jnp.concatenate(
                    [patch_pos, mrope_positions + n_patch], axis=1)

        x, aux = stacks.apply_stack(
            params, x, cfg, memory=memory,
            mrope_positions=mrope_positions,
            scan_layers=self.scan_layers, remat=self.remat,
            act_pspec=self.act_pspec)

        if cfg.family == "vlm":
            x = x[:, batch["frontend_feats"].shape[1]:, :]  # text positions only
        logits = lm_logits(params, x, cfg)
        return logits, aux

    def loss(self, params, batch: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        valid = labels >= 0
        safe = jnp.where(valid, labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(valid.sum(), 1)
        ce = jnp.where(valid, nll, 0.0).sum() / denom
        total = ce + aux
        return total, {"ce": ce, "aux": aux,
                       "accuracy": (jnp.where(
                           valid, (jnp.argmax(logits, -1) == labels), False
                       ).sum() / denom)}

    # ----- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Any:
        return stacks.init_stack_cache(self.cfg, batch, max_len, self.compute_dtype)

    def decode_step(self, params, tokens: Array, cache: Any, *,
                    memory: Optional[Array] = None,
                    mrope_positions=None) -> Tuple[Array, Any]:
        """tokens: (B, 1). Returns (logits (B,1,V), new cache)."""
        cfg = self.cfg
        dt = self.compute_dtype
        x = embed_tokens(params, tokens, cfg, dt)
        if cfg.family == "audio" and memory is None:
            raise ValueError("audio decode requires encoder memory")
        if cfg.family == "audio":
            memory = memory.astype(dt)
        x, cache = stacks.decode_stack(
            params, x, cache, cfg, memory=memory,
            scan_layers=self.scan_layers, mrope_positions=mrope_positions)
        return lm_logits(params, x, cfg), cache

    def encode(self, params, frontend_feats: Array) -> Array:
        """Audio: run the encoder over projected frame embeddings."""
        cfg = self.cfg
        dt = self.compute_dtype
        mem = apply_frontend_projector(params, frontend_feats, dt)

        def enc_body(h, layer_params):
            h2, _ = stacks.apply_attn_block(layer_params, h, cfg, causal=False)
            return h2, None

        if self.scan_layers:
            mem, _ = jax.lax.scan(enc_body, mem, params["encoder"])
        else:
            for i in range(cfg.encoder_layers):
                layer = jax.tree.map(lambda a: a[i], params["encoder"])
                mem, _ = enc_body(mem, layer)
        return mem

    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))


def _vlm_patch_positions(batch: Dict[str, Array], n_patch: int) -> Array:
    """M-RoPE ids for a single image's patch grid (t=0, h/w raster order)."""
    b = batch["tokens"].shape[0]
    side = max(1, int(n_patch ** 0.5))
    hh = (jnp.arange(n_patch) // side).astype(jnp.int32)
    ww = (jnp.arange(n_patch) % side).astype(jnp.int32)
    tt = jnp.zeros((n_patch,), jnp.int32)
    pos = jnp.stack([tt, hh, ww], axis=-1)          # (n_patch, 3)
    return jnp.broadcast_to(pos[None], (b, n_patch, 3))


def init_stack(key, cfg: ModelConfig):
    return stacks.init_stack(key, cfg)


def build_model(cfg: ModelConfig, *, scan_layers: bool = True,
                remat: str = "none", compute_dtype=jnp.bfloat16,
                act_pspec=None) -> Model:
    cfg.validate()
    return Model(cfg=cfg, scan_layers=scan_layers, remat=remat,
                 compute_dtype=compute_dtype, act_pspec=act_pspec)
