"""Attention: GQA with qk-norm / QKV-bias / sliding-window / M-RoPE variants,
causal training path, KV-cache decode path, and cross-attention (enc-dec).

Weights are kept in head-factored layout so the FedAdamW Hessian-block
partitioner can split query/key by head (paper Appendix D Class 1) and
value/attn.proj by output neuron (Class 2/3):

    attn_wq : (d_model, H,  head_dim)
    attn_wk : (d_model, KV, head_dim)
    attn_wv : (d_model, KV, head_dim)
    attn_wo : (H, head_dim, d_model)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import _dense_init, apply_mrope, apply_rope, rms_norm_simple

Array = jax.Array


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    a = cfg.attention
    d, h, kv, hd = cfg.d_model, a.num_heads, a.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    prefix = "cross_" if cross else ""
    p = {
        prefix + "attn_wq": _dense_init(ks[0], (d, h, hd), scale=d ** -0.5),
        prefix + "attn_wk": _dense_init(ks[1], (d, kv, hd), scale=d ** -0.5),
        prefix + "attn_wv": _dense_init(ks[2], (d, kv, hd), scale=d ** -0.5),
        prefix + "attn_wo": _dense_init(ks[3], (h, hd, d), scale=(h * hd) ** -0.5),
    }
    if a.qkv_bias:
        p[prefix + "attn_bq"] = jnp.zeros((h, hd))
        p[prefix + "attn_bk"] = jnp.zeros((kv, hd))
        p[prefix + "attn_bv"] = jnp.zeros((kv, hd))
    if a.qk_norm:
        p[prefix + "attn_qnorm"] = jnp.ones((hd,))
        p[prefix + "attn_knorm"] = jnp.ones((hd,))
    return p


def _project_qkv(params, x: Array, cfg: ModelConfig, prefix: str = ""):
    a = cfg.attention
    dt = x.dtype
    q = jnp.einsum("...d,dhk->...hk", x, params[prefix + "attn_wq"].astype(dt))
    k = jnp.einsum("...d,dmk->...mk", x, params[prefix + "attn_wk"].astype(dt))
    v = jnp.einsum("...d,dmk->...mk", x, params[prefix + "attn_wv"].astype(dt))
    if a.qkv_bias:
        q = q + params[prefix + "attn_bq"].astype(dt)
        k = k + params[prefix + "attn_bk"].astype(dt)
        v = v + params[prefix + "attn_bv"].astype(dt)
    if a.qk_norm:
        q = rms_norm_simple(q, params[prefix + "attn_qnorm"])
        k = rms_norm_simple(k, params[prefix + "attn_knorm"])
    return q, k, v


def _rotate(q, k, positions, cfg: ModelConfig, mrope_positions=None):
    a = cfg.attention
    if a.use_mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, a.rope_theta, a.mrope_sections)
        k = apply_mrope(k, mrope_positions, a.rope_theta, a.mrope_sections)
    else:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    return q, k


def _repeat_kv(k: Array, v: Array, num_heads: int) -> Tuple[Array, Array]:
    kvh = k.shape[-2]
    if kvh == num_heads:
        return k, v
    rep = num_heads // kvh
    k = jnp.repeat(k, rep, axis=-2)
    v = jnp.repeat(v, rep, axis=-2)
    return k, v


def _attention_core_naive(q: Array, k: Array, v: Array, cfg: ModelConfig
                          ) -> Array:
    """Materialized-score attention. q/k/v: (b, s, h, hd) (kv repeated)."""
    a = cfg.attention
    s = q.shape[1]
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bmhd->bhqm", q * scale, k)  # (b, h, q, kv)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = ki <= qi
    if a.sliding_window is not None:
        mask = mask & (ki > qi - a.sliding_window)
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqm,bmhd->bqhd", probs, v)


def _attention_core_chunked(q: Array, k: Array, v: Array, cfg: ModelConfig
                            ) -> Array:
    """Exact flash-style attention: online softmax over KV chunks, query
    blocks in parallel (vmap), KV walked sequentially (scan). Never
    materializes the (s, s) score matrix — the working set is
    O(b*h*s*kv_chunk), which is what lets the 32k prefill and 4k train
    shapes fit HBM (EXPERIMENTS.md §Perf). Same math as the naive path
    (tested allclose)."""
    a = cfg.attention
    b, s_orig, h, hd = q.shape
    qc = min(cfg.attn_q_chunk, s_orig)
    kc = min(cfg.attn_kv_chunk, s_orig)
    # pad the sequence up to a chunk multiple: padded KEYS sit at positions
    # >= s_orig, so the causal mask (col <= row) already excludes them for
    # every real query row; padded QUERY rows are sliced off at the end.
    pad = (-s_orig) % qc
    if kc != qc:
        lcm = qc * kc // __import__("math").gcd(qc, kc)
        pad = (-s_orig) % lcm
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    s = s_orig + pad
    nq, nk = s // qc, s // kc
    scale = cfg.head_dim ** -0.5

    qb = jnp.moveaxis(q.reshape(b, nq, qc, h, hd), 1, 0)   # (nq,b,qc,h,hd)
    kb = jnp.moveaxis(k.reshape(b, nk, kc, h, hd), 1, 0)   # (nk,b,kc,h,hd)
    vb = jnp.moveaxis(v.reshape(b, nk, kc, h, hd), 1, 0)

    neg = jnp.float32(-1e30)

    def one_qblock(qi: Array, qblk: Array) -> Array:
        row = qi * qc + jnp.arange(qc)                     # global q ids

        def kv_step(carry, inp):
            m, l, acc = carry
            j, kblk, vblk = inp
            col = j * kc + jnp.arange(kc)
            logits = jnp.einsum("bqhd,bkhd->bhqk",
                                (qblk * scale).astype(jnp.float32),
                                kblk.astype(jnp.float32))
            mask = col[None, :] <= row[:, None]
            if a.sliding_window is not None:
                mask = mask & (col[None, :] > row[:, None] - a.sliding_window)
            logits = jnp.where(mask[None, None], logits, neg)
            blk_max = jnp.max(logits, axis=-1)             # (b,h,qc)
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            l2 = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p,
                            vblk.astype(jnp.float32))
            acc2 = acc * corr[..., None] + pv
            return (new_m, l2, acc2), None

        m0 = jnp.full((b, h, qc), neg, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,h,qc,hd)
        return jnp.moveaxis(out, 1, 2)                     # (b,qc,h,hd)

    outs = jax.vmap(one_qblock)(jnp.arange(nq), qb)        # (nq,b,qc,h,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    if pad:
        out = out[:, :s_orig]
    return out.astype(q.dtype)


def _attention_core(q: Array, k: Array, v: Array, cfg: ModelConfig) -> Array:
    s = q.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if s > cfg.attn_chunk_threshold else "naive"
    if impl == "chunked":
        return _attention_core_chunked(q, k, v, cfg)
    return _attention_core_naive(q, k, v, cfg)


def causal_attention(params, x: Array, cfg: ModelConfig, *,
                     positions: Optional[Array] = None,
                     mrope_positions: Optional[Array] = None,
                     segment_ids: Optional[Array] = None) -> Array:
    """Training / prefill attention. x: (batch, seq, d_model)."""
    a = cfg.attention
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rotate(q, k, positions, cfg, mrope_positions)
    k, v = _repeat_kv(k, v, a.num_heads)
    if segment_ids is not None:
        # segment masking only exists on the (rarely used) naive path
        scale = cfg.head_dim ** -0.5
        logits = jnp.einsum("bqhd,bmhd->bhqm", q * scale, k)
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        mask = ki <= qi
        if a.sliding_window is not None:
            mask = mask & (ki > qi - a.sliding_window)
        mask = mask & (segment_ids[:, :, None]
                       == segment_ids[:, None, :])[:, None]
        logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqm,bmhd->bqhd", probs, v)
    else:
        out = _attention_core(q, k, v, cfg)
    return jnp.einsum("...hd,hdD->...D", out, params["attn_wo"].astype(x.dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    a = cfg.attention
    length = min(max_len, a.sliding_window) if a.sliding_window else max_len
    shape = (batch, length, a.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_attention(params, x: Array, cache: dict, cfg: ModelConfig, *,
                     mrope_positions: Optional[Array] = None) -> Tuple[Array, dict]:
    """Single-token decode step. x: (batch, 1, d_model); cache holds the
    (optionally ring-buffered, for sliding-window) key/value history."""
    a = cfg.attention
    b = x.shape[0]
    idx = cache["index"]
    positions = jnp.full((b, 1), idx, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rotate(q, k, positions, cfg, mrope_positions)

    cache_len = cache["k"].shape[1]
    if a.sliding_window is not None and cache_len == a.sliding_window:
        slot = jnp.mod(idx, cache_len)  # ring buffer
    else:
        slot = jnp.minimum(idx, cache_len - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot.astype(jnp.int32), 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot.astype(jnp.int32), 0, 0))

    kk, vv = _repeat_kv(ck, cv, a.num_heads)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bmhd->bhqm", q * scale, kk.astype(q.dtype))
    valid = jnp.arange(cache_len) <= jnp.minimum(idx, cache_len - 1)
    logits = jnp.where(valid[None, None, None, :], logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqm,bmhd->bqhd", probs, vv.astype(x.dtype))
    y = jnp.einsum("...hd,hdD->...D", out, params["attn_wo"].astype(x.dtype))
    new_cache = {"k": ck, "v": cv, "index": idx + 1}
    return y, new_cache


def cross_attention(params, x: Array, memory: Array, cfg: ModelConfig) -> Array:
    """Encoder-decoder cross attention. memory: (batch, src, d_model)."""
    a = cfg.attention
    dt = x.dtype
    q = jnp.einsum("...d,dhk->...hk", x, params["cross_attn_wq"].astype(dt))
    k = jnp.einsum("...d,dmk->...mk", memory, params["cross_attn_wk"].astype(dt))
    v = jnp.einsum("...d,dmk->...mk", memory, params["cross_attn_wv"].astype(dt))
    k, v = _repeat_kv(k, v, a.num_heads)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bmhd->bhqm", q * scale, k)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
    out = jnp.einsum("bhqm,bmhd->bqhd", probs, v)
    return jnp.einsum("...hd,hdD->...D", out, params["cross_attn_wo"].astype(dt))


def encoder_attention(params, x: Array, cfg: ModelConfig) -> Array:
    """Bidirectional (non-causal) self attention for encoder stacks."""
    a = cfg.attention
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rotate(q, k, positions, cfg)
    k, v = _repeat_kv(k, v, a.num_heads)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bmhd->bhqm", q * scale, k)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqm,bmhd->bqhd", probs, v)
    return jnp.einsum("...hd,hdD->...D", out, params["attn_wo"].astype(x.dtype))
