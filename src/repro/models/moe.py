"""Mixture-of-experts FFN with top-k routing and capacity-based dispatch.

Dispatch uses the Switch-Transformer grouped one-hot formulation: tokens are
grouped by batch row (the dimension sharded over the ``data`` mesh axis), so
the (group, token, expert, capacity) dispatch/combine tensors stay local to a
shard and their memory is bounded by ``tokens_per_group * E * capacity``.
Under expert parallelism the expert einsums lower to all-to-alls on the
``model`` axis; under tensor parallelism they stay local with sharded F.

Expert weights are stored stacked:

    moe_router  : (d_model, E)
    moe_exp_wi  : (E, d_model, F)
    moe_exp_wg  : (E, d_model, F)   (swiglu gate)
    moe_exp_wo  : (E, F, d_model)

so the FedAdamW partitioner can block them per (expert, output-neuron-group).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import _dense_init, init_mlp, apply_mlp

Array = jax.Array


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "moe_router": _dense_init(ks[0], (d, m.num_experts), scale=0.02),
        "moe_exp_wi": _dense_init(ks[1], (m.num_experts, d, f), scale=d ** -0.5),
        "moe_exp_wg": _dense_init(ks[2], (m.num_experts, d, f), scale=d ** -0.5),
        "moe_exp_wo": _dense_init(ks[3], (m.num_experts, f, d), scale=f ** -0.5),
    }
    if m.num_shared_experts > 0:
        shared = init_mlp(ks[4], cfg, d_ff=f * m.num_shared_experts)
        p.update({"moe_shared_" + k.split("mlp_")[1]: v for k, v in shared.items()})
    return p


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    cap = int(m.capacity_factor * tokens_per_group * m.top_k / m.num_experts)
    cap = max(cap, m.top_k)
    return min(cap, tokens_per_group)


def apply_moe(params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x: (batch, seq, d) — batch is the sharded dimension.

    Tokens are regrouped into routing groups of ≤ ``tokens_per_group`` so the
    dispatch/combine tensors stay O(group · E · capacity) regardless of the
    global token count. Returns (output, aux_load_balance_loss).
    """
    m = cfg.moe
    b, s, d = x.shape
    total = b * s
    t = min(m.tokens_per_group, total)
    # pad token count up to a multiple of the group size
    g = -(-total // t)
    pad = g * t - total
    xt = x.reshape(total, d)
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], axis=0)
    xg = xt.reshape(g, t, d)
    out, aux = _apply_moe_grouped(params, xg, cfg)
    out = out.reshape(g * t, d)
    if pad:
        out = out[:total]
    return out.reshape(b, s, d), aux


def _apply_moe_grouped(params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    m = cfg.moe
    g, t, d = x.shape  # routing groups, tokens per group, model dim
    capacity = moe_capacity(cfg, t)

    probs = jax.nn.softmax(
        jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                   params["moe_router"].astype(jnp.float32)), axis=-1)  # (g,t,E)

    top_p, top_e = jax.lax.top_k(probs, m.top_k)                        # (g,t,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # (g, t, k, E) one-hot assignment
    assign = jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32)
    # queue position of each (token, slot) within its expert, per group
    flat = assign.reshape(g, t * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) - 1.0
    pos = pos.reshape(g, t, m.top_k, m.num_experts)
    keep = (pos < capacity).astype(jnp.float32) * assign                # (g,t,k,E)

    # dispatch (g,t,E,C) and combine (g,t,E,C)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                            dtype=jnp.float32) * keep[..., None]        # (g,t,k,E,C)
    dispatch = pos_oh.sum(axis=2)
    combine = (pos_oh * top_p[..., None, None]).sum(axis=2)

    dt = x.dtype
    exp_in = jnp.einsum("gtd,gtec->gecd", x.astype(jnp.float32),
                        dispatch).astype(dt)                            # (g,E,C,d)
    h = jnp.einsum("gecd,edf->gecf", exp_in, params["moe_exp_wi"].astype(dt))
    gate = jnp.einsum("gecd,edf->gecf", exp_in, params["moe_exp_wg"].astype(dt))
    h = jax.nn.silu(gate) * h
    exp_out = jnp.einsum("gecf,efd->gecd", h, params["moe_exp_wo"].astype(dt))
    out = jnp.einsum("gecd,gtec->gtd", exp_out.astype(jnp.float32),
                     combine).astype(dt)

    if m.num_shared_experts > 0:
        f = m.d_ff_expert or cfg.d_ff
        shared_params = {("mlp_" + k.split("moe_shared_")[1]): v
                         for k, v in params.items() if k.startswith("moe_shared_")}
        out = out + apply_mlp(shared_params, x, cfg).astype(dt)

    # Switch-style load-balance loss: E * sum_e fraction_e * mean_prob_e
    frac = assign.sum(axis=2).mean(axis=(0, 1))   # (E,) fraction routed per expert
    mean_prob = probs.mean(axis=(0, 1))
    aux = m.num_experts * jnp.sum(frac * mean_prob) * m.aux_loss_weight
    return out, aux
