"""Primitive layers: norms, rotary embeddings (RoPE / M-RoPE), MLPs, embeddings.

Everything is functional: ``init_*`` builds a params sub-tree (dict of
jnp arrays), ``apply`` consumes it. Param-tree key names are load-bearing:
the FedAdamW Hessian-block partitioner (repro.core.partition) pattern-matches
on them (query/key/value/proj/mlp/embed...), mirroring paper Appendix D.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Array = jax.Array


def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[0])
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int):
    """Returns norm params ({} for OLMo's non-parametric LN)."""
    if cfg.norm_type == "nonparam_ln":
        return {}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((dim,)), "bias": jnp.zeros((dim,))}
    return {"scale": jnp.ones((dim,))}  # rmsnorm


def apply_norm(params, x: Array, cfg: ModelConfig, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
        x32 = x32 * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        x32 = (x32 - mean) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            x32 = x32 * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        # nonparam_ln (OLMo): no affine parameters
    return x32.astype(dt)


def rms_norm_simple(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """Per-head qk-norm (Qwen3) / SSM-internal norm helper."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    angles = angles[..., :, None, :]                          # (..., seq, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_thw: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE.

    positions_thw: (..., seq, 3) temporal/height/width position ids. The
    rotary half-dim is split into ``sections`` (t, h, w); each section rotates
    with its own position stream. For pure-text tokens all three ids are
    equal, reducing exactly to standard RoPE.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(head_dim, theta)                 # (half,)
    # build per-frequency position stream by section
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                        # (half,)
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),                    # (..., seq, 3)
        jnp.broadcast_to(sec_ids, positions_thw.shape[:-1] + (half,)).astype(jnp.int32) ,
        axis=-1,
    )                                                         # (..., seq, half)
    angles = pos * freqs                                      # (..., seq, half)
    angles = angles[..., :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "mlp_wi": _dense_init(ks[0], (d, f)),
            "mlp_wg": _dense_init(ks[1], (d, f)),
            "mlp_wo": _dense_init(ks[2], (f, d)),
        }
    return {
        "mlp_wi": _dense_init(ks[0], (d, f)),
        "mlp_wo": _dense_init(ks[2], (f, d)),
    }


def apply_mlp(params, x: Array, cfg: ModelConfig) -> Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["mlp_wi"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["mlp_wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["mlp_wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


def init_embeddings(key, cfg: ModelConfig):
    pv = padded_vocab(cfg.vocab_size)
    ks = jax.random.split(key, 2)
    params = {"embed_tokens": _dense_init(ks[0], (pv, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        params["output_head"] = _dense_init(ks[1], (cfg.d_model, pv))
    return params


def embed_tokens(params, tokens: Array, cfg: ModelConfig, dtype) -> Array:
    return params["embed_tokens"].astype(dtype)[tokens]


def lm_logits(params, x: Array, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        w = params["embed_tokens"].astype(x.dtype).T
    else:
        w = params["output_head"].astype(x.dtype)
    logits = jnp.einsum("...d,dv->...v", x, w)
    pv = padded_vocab(cfg.vocab_size)
    if pv != cfg.vocab_size:
        # mask padded vocab entries so they never win / receive probability
        mask = jnp.arange(pv) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def init_frontend_projector(key, cfg: ModelConfig):
    """Stub modality frontend: linear projector from precomputed patch/frame
    embeddings (vlm/audio carve-out per the spec)."""
    return {"frontend_proj": _dense_init(key, (cfg.frontend_embed_dim, cfg.d_model))}


def apply_frontend_projector(params, feats: Array, dtype) -> Array:
    return jnp.einsum("...e,ed->...d", feats.astype(dtype),
                      params["frontend_proj"].astype(dtype))
