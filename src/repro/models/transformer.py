"""Block assembly and stacks: decoder-only (dense/moe/ssm/vlm), hybrid
(Zamba2: Mamba2 backbone + shared attention block), encoder-decoder (audio).

Homogeneous stacks store per-layer params stacked along a leading ``L`` axis
and run under ``lax.scan`` (or a python unroll when ``scan_layers=False`` —
the dry-run uses the unroll for accurate ``cost_analysis`` trip counts).
The hybrid stack is heterogenous and always unrolls; its shared attention
block has a single (unstacked) param set reused every ``hybrid_attn_every``
layers, matching Zamba2's weight sharing.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, init_mlp, apply_mlp, init_norm

Array = jax.Array


# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {}
    p.update(attn_mod.init_attention(ks[0], cfg))
    p["norm_attn"] = init_norm(cfg, cfg.d_model)
    if cross:
        p.update(attn_mod.init_attention(ks[2], cfg, cross=True))
        p["norm_cross"] = init_norm(cfg, cfg.d_model)
    if cfg.family == "moe":
        p.update(moe_mod.init_moe(ks[1], cfg))
    else:
        p.update(init_mlp(ks[1], cfg))
    p["norm_mlp"] = init_norm(cfg, cfg.d_model)
    return p


def init_ssm_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    p = ssm_mod.init_ssm(key, cfg)
    p["norm_ssm"] = init_norm(cfg, cfg.d_model)
    return p


def apply_attn_block(params, x: Array, cfg: ModelConfig, *,
                     causal: bool = True,
                     memory: Optional[Array] = None,
                     positions: Optional[Array] = None,
                     mrope_positions: Optional[Array] = None
                     ) -> Tuple[Array, Array]:
    """Full-sequence attention block. Returns (x, moe_aux_loss)."""
    h = apply_norm(params["norm_attn"], x, cfg)
    if causal:
        h = attn_mod.causal_attention(params, h, cfg, positions=positions,
                                      mrope_positions=mrope_positions)
    else:
        h = attn_mod.encoder_attention(params, h, cfg)
    x = x + h
    if memory is not None:
        h = apply_norm(params["norm_cross"], x, cfg)
        x = x + attn_mod.cross_attention(params, h, memory, cfg)
    h = apply_norm(params["norm_mlp"], x, cfg)
    if cfg.family == "moe":
        h, aux = moe_mod.apply_moe(params, h, cfg)
    else:
        h, aux = apply_mlp(params, h, cfg), jnp.zeros((), jnp.float32)
    return x + h, aux


def apply_ssm_block(params, x: Array, cfg: ModelConfig) -> Array:
    h = apply_norm(params["norm_ssm"], x, cfg)
    return x + ssm_mod.apply_ssm(params, h, cfg)


def decode_attn_block(params, x: Array, cache, cfg: ModelConfig, *,
                      memory: Optional[Array] = None,
                      mrope_positions=None):
    h = apply_norm(params["norm_attn"], x, cfg)
    h, cache = attn_mod.decode_attention(params, h, cache, cfg,
                                         mrope_positions=mrope_positions)
    x = x + h
    if memory is not None:
        h = apply_norm(params["norm_cross"], x, cfg)
        x = x + attn_mod.cross_attention(params, h, memory, cfg)
    h = apply_norm(params["norm_mlp"], x, cfg)
    if cfg.family == "moe":
        h, _ = moe_mod.apply_moe(params, h, cfg)
    else:
        h = apply_mlp(params, h, cfg)
    return x + h, cache


def decode_ssm_block(params, x: Array, cache, cfg: ModelConfig):
    h = apply_norm(params["norm_ssm"], x, cfg)
    h, cache = ssm_mod.decode_ssm(params, h, cache, cfg)
    return x + h, cache


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _stacked_init(key, n: int, init_fn) -> Dict[str, Any]:
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_stack(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Parameters for the decoder stack (+ encoder for audio)."""
    ks = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        kinds = cfg.layer_kinds()
        lkeys = jax.random.split(ks[0], len(kinds))
        layers = {}
        for i, kind in enumerate(kinds):
            if kind == "ssm":
                layers[f"layer_{i:03d}"] = init_ssm_block(lkeys[i], cfg)
            elif not cfg.hybrid_shared_attn:
                layers[f"layer_{i:03d}"] = init_attn_block(lkeys[i], cfg)
        params["layers"] = layers
        if cfg.hybrid_shared_attn:
            params["shared_attn"] = init_attn_block(ks[1], cfg)
    elif cfg.family == "ssm":
        params["layers"] = _stacked_init(
            ks[0], cfg.num_layers, lambda k: init_ssm_block(k, cfg))
    elif cfg.family == "audio":
        params["encoder"] = _stacked_init(
            ks[1], cfg.encoder_layers, lambda k: init_attn_block(k, cfg))
        params["layers"] = _stacked_init(
            ks[0], cfg.num_layers, lambda k: init_attn_block(k, cfg, cross=True))
    else:  # dense / moe / vlm
        params["layers"] = _stacked_init(
            ks[0], cfg.num_layers, lambda k: init_attn_block(k, cfg))
    params["norm_final"] = init_norm(cfg, cfg.d_model)
    return params


def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _constrain(x: Array, act_pspec) -> Array:
    """Re-anchor activation sharding (batch, seq, d). GSPMD propagation can
    drop the batch sharding deep inside scanned layers under the FSDP
    (client_sequential) layout — MaxText-style explicit constraints at the
    block boundaries keep it (EXPERIMENTS.md §Dry-run memory iteration)."""
    if act_pspec is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, act_pspec)


def apply_stack(params, x: Array, cfg: ModelConfig, *,
                memory: Optional[Array] = None,
                positions: Optional[Array] = None,
                mrope_positions=None,
                scan_layers: bool = True,
                remat: str = "none",
                act_pspec=None) -> Tuple[Array, Array]:
    """Run the decoder stack over a full sequence. Returns (x, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    x = _constrain(x, act_pspec)

    if cfg.family == "hybrid":
        kinds = cfg.layer_kinds()
        for i, kind in enumerate(kinds):
            if kind == "ssm":
                blk = _maybe_remat(
                    lambda p, h: apply_ssm_block(p, h, cfg), remat)
                x = blk(params["layers"][f"layer_{i:03d}"], x)
            else:
                p_attn = (params["shared_attn"] if cfg.hybrid_shared_attn
                          else params["layers"][f"layer_{i:03d}"])
                blk = _maybe_remat(
                    lambda p, h: apply_attn_block(p, h, cfg,
                                                  positions=positions)[0], remat)
                x = blk(p_attn, x)
            x = _constrain(x, act_pspec)
        x = apply_norm(params["norm_final"], x, cfg)
        return x, aux_total

    if cfg.family == "audio":
        # encoder (bidirectional)
        def enc_body(h, layer_params):
            h2, _ = apply_attn_block(layer_params, h, cfg, causal=False)
            return h2, None
        enc_in = memory  # projected frame embeddings
        if scan_layers:
            enc_out, _ = jax.lax.scan(
                _maybe_remat(enc_body, remat), enc_in, params["encoder"])
        else:
            enc_out = enc_in
            for i in range(cfg.encoder_layers):
                layer = jax.tree.map(lambda a: a[i], params["encoder"])
                enc_out, _ = enc_body(enc_out, layer)
        memory = enc_out

    def body(carry, layer_params):
        h, aux = carry
        h2, a = apply_attn_block(layer_params, h, cfg, memory=memory,
                                 positions=positions,
                                 mrope_positions=mrope_positions)
        return (_constrain(h2, act_pspec), aux + a), None

    if cfg.family == "ssm":
        def body(carry, layer_params):  # noqa: F811
            h, aux = carry
            h2 = _constrain(apply_ssm_block(layer_params, h, cfg), act_pspec)
            return (h2, aux), None

    if scan_layers:
        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(body, remat), (x, aux_total), params["layers"])
    else:
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux_total), _ = _maybe_remat(body, remat)((x, aux_total), layer)

    x = apply_norm(params["norm_final"], x, cfg)
    return x, aux_total


# ---------------------------------------------------------------------------
# Decode stacks (single-token step against per-layer caches)
# ---------------------------------------------------------------------------

def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    def attn_cache(_):
        return attn_mod.init_kv_cache(cfg, batch, max_len, dtype)

    def ssm_cache(_):
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)

    if cfg.family == "hybrid":
        caches = {}
        for i, kind in enumerate(cfg.layer_kinds()):
            caches[f"layer_{i:03d}"] = (ssm_cache(None) if kind == "ssm"
                                        else attn_cache(None))
        return caches
    if cfg.family == "ssm":
        return jax.vmap(lambda i: ssm_cache(None))(jnp.arange(cfg.num_layers))
    return jax.vmap(lambda i: attn_cache(None))(jnp.arange(cfg.num_layers))


def decode_stack(params, x: Array, caches, cfg: ModelConfig, *,
                 memory: Optional[Array] = None,
                 scan_layers: bool = True,
                 mrope_positions=None) -> Tuple[Array, Any]:
    if cfg.family == "hybrid":
        new_caches = {}
        for i, kind in enumerate(cfg.layer_kinds()):
            name = f"layer_{i:03d}"
            if kind == "ssm":
                x, new_caches[name] = decode_ssm_block(
                    params["layers"][name], x, caches[name], cfg)
            else:
                p_attn = (params["shared_attn"] if cfg.hybrid_shared_attn
                          else params["layers"][name])
                x, new_caches[name] = decode_attn_block(
                    p_attn, x, caches[name], cfg)
        x = apply_norm(params["norm_final"], x, cfg)
        return x, new_caches

    if cfg.family == "ssm":
        def body(h, inp):
            layer_params, cache = inp
            h2, c2 = decode_ssm_block(layer_params, h, cache, cfg)
            return h2, c2
    else:
        def body(h, inp):
            layer_params, cache = inp
            h2, c2 = decode_attn_block(layer_params, h, cache, cfg,
                                       memory=memory,
                                       mrope_positions=mrope_positions)
            return h2, c2

    if scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        outs = []
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], params["layers"])
            cache = jax.tree.map(lambda a: a[i], caches)
            x, c2 = body(x, (layer, cache))
            outs.append(c2)
        new_caches = jax.tree.map(lambda *cs: jnp.stack(cs), *outs)
    x = apply_norm(params["norm_final"], x, cfg)
    return x, new_caches
