"""Level-2 static analysis: jaxpr/HLO audit of the traced round program.

Where the AST lint (``repro.analysis.lint``) reads source, this module
reads the PROGRAM: it traces ``make_round_fn`` / ``make_multi_round_fn``
abstractly (zero FLOPs — ``repro.core.rounds.trace_round_jaxpr``) under
a matrix of representative :class:`FedConfig` s and checks four
invariants every subsystem PR so far proved by hand:

``RA201`` **gate-parity** — a feature at its disabled value must trace
          the *byte-identical* program to the feature-free engine
          (static gating, the repo-wide bit-exactness contract). The
          pretty-printed jaxpr is deterministic, so string equality is
          the check: milliseconds of IR diff where trajectory parity
          costs minutes. A live host-telemetry session is one of the
          gates: tracing inside ``telemetry.session()`` must emit the
          same program.
``RA202`` **dtype audit** — no f64/c128 equation output anywhere in the
          program (the fresh-f32-zeros accumulator bug class: an
          accidental Python-float promotion upcasts a whole chain).
``RA203`` **host callbacks in scanned bodies** — ``pure_callback`` et
          al. inside a ``scan``/``while`` body re-enter the host per
          iteration: a silent ×(K·S·M) dispatch cliff.
``RA204`` **donation aliasing** — every ``donate_argnums`` leaf of the
          engine's jit signature must be aliased in the compiled
          executable's ``input_output_alias`` header (the PR 3
          ``is_deleted`` property as an IR fact, not a runtime probe).

Sanity direction is checked too: each feature's ON program must DIFFER
from base, otherwise the parity assertions are vacuous.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.findings import Finding

LAYOUTS = ("client_parallel", "client_sequential")

#: primitive names that re-enter the host from traced code
CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                  "python_callback", "callback")
#: primitives whose body re-runs per iteration
LOOP_PRIMS = ("scan", "while")

BANNED_DTYPES = ("float64", "complex128")


# ----------------------------------------------------------- jaxpr walking

def iter_eqns(closed_jaxpr) -> Iterable[Tuple[object, bool]]:
    """Yield ``(eqn, inside_loop)`` over every equation, recursing into
    sub-jaxprs carried in eqn params (scan/while/cond bodies, pjit
    calls); ``inside_loop`` is True under any scan/while body."""
    def sub_jaxprs(params):
        vals = []
        for v in params.values():
            vals.extend(v if isinstance(v, (list, tuple)) else [v])
        for v in vals:
            sub = getattr(v, "jaxpr", v)
            if hasattr(sub, "eqns"):
                yield sub

    def rec(jaxpr, in_loop):
        for eqn in jaxpr.eqns:
            yield eqn, in_loop
            child_loop = in_loop or eqn.primitive.name in LOOP_PRIMS
            for sub in sub_jaxprs(eqn.params):
                yield from rec(sub, child_loop)

    yield from rec(closed_jaxpr.jaxpr, False)


def audit_dtypes(name: str, closed_jaxpr) -> List[Finding]:
    """RA202: flag banned-dtype equation outputs (f64 leak)."""
    out: List[Finding] = []
    seen = set()
    for eqn, _ in iter_eqns(closed_jaxpr):
        for ov in eqn.outvars:
            dtype = getattr(getattr(ov, "aval", None), "dtype", None)
            if dtype is None or str(dtype) not in BANNED_DTYPES:
                continue
            key = (eqn.primitive.name, str(dtype))
            if key in seen:
                continue
            seen.add(key)
            out.append(Finding(
                code="RA202", path=f"jaxpr:{name}", line=0,
                message=f"equation {eqn.primitive.name!r} produces "
                        f"{dtype} — the stack is f32; a silent x64 "
                        "promotion doubles bytes and breaks cross-config "
                        "bit-exactness",
                fixit="find the Python float / np.float64 scalar that "
                      "entered the trace and cast it to the leaf dtype",
                text=f"{eqn.primitive.name}->{dtype}"))
    return out


def audit_callbacks(name: str, closed_jaxpr) -> List[Finding]:
    """RA203: host callbacks inside scanned bodies."""
    out: List[Finding] = []
    seen = set()
    for eqn, in_loop in iter_eqns(closed_jaxpr):
        prim = eqn.primitive.name
        if in_loop and any(prim.startswith(c) for c in CALLBACK_PRIMS):
            if prim in seen:
                continue
            seen.add(prim)
            cb = eqn.params.get("callback", "")
            out.append(Finding(
                code="RA203", path=f"jaxpr:{name}", line=0,
                message=f"host callback {prim!r} ({cb}) inside a "
                        "scan/while body — re-enters the host every "
                        "iteration (xK local steps, xM fused rounds)",
                fixit="hoist the callback out of the loop or replace it "
                      "with an in-program accumulator drained once per "
                      "call (see telemetry.diagnostics)",
                text=f"{prim} in loop"))
    return out


# ------------------------------------------------------------ config matrix

@dataclasses.dataclass(frozen=True)
class AuditCase:
    """One traced configuration. ``parity_with`` names the case whose
    jaxpr this one must equal (RA201); ``differs_from`` names the case
    it must NOT equal (the sanity direction). ``trace_kw`` feeds
    ``trace_round_jaxpr``; ``in_telemetry_session`` traces under a live
    host session."""
    name: str
    fed: object
    parity_with: Optional[str] = None
    differs_from: Optional[str] = None
    trace_kw: Dict = dataclasses.field(default_factory=dict)
    in_telemetry_session: bool = False


def _base_fed(layout: str, **overrides):
    from repro.config import FedConfig
    kw = dict(algorithm="fedadamw", num_clients=8, clients_per_round=2,
              local_steps=2, lr=1e-3, layout=layout, sequential_clients=2)
    kw.update(overrides)
    return FedConfig(**kw)


def audit_matrix(layouts: Tuple[str, ...] = LAYOUTS) -> List[AuditCase]:
    """The representative configs: per layout, a feature-free base, every
    feature at its OFF value (must trace == base even when its inert
    knobs move), and every feature ON (must trace != base, and feeds the
    dtype/callback audits). Codec + uploadfuse cases run in both
    layouts; the rank-defense and multi-round cases are
    client_parallel-only."""
    cases: List[AuditCase] = []
    for lay in layouts:
        b = f"base[{lay}]"
        cases.append(AuditCase(b, _base_fed(lay)))
        cases.append(AuditCase(
            f"dp_off[{lay}]",
            _base_fed(lay, dp_clip=0.0, dp_noise_multiplier=0.0,
                      dp_seed=123),
            parity_with=b))
        cases.append(AuditCase(
            f"diag_off[{lay}]",
            _base_fed(lay, telemetry_diagnostics=False, scenario_seed=7),
            parity_with=b, in_telemetry_session=True))
        cases.append(AuditCase(
            f"scenario_off[{lay}]", _base_fed(lay, scenario_seed=7),
            parity_with=b, trace_kw={"with_scenario": False}))
        cases.append(AuditCase(
            f"dp_on[{lay}]",
            _base_fed(lay, dp_clip=1.0, dp_noise_multiplier=1.0),
            differs_from=b))
        cases.append(AuditCase(
            f"diag_on[{lay}]", _base_fed(lay, telemetry_diagnostics=True),
            differs_from=b))
        # per-client flight recorder (telemetry.ledger): off must be
        # byte-identical even while unrelated inert knobs move; on must
        # actually attach the (S, n_stats) block
        cases.append(AuditCase(
            f"ledger_off[{lay}]",
            _base_fed(lay, telemetry_ledger=False, scenario_seed=9),
            parity_with=b, in_telemetry_session=True))
        cases.append(AuditCase(
            f"ledger_on[{lay}]", _base_fed(lay, telemetry_ledger=True),
            differs_from=b))
        cases.append(AuditCase(
            f"scenario_on[{lay}]",
            _base_fed(lay, straggler_frac=0.5, agg_weighting="inv_steps"),
            differs_from=b, trace_kw={"with_scenario": True}))
        cases.append(AuditCase(
            f"faults_off[{lay}]", _base_fed(lay, fault_seed=123),
            parity_with=b, trace_kw={"with_faults": False}))
        # the mean defense + quorum work in BOTH layouts; the rank-based
        # aggregators are client_parallel-only (CONSTRAINTS)
        cases.append(AuditCase(
            f"faults_on[{lay}]",
            _base_fed(lay, fault_nan=0.3, robust_agg="mean",
                      min_quorum=1),
            differs_from=b, trace_kw={"with_faults": True}))
        # upload codec + the fused upload megakernel (both layouts):
        # uploadfuse at its OFF value must leave the codec program
        # byte-identical (the defer gate in comm.compress is static),
        # and ON must actually reroute the aggregation
        cases.append(AuditCase(
            f"codec_on[{lay}]",
            _base_fed(lay, algorithm="fedadamw+int8"),
            differs_from=b))
        cases.append(AuditCase(
            f"uploadfuse_off[{lay}]",
            _base_fed(lay, algorithm="fedadamw+int8",
                      use_pallas_uploadfuse=False),
            parity_with=f"codec_on[{lay}]"))
        cases.append(AuditCase(
            f"uploadfuse_on[{lay}]",
            _base_fed(lay, algorithm="fedadamw+int8",
                      use_pallas_uploadfuse=True),
            differs_from=f"codec_on[{lay}]"))
    if "client_parallel" not in layouts:
        return cases
    cases.append(AuditCase(
        "defense_on[client_parallel]",
        _base_fed("client_parallel", fault_scale=0.3,
                  robust_agg="trimmed0.25"),
        differs_from="faults_on[client_parallel]",
        trace_kw={"with_faults": True}))
    cases.append(AuditCase(
        "multi_dp_off[client_parallel]",
        _base_fed("client_parallel", dp_clip=0.0, dp_seed=123,
                  rounds_per_call=3),
        parity_with="multi_base[client_parallel]",
        trace_kw={"multi_rounds": 3}))
    cases.insert(0, AuditCase(          # referenced by the case above
        "multi_base[client_parallel]",
        _base_fed("client_parallel", rounds_per_call=3),
        trace_kw={"multi_rounds": 3}))
    return cases


def _validate_matrix(cases: List[AuditCase]) -> None:
    """Every matrix config must satisfy the declarative constraint table
    (repro.config.fed_config.CONSTRAINTS) — the auditor must not audit
    programs the config layer would reject."""
    for case in cases:
        case.fed.validate()


def tiny_model():
    """The reduced vit-tiny used for all audit traces (same one the
    roofline CI job rooflines)."""
    import jax.numpy as jnp
    from repro.config import get_arch
    from repro.config.model_config import reduced_variant
    from repro.models import build_model
    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    return build_model(cfg, compute_dtype=jnp.float32), cfg


def trace_case(model, cfg, case: AuditCase):
    """-> (ClosedJaxpr, args) for one matrix case."""
    from repro import telemetry
    from repro.core.rounds import trace_round_jaxpr
    if case.in_telemetry_session:
        with telemetry.session():
            return trace_round_jaxpr(model, case.fed, cfg=cfg,
                                     **case.trace_kw)
    return trace_round_jaxpr(model, case.fed, cfg=cfg, **case.trace_kw)


def gate_parity_findings(cases: List[AuditCase],
                         texts: Dict[str, str]) -> List[Finding]:
    """RA201 both directions: off-gates equal their baseline, on-gates
    differ from it (else the parity assertions prove nothing)."""
    out: List[Finding] = []
    for case in cases:
        if case.parity_with is not None and \
                texts[case.name] != texts[case.parity_with]:
            out.append(Finding(
                code="RA201", path=f"jaxpr:{case.name}", line=0,
                message=f"feature-off program differs from "
                        f"{case.parity_with!r} "
                        f"({len(texts[case.name])} vs "
                        f"{len(texts[case.parity_with])} chars) — the "
                        "gate leaks into the traced program",
                fixit="gate the feature statically (Python-level branch "
                      "on the config, not lax.cond/jnp.where) so the "
                      "disabled trace is byte-identical",
                text=f"{case.name} != {case.parity_with}"))
        if case.differs_from is not None and \
                texts[case.name] == texts[case.differs_from]:
            out.append(Finding(
                code="RA201", path=f"jaxpr:{case.name}", line=0,
                message=f"feature-ON program is identical to "
                        f"{case.differs_from!r} — the feature never "
                        "entered the trace; the off-gate parity checks "
                        "are vacuous",
                fixit="check the config plumbing: the flag is not "
                      "reaching make_round_fn",
                text=f"{case.name} == {case.differs_from}"))
    return out


# --------------------------------------------------------------- donation

def audit_donation(model, cfg, fed=None) -> List[Finding]:
    """RA204: compile the engine's jit signature (donate_argnums=(0, 1),
    exactly ``launch.pipeline.RoundEngine``'s) from abstract args and
    verify every donated leaf is aliased in the executable header.
    This is the one audit that pays a real XLA compile (~10 s)."""
    import jax
    from repro.core.rounds import make_round_fn, round_abstract_args
    from repro.roofline.hlo_counter import parse_input_output_alias

    fed = fed or _base_fed("client_parallel")
    args, specs, alg = round_abstract_args(model, fed, cfg=cfg)
    fn = make_round_fn(model, fed, specs, alg=alg, cosine_total_rounds=10)
    compiled = jax.jit(fn, donate_argnums=(0, 1)).lower(*args).compile()
    alias = parse_input_output_alias(compiled.as_text())
    n_donated = len(jax.tree.leaves(args[0])) + len(jax.tree.leaves(args[1]))
    missing = [i for i in range(n_donated) if i not in alias]
    if not missing:
        return []
    return [Finding(
        code="RA204", path="hlo:donation[client_parallel]", line=0,
        message=f"{len(missing)} of {n_donated} donated input buffers "
                f"(params+sstate leaves {missing[:8]}"
                f"{'...' if len(missing) > 8 else ''}) are NOT aliased "
                "in the compiled executable — donation silently degrades "
                "to a copy and peak memory doubles",
        fixit="keep donated leaves' shapes/dtypes identical between the "
              "matching input and output positions of round_fn",
        text=f"unaliased donated params {missing[:8]}")]


# ----------------------------------------------------------------- driver

def run_audit(layouts: Tuple[str, ...] = LAYOUTS, *,
              donation: bool = True) -> List[Finding]:
    """Trace the full matrix and run all four audits. ~25 traces of the
    reduced tiny model (~1 s each) plus one XLA compile when
    ``donation``; comfortably inside the 60 s CI budget."""
    model, cfg = tiny_model()
    cases = audit_matrix(layouts)
    _validate_matrix(cases)
    findings: List[Finding] = []
    texts: Dict[str, str] = {}
    for case in cases:
        closed, _ = trace_case(model, cfg, case)
        texts[case.name] = str(closed)
        findings.extend(audit_dtypes(case.name, closed))
        findings.extend(audit_callbacks(case.name, closed))
    findings.extend(gate_parity_findings(cases, texts))
    if donation:
        findings.extend(audit_donation(model, cfg))
    return findings
