"""Findings, baselines, and inline suppressions for ``repro.analysis``.

A :class:`Finding` is one violation from either analysis level (AST lint
or jaxpr audit): an error code (``RA1xx`` lint / ``RA2xx`` audit), a
location, a message, and a fix-it hint. The full rule catalog lives in
docs/analysis.md.

Two suppression mechanisms, both intentional-and-documented:

* **inline** — a ``# ra: allow[RA101] <reason>`` comment on (or directly
  above) the flagged line. Used for the handful of sanctioned sites
  (e.g. the codec key *constructor itself* builds a raw ``PRNGKey``).
  The reason is mandatory by convention and reviewed like code.
* **baseline** — ``src/repro/analysis/baseline.json``, a checked-in list
  of fingerprints for violations that predate a rule and are accepted
  for now. ``tools/analyze.py --update-baseline`` regenerates it; CI
  fails on any finding that is in neither. Fingerprints are
  ``(code, path, stripped source line)`` — stable across pure line-number
  drift, invalidated when the offending line actually changes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence

#: rule code -> process exit code for ``tools/analyze.py`` (distinct per
#: rule so CI logs and scripts can tell failure classes apart; mixed-rule
#: failures exit 1).
EXIT_CODES: Dict[str, int] = {
    "RA101": 11,   # raw PRNGKey outside a sanctioned constructor
    "RA102": 12,   # PRNG key reused by two samplers without fold_in/split
    "RA103": 13,   # reserved round-batch key as a string literal
    "RA104": 14,   # telemetry metric name not in the registry catalog
    "RA105": 15,   # wall-clock / unseeded randomness in jit-feeding code
    "RA106": 16,   # unused import
    "RA201": 21,   # gate-parity: feature-off jaxpr != feature-free jaxpr
    "RA202": 22,   # f64 leak / unexpected dtype promotion in the jaxpr
    "RA203": 23,   # host callback inside a scanned body
    "RA204": 24,   # donated buffer not aliased in the compiled executable
}

MIXED_EXIT = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: ``code`` is the RAxxx rule id; ``path`` is repo-
    relative (or a synthetic ``jaxpr:<case>`` locator for audit
    findings); ``text`` is the stripped source line / IR detail used for
    baseline fingerprinting."""
    code: str
    path: str
    line: int
    message: str
    fixit: str = ""
    text: str = ""

    def fingerprint(self) -> str:
        return f"{self.code}|{self.path}|{self.text.strip()}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: {self.code} {self.message}"
        if self.fixit:
            out += f"\n    fix: {self.fixit}"
        return out


def exit_code_for(findings: Sequence[Finding]) -> int:
    """0 when clean; the rule's distinct exit code when every finding
    shares one rule; ``MIXED_EXIT`` otherwise."""
    codes = {f.code for f in findings}
    if not codes:
        return 0
    if len(codes) == 1:
        return EXIT_CODES.get(codes.pop(), MIXED_EXIT)
    return MIXED_EXIT


# ---------------------------------------------------------------- suppression

_ALLOW_RE = re.compile(r"ra:\s*allow\[(RA\d{3})\]")


def inline_allows(source_lines: Sequence[str]) -> Dict[int, set]:
    """{1-based line -> {codes allowed}} from ``# ra: allow[RAxxx]``
    comments. An allow comment covers its own line AND the line below it
    (so long flagged expressions can carry the comment above them)."""
    allows: Dict[int, set] = {}
    for i, line in enumerate(source_lines, start=1):
        for m in _ALLOW_RE.finditer(line):
            allows.setdefault(i, set()).add(m.group(1))
            allows.setdefault(i + 1, set()).add(m.group(1))
    return allows


def is_allowed(finding: Finding, allows: Dict[int, set]) -> bool:
    return finding.code in allows.get(finding.line, ())


# ------------------------------------------------------------------ baseline

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: Optional[str] = None) -> List[dict]:
    path = path or DEFAULT_BASELINE
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        body = fh.read()
    if not body.strip():        # /dev/null or an empty file: no baseline
        return []
    return json.loads(body).get("suppressions", [])


def save_baseline(findings: Iterable[Finding],
                  path: Optional[str] = None) -> str:
    path = path or DEFAULT_BASELINE
    entries = sorted(
        ({"code": f.code, "path": f.path, "text": f.text.strip(),
          "message": f.message} for f in findings),
        key=lambda e: (e["code"], e["path"], e["text"]))
    doc = {"_comment": ("Accepted pre-existing violations; regenerate with "
                        "`python tools/analyze.py --update-baseline`. "
                        "New code must be clean — prefer an inline "
                        "`# ra: allow[RAxxx] reason` for sanctioned sites."),
           "suppressions": entries}
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def split_baselined(findings: Sequence[Finding],
                    baseline: Sequence[dict]):
    """-> (new, baselined): a finding is baselined when an entry matches
    its (code, path, stripped text)."""
    keys = {(e["code"], e["path"], e["text"]) for e in baseline}
    new, old = [], []
    for f in findings:
        (old if (f.code, f.path, f.text.strip()) in keys else new).append(f)
    return new, old
