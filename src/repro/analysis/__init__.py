"""Static analysis for the repro stack: AST lint + jaxpr program audit.

Two levels, one CLI (``tools/analyze.py``), one CI job:

* **Level 1 — AST lint** (``repro.analysis.lint``): repo-specific source
  rules RA101–RA106 (RNG fold-in discipline, reserved scenario keys,
  telemetry metric catalog, jit-feeding nondeterminism, unused imports).
  Stdlib ``ast`` only.
* **Level 2 — jaxpr audit** (``repro.analysis.jaxpr_audit``): traces the
  round program abstractly under a config matrix and checks RA201–RA204
  (gate-parity, dtype, host-callbacks-in-scan, donation aliasing).

Findings, exit codes, inline ``# ra: allow[RAxxx]`` suppressions and the
checked-in baseline live in ``repro.analysis.findings``; the rule
catalog is documented in docs/analysis.md.
"""
from repro.analysis.findings import (DEFAULT_BASELINE, EXIT_CODES,
                                     Finding, exit_code_for, load_baseline,
                                     save_baseline, split_baselined)
from repro.analysis.jaxpr_audit import (AuditCase, audit_matrix, run_audit,
                                        trace_case)
from repro.analysis.lint import LINT_RULES, lint_file, run_lint

__all__ = [
    "Finding", "EXIT_CODES", "exit_code_for", "DEFAULT_BASELINE",
    "load_baseline", "save_baseline", "split_baselined",
    "LINT_RULES", "lint_file", "run_lint",
    "AuditCase", "audit_matrix", "run_audit", "trace_case",
]
