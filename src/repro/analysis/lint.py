"""Level-1 static analysis: repo-specific AST lint (stdlib ``ast`` only).

Every rule guards an invariant a past PR had to prove (or fix) by hand —
see docs/analysis.md for the catalog with examples. Codes:

``RA101``  raw ``jax.random.PRNGKey``/``jax.random.key`` in jit-feeding
           modules outside a sanctioned constructor. Per-round /
           per-client keys must be derived via ``fold_in`` from a seeded
           root (the PR 2 ``_encode_key`` client-id-miss bug class);
           a key built immediately inside ``jax.random.fold_in(...)`` is
           fine, anything else needs an inline ``# ra: allow[RA101]``.
``RA102``  PRNG key reuse: one key variable consumed by two or more
           sampling calls without an intervening ``fold_in``/``split`` —
           the draws would be correlated.
``RA103``  reserved round-batch keys (``_step_mask``/``_agg_weights``)
           spelled as string literals anywhere but their defining module
           — use ``repro.scenario.STEP_MASK_KEY``/``AGG_WEIGHTS_KEY``.
``RA104``  telemetry counter/gauge name literal not in the
           ``repro.telemetry.registry.CANONICAL_METRICS`` catalog (a
           typo'd name silently splits the accumulator).
``RA105``  wall-clock / unseeded randomness (``time.time``,
           ``np.random.*`` global-state calls, stdlib ``random``) inside
           modules that feed jitted code — nondeterminism there breaks
           the bit-exactness contracts every subsystem asserts.
``RA106``  unused import (dead ``upload_bytes``-era aliases rot here).

Suppressions: ``# ra: allow[RAxxx] reason`` inline (sanctioned sites) or
the checked-in baseline (``repro.analysis.findings``).
"""
from __future__ import annotations

import ast
import dataclasses
import difflib
import os
from typing import Callable, Dict, List, Optional, Sequence

from repro.analysis.findings import Finding, inline_allows, is_allowed

# Packages whose code is traced into (or stages data for) the jitted
# round program: nondeterminism or ad-hoc keys here break bit-exactness.
JIT_FEEDING = (
    "src/repro/core/", "src/repro/comm/", "src/repro/privacy/",
    "src/repro/state/", "src/repro/kernels/", "src/repro/scenario/",
    "src/repro/models/", "src/repro/lora/", "src/repro/data/",
    "src/repro/faults/",
)

RESERVED_BATCH_KEYS = ("_step_mask", "_agg_weights",  # ra: allow[RA103] the rule's own pattern table
                       "_fault_drop", "_fault_mult")  # ra: allow[RA103] the rule's own pattern table
RESERVED_DEFINING_MODULES = ("src/repro/scenario/__init__.py",
                             "src/repro/faults/__init__.py")

# jax.random functions that CONSUME a key (fresh draws); fold_in/split/
# clone DERIVE new keys and are the sanctioned way to reuse one.
_KEY_CONSUMERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "truncated_normal",
    "permutation", "choice", "gamma", "beta", "categorical", "bits",
    "exponential", "laplace", "gumbel", "rademacher", "ball", "dirichlet",
    "poisson", "shuffle", "t", "cauchy", "logistic", "rayleigh",
})
_KEY_DERIVERS = frozenset({"fold_in", "split", "clone"})
_KEY_MAKERS = frozenset({"PRNGKey", "key"})

# np.random attributes that are fine: explicitly seeded generator
# construction, not draws from the global state.
_NP_RANDOM_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                           "PCG64", "Philox", "BitGenerator", "RandomState"})

_WALLCLOCK = frozenset({"time.time", "time.time_ns", "datetime.now",
                        "datetime.datetime.now", "datetime.datetime.today"})


# --------------------------------------------------------------- file context

@dataclasses.dataclass
class FileContext:
    path: str                       # repo-relative, "/"-separated
    tree: ast.AST
    lines: Sequence[str]
    aliases: Dict[str, str]         # local name -> dotted module


def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to the dotted module they reference, so attribute
    chains resolve regardless of import spelling (``import jax.random as
    jr`` / ``from jax import random`` / ``import numpy as np``)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Resolve an attribute chain to a dotted name through the alias map
    (``jr.fold_in`` -> ``jax.random.fold_in``); None for non-chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def _call_name(call: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return _dotted(call.func, aliases)


def make_context(path: str, source: str, repo_rel: str) -> FileContext:
    tree = ast.parse(source, filename=path)
    return FileContext(path=repo_rel, tree=tree,
                       lines=source.splitlines(),
                       aliases=_collect_aliases(tree))


# --------------------------------------------------------------------- rules

@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    applies: Callable[[str], bool]      # repo-relative path predicate
    check: Callable[[FileContext], List[Finding]]
    summary: str


def _finding(ctx: FileContext, code: str, node: ast.AST, message: str,
             fixit: str = "") -> Finding:
    line = getattr(node, "lineno", 0)
    text = ctx.lines[line - 1] if 0 < line <= len(ctx.lines) else ""
    return Finding(code=code, path=ctx.path, line=line, message=message,
                   fixit=fixit, text=text)


def _is_key_maker(name: Optional[str]) -> bool:
    return name in {f"jax.random.{m}" for m in _KEY_MAKERS}


def check_raw_prngkey(ctx: FileContext) -> List[Finding]:
    """RA101: flag PRNGKey/key construction not immediately folded."""
    sanctioned = set()
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node, ctx.aliases)
        if name == "jax.random.fold_in":
            for arg in node.args:
                if isinstance(arg, ast.Call) and _is_key_maker(
                        _call_name(arg, ctx.aliases)):
                    sanctioned.add(id(arg))
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and id(node) not in sanctioned \
                and _is_key_maker(_call_name(node, ctx.aliases)):
            out.append(_finding(
                ctx, "RA101", node,
                "raw PRNG key construction in a jit-feeding module; "
                "per-round/per-client keys must derive from a seeded "
                "root via jax.random.fold_in",
                "wrap as jax.random.fold_in(jax.random.PRNGKey(seed), "
                "round_or_client_index), or mark the sanctioned "
                "constructor with `# ra: allow[RA101] reason`"))
    return out


def _scopes(tree: ast.AST):
    """Yield (scope_node, direct statements) per function/module scope —
    nested defs start their own scope and are excluded from the parent's."""
    fns = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

    def direct_nodes(scope):
        todo = list(ast.iter_child_nodes(scope))
        while todo:
            n = todo.pop()
            yield n
            if not isinstance(n, fns):
                todo.extend(ast.iter_child_nodes(n))

    for node in ast.walk(tree):
        if isinstance(node, fns) or isinstance(node, ast.Module):
            yield node, list(direct_nodes(node))


def check_key_reuse(ctx: FileContext) -> List[Finding]:
    """RA102: a key variable assigned once and consumed by >= 2 sampling
    calls draws correlated randomness."""
    out: List[Finding] = []
    consumer_names = {f"jax.random.{c}" for c in _KEY_CONSUMERS}
    for _scope, nodes in _scopes(ctx.tree):
        assigns: Dict[str, int] = {}
        consumed: Dict[str, List[ast.Call]] = {}
        for n in nodes:
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
                vname = _call_name(n.value, ctx.aliases)
                if vname and vname.startswith("jax.random.") and \
                        vname.rsplit(".", 1)[-1] in (_KEY_MAKERS
                                                     | _KEY_DERIVERS):
                    for tgt in n.targets:
                        if isinstance(tgt, ast.Name):
                            assigns[tgt.id] = assigns.get(tgt.id, 0) + 1
            if isinstance(n, ast.Call):
                cname = _call_name(n, ctx.aliases)
                if cname in consumer_names:
                    for arg in list(n.args) + [kw.value for kw in
                                               n.keywords]:
                        if isinstance(arg, ast.Name):
                            consumed.setdefault(arg.id, []).append(n)
        for var, sites in consumed.items():
            if assigns.get(var, 0) == 1 and len(sites) >= 2:
                second = sorted(sites, key=lambda c: c.lineno)[1]
                out.append(_finding(
                    ctx, "RA102", second,
                    f"PRNG key {var!r} is consumed by "
                    f"{len(sites)} sampling calls — the draws are "
                    "correlated, not independent",
                    f"derive a fresh key per draw: jax.random.fold_in"
                    f"({var}, i) or jax.random.split({var})"))
    return out


def check_reserved_keys(ctx: FileContext) -> List[Finding]:
    """RA103: reserved scenario batch keys only via the named constants."""
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Constant) and node.value in \
                RESERVED_BATCH_KEYS:
            out.append(_finding(
                ctx, "RA103", node,
                f"reserved round-batch key {node.value!r} spelled as a "
                "literal; the engine pops these by the constants' "
                "identity and a drifted spelling silently ships the key "
                "into the model batch",
                "import STEP_MASK_KEY / AGG_WEIGHTS_KEY from "
                "repro.scenario (or FAULT_DROP_KEY / FAULT_MULT_KEY "
                "from repro.faults)"))
    return out


def check_metric_names(ctx: FileContext) -> List[Finding]:
    """RA104: telemetry metric name literals must be cataloged."""
    from repro.telemetry.registry import CANONICAL_METRICS
    out: List[Finding] = []
    accessors = {"add", "set_gauge", "counter", "gauge", "value"}
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in accessors and node.args):
            continue
        base = _dotted(node.func.value, ctx.aliases)
        if base is None or not base.endswith("telemetry"):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                and arg.value not in CANONICAL_METRICS:
            close = difflib.get_close_matches(arg.value,
                                              CANONICAL_METRICS, n=1)
            hint = (f"did you mean {close[0]!r}?" if close else
                    "add it to CANONICAL_METRICS in "
                    "repro/telemetry/registry.py (and the "
                    "docs/observability.md catalog)")
            out.append(_finding(
                ctx, "RA104", node,
                f"telemetry metric name {arg.value!r} is not in the "
                "registry catalog — a typo here silently splits the "
                "accumulator", hint))
    return out


def check_nondeterminism(ctx: FileContext) -> List[Finding]:
    """RA105: wall-clock / global-state randomness in jit-feeding code."""
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node, ctx.aliases)
        if name is None:
            continue
        bad = None
        if name in _WALLCLOCK or name.startswith("time.perf_counter"):
            bad = ("wall-clock read", "hoist timing to the host driver "
                   "(repro.telemetry spans) — traced code must be a pure "
                   "function of its inputs")
        elif name.startswith("numpy.random.") and \
                name.split(".")[2] not in _NP_RANDOM_OK:
            bad = ("numpy global-state randomness",
                   "use a seeded np.random.default_rng(...) generator "
                   "threaded from the caller")
        elif name.split(".")[0] == "random" and \
                ctx.aliases.get("random", "") == "random":
            bad = ("stdlib random (process-global state)",
                   "use a seeded np.random.default_rng(...) generator")
        if bad:
            out.append(_finding(
                ctx, "RA105", node,
                f"{bad[0]} ({name}) inside a module that feeds jitted "
                "code — breaks the bit-exactness contracts", bad[1]))
    return out


def check_unused_imports(ctx: FileContext) -> List[Finding]:
    """RA106: imports never referenced in the module."""
    if ctx.path.endswith("__init__.py"):
        return []   # re-export surface: presence IS the use
    bindings: List = []   # (local name, node, display)
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = a.asname or a.name.split(".")[0]
                bindings.append((local, node, a.name))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                local = a.asname or a.name
                bindings.append((local, node,
                                 f"{node.module or '.'}.{a.name}"))
    used = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            used.add(node.value)    # __all__ entries & string annotations
        elif isinstance(node, ast.Attribute):
            pass                    # roots arrive as Name nodes
    out = []
    for local, node, display in bindings:
        if local not in used:
            out.append(_finding(
                ctx, "RA106", node,
                f"{display!r} imported as {local!r} but never used",
                "delete the import (or export it via __all__)"))
    return out


def _in(*prefixes: str) -> Callable[[str], bool]:
    return lambda p: p.startswith(prefixes)


LINT_RULES: List[Rule] = [
    Rule("RA101", "raw-prng-key", _in(*JIT_FEEDING), check_raw_prngkey,
         "raw PRNGKey outside sanctioned constructors"),
    Rule("RA102", "prng-key-reuse", lambda p: True, check_key_reuse,
         "PRNG key consumed twice without fold_in/split"),
    Rule("RA103", "reserved-batch-keys",
         lambda p: p not in RESERVED_DEFINING_MODULES,
         check_reserved_keys,
         "reserved round-batch keys via named constants only"),
    Rule("RA104", "metric-name-catalog",
         _in("src/", "benchmarks/", "tools/"), check_metric_names,
         "telemetry metric literals must be cataloged"),
    Rule("RA105", "jit-nondeterminism", _in(*JIT_FEEDING),
         check_nondeterminism,
         "no wall-clock/global randomness in jit-feeding modules"),
    Rule("RA106", "unused-import",
         _in("src/", "tests/", "benchmarks/", "tools/"),
         check_unused_imports, "no unused imports"),
]


# -------------------------------------------------------------------- driver

DEFAULT_LINT_DIRS = ("src", "tests", "benchmarks", "tools")


def iter_py_files(root: str, dirs: Sequence[str] = DEFAULT_LINT_DIRS):
    for d in dirs:
        base = os.path.join(root, d)
        if os.path.isfile(base) and base.endswith(".py"):
            yield base
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_file(path: str, repo_root: str,
              rules: Sequence[Rule] = ()) -> List[Finding]:
    rules = rules or LINT_RULES
    with open(path) as fh:
        source = fh.read()
    rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
    try:
        ctx = make_context(path, source, rel)
    except SyntaxError as e:
        return [Finding(code="RA100", path=rel, line=e.lineno or 0,
                        message=f"file does not parse: {e.msg}",
                        text="")]
    allows = inline_allows(ctx.lines)
    findings: List[Finding] = []
    for rule in rules:
        if not rule.applies(rel):
            continue
        findings.extend(f for f in rule.check(ctx)
                        if not is_allowed(f, allows))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def lint_source(source: str, repo_rel: str,
                rules: Sequence[Rule] = ()) -> List[Finding]:
    """Lint a source string as if it lived at ``repo_rel`` (tests and
    fixture snippets; the path controls which rules apply)."""
    rules = rules or LINT_RULES
    ctx = make_context(repo_rel, source, repo_rel)
    allows = inline_allows(ctx.lines)
    findings: List[Finding] = []
    for rule in rules:
        if rule.applies(repo_rel):
            findings.extend(f for f in rule.check(ctx)
                            if not is_allowed(f, allows))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def run_lint(repo_root: str, dirs: Sequence[str] = DEFAULT_LINT_DIRS,
             rules: Sequence[Rule] = ()) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(repo_root, dirs):
        findings.extend(lint_file(path, repo_root, rules))
    return findings
