"""Dependency-free RDP (moments) accountant for client-level DP training.

Tracks the cumulative privacy loss of R federated rounds, each of which
releases the noised aggregate of S clipped client uploads out of N
clients — the subsampled Gaussian mechanism with sampling rate
``q = S / N`` and noise multiplier ``sigma`` (noise std ``sigma * C`` on
the clipped-to-``C`` sum, i.e. ``sigma * C / S`` on the mean the server
actually applies).

Renyi-DP composition (Mironov 2017; subsampled bound of Mironov, Talwar
& Zhang 2019 / Wang, Balle & Kasiviswanathan 2019, integer orders):

* one round of the plain Gaussian mechanism (``q = 1``) has
  ``RDP(alpha) = alpha / (2 sigma^2)``;
* one Poisson-subsampled round at rate ``q < 1`` has, for integer
  ``alpha >= 2``,

  ``RDP(alpha) = log( sum_k C(alpha,k) (1-q)^(alpha-k) q^k
  exp(k (k-1) / (2 sigma^2)) ) / (alpha - 1)``;

* rounds compose by ADDING their RDP at each order, which is what lets
  the accountant consume the ACTUAL per-round cohort sizes the
  participation engine produced instead of assuming a constant rate;
* the (eps, delta) conversion is ``eps = min_alpha RDP(alpha) +
  log(1/delta) / (alpha - 1)``.

CAVEAT (sampling-scheme mismatch, docs/privacy.md): the amplification
bound above is a theorem for POISSON sampling, while the engine's
samplers draw fixed-size cohorts without replacement. Applying the
Poisson bound at ``q = S/N`` is the standard practice of the DP-FL
tooling ecosystem (Opacus / TF-Privacy account exactly this way for
fixed-size batches) but is an approximation, not a theorem, for this
sampler; fixed-size without-replacement RDP bounds (Wang, Balle &
Kasiviswanathan 2019) differ and can be larger. Treat reported eps
accordingly, or deploy with Poisson cohort sampling.

When one round releases E separately clipped-and-noised aggregates
(FedAdamW ships ``delta`` AND the block-mean ``v``; SCAFFOLD ships
``delta`` and ``dc``), the joint release is a single Gaussian mechanism
on the concatenated vector with sensitivity ``sqrt(E) * C`` but
per-block noise ``sigma * C`` — equivalent to one release at effective
multiplier ``sigma / sqrt(E)`` (``released_entries``; docs/privacy.md).

Usage (runs under ``python -m doctest``):

>>> acc = RDPAccountant(noise_multiplier=1.0, num_clients=100,
...                     delta=1e-5)
>>> for _ in range(10):
...     acc.step(cohort_size=10)            # the ACTUAL per-round S_r
>>> 0.0 < acc.epsilon() < epsilon(1.0, q=1.0, rounds=10, delta=1e-5)
True
>>> epsilon(2.0, q=0.1, rounds=10) < epsilon(1.0, q=0.1, rounds=10)
True
>>> sigma = calibrate_noise_multiplier(2.0, q=0.1, rounds=50)
>>> epsilon(sigma, q=0.1, rounds=50) <= 2.0
True
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Sequence, Tuple

# Integer Renyi orders: dense where the optimum usually lands, sparse
# tail for very small eps / very large sigma.
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 65)) + (
    80, 96, 128, 192, 256, 384, 512, 1024)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def _logsumexp(xs: Iterable[float]) -> float:
    xs = list(xs)
    m = max(xs)
    if m == -math.inf:
        return -math.inf
    return m + math.log(sum(math.exp(x - m) for x in xs))


def _rdp_round(q: float, sigma: float, orders: Sequence[int]
               ) -> Tuple[float, ...]:
    """RDP cost of ONE subsampled-Gaussian round at every order."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling rate q must be in [0, 1], got {q}")
    if sigma < 0.0:
        raise ValueError(f"noise multiplier must be >= 0, got {sigma}")
    if q == 0.0:
        return tuple(0.0 for _ in orders)
    if sigma == 0.0:
        return tuple(math.inf for _ in orders)
    if q == 1.0:
        return tuple(a / (2.0 * sigma * sigma) for a in orders)
    log_q, log_1mq = math.log(q), math.log1p(-q)
    out = []
    for a in orders:
        terms = (_log_comb(a, k) + k * log_q + (a - k) * log_1mq
                 + k * (k - 1) / (2.0 * sigma * sigma)
                 for k in range(a + 1))
        out.append(_logsumexp(terms) / (a - 1))
    return tuple(out)


def _rdp_to_epsilon(rdp: Sequence[float], orders: Sequence[int],
                    delta: float) -> float:
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    log_inv_delta = math.log(1.0 / delta)
    return min(r + log_inv_delta / (a - 1) for r, a in zip(rdp, orders))


class RDPAccountant:
    """Cumulative (eps, delta) tracker over heterogeneous rounds.

    ``step(cohort_size)`` charges one round at the rate that round
    ACTUALLY ran (``cohort_size / num_clients``); ``epsilon()`` converts
    the composed RDP curve at ``delta``. ``released_entries`` folds the
    E-separately-noised-aggregates release into an effective noise
    multiplier ``sigma / sqrt(E)`` (module docstring).
    """

    def __init__(self, noise_multiplier: float, num_clients: int, *,
                 delta: float = 1e-5, released_entries: int = 1,
                 orders: Sequence[int] = DEFAULT_ORDERS):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        if released_entries < 1:
            raise ValueError(
                f"released_entries must be >= 1, got {released_entries}")
        if noise_multiplier < 0.0:
            raise ValueError(
                f"noise multiplier must be >= 0, got {noise_multiplier}")
        self.noise_multiplier = float(noise_multiplier)
        self.num_clients = int(num_clients)
        self.delta = float(delta)
        self.released_entries = int(released_entries)
        self.orders = tuple(orders)
        self.rounds = 0
        self._rdp = [0.0 for _ in self.orders]
        self._sigma_eff = (self.noise_multiplier
                          / math.sqrt(self.released_entries))

    def step(self, cohort_size: int, *, rounds: int = 1) -> None:
        """Charge ``rounds`` rounds that each sampled ``cohort_size``
        distinct clients."""
        if not 0 <= cohort_size <= self.num_clients:
            raise ValueError(
                f"cohort_size must be in [0, num_clients="
                f"{self.num_clients}], got {cohort_size}")
        q = cohort_size / self.num_clients
        per_round = _rdp_round(q, self._sigma_eff, self.orders)
        self._rdp = [r + rounds * p for r, p in zip(self._rdp, per_round)]
        self.rounds += rounds

    def epsilon(self, delta: Optional[float] = None) -> float:
        """eps spent so far at ``delta`` (defaults to the constructor's).
        ``inf`` before any noised round, or when sigma == 0."""
        if self.rounds == 0 and all(r == 0.0 for r in self._rdp):
            return 0.0
        return _rdp_to_epsilon(self._rdp, self.orders,
                               self.delta if delta is None else delta)


def epsilon(noise_multiplier: float, *, q: float, rounds: int,
            delta: float = 1e-5, released_entries: int = 1,
            orders: Sequence[int] = DEFAULT_ORDERS) -> float:
    """eps of ``rounds`` homogeneous subsampled-Gaussian rounds."""
    sigma = noise_multiplier / math.sqrt(released_entries)
    if sigma == 0.0:
        return math.inf
    rdp = [rounds * r for r in _rdp_round(q, sigma, orders)]
    return _rdp_to_epsilon(rdp, orders, delta)


def gaussian_epsilon_closed_form(noise_multiplier: float,
                                 delta: float = 1e-5) -> float:
    """Closed-form (continuous-order) conversion for ONE plain Gaussian
    mechanism (``q = 1``, one round): minimizing ``alpha/(2 sigma^2) +
    log(1/delta)/(alpha-1)`` over real alpha gives

        eps = 1 / (2 sigma^2) + sqrt(2 log(1/delta)) / sigma

    The integer-order accountant must match this within the order-grid
    discretization (test fixture).
    """
    s = float(noise_multiplier)
    if s <= 0.0:
        return math.inf
    return 1.0 / (2.0 * s * s) + math.sqrt(2.0 * math.log(1.0 / delta)) / s


def calibrate_noise_multiplier(target_epsilon: float, *, q: float,
                               rounds: int, delta: float = 1e-5,
                               released_entries: int = 1,
                               tol: float = 1e-3,
                               sigma_max: float = 1e4) -> float:
    """Smallest noise multiplier whose eps is <= ``target_epsilon``.

    Bisection on the (monotonically decreasing) ``epsilon(sigma)`` curve;
    raises if even ``sigma_max`` cannot reach the target.
    """
    if target_epsilon <= 0.0:
        raise ValueError(
            f"target_epsilon must be > 0, got {target_epsilon}")

    def eps_at(sigma: float) -> float:
        return epsilon(sigma, q=q, rounds=rounds, delta=delta,
                       released_entries=released_entries)

    lo, hi = 1e-3, sigma_max
    if eps_at(hi) > target_epsilon:
        raise ValueError(
            f"target_epsilon={target_epsilon} unreachable with noise "
            f"multiplier <= {sigma_max} at q={q}, rounds={rounds}, "
            f"delta={delta}: even that much noise leaks "
            f"eps={eps_at(hi):.3g}. Raise target_epsilon, lower the "
            "sampling rate, or train fewer rounds.")
    if eps_at(lo) <= target_epsilon:
        return lo
    while hi - lo > tol * hi:
        mid = 0.5 * (lo + hi)
        if eps_at(mid) <= target_epsilon:
            hi = mid
        else:
            lo = mid
    return hi
