"""Differential privacy for federated training (docs/privacy.md).

Client-level DP for every registered algorithm, composed with the four
existing subsystems (comm codecs, client-state store, pipelined rounds,
participation engine) rather than forked from them:

``dp``          jittable mechanism — per-client L2 clipping of every
                aggregated upload entry (inside ``core.rounds``, both
                placement layouts, BEFORE codec compression) and seeded
                Gaussian noise on the post-aggregation mean, keyed on
                ``(dp_seed, round_index)`` so eager / prefetched /
                ``rounds_per_call``-fused execution stay bit-identical
``accountant``  dependency-free RDP/moments accountant: composes the
                ACTUAL per-round cohort sizes into (eps, delta), and
                inverts a ``target_epsilon`` into a noise multiplier at
                config time

The DP hot path has an opt-in fused Pallas kernel
(``repro.kernels.clipacc``, ``FedConfig.use_pallas_clipacc``) that folds
the per-client norm + scale + cross-client accumulate into one pass over
the (S, model-size) upload stack.

The disabled config (``dp_clip == 0``) is statically gated and traces
the exact pre-privacy round program — bit-exact by construction.
"""
from repro.privacy.accountant import (
    DEFAULT_ORDERS,
    RDPAccountant,
    calibrate_noise_multiplier,
    epsilon,
    gaussian_epsilon_closed_form,
)
from repro.privacy.dp import (
    NONNEG_ENTRIES,
    NORM_FLOOR,
    add_round_noise,
    clip_tree_by_l2,
    clip_upload_aux,
    dp_enabled,
    l2_clip_factor,
    l2_sq_norm,
    released_entry_count,
    resolve_dp_noise,
)

__all__ = [
    "DEFAULT_ORDERS", "RDPAccountant", "calibrate_noise_multiplier",
    "epsilon", "gaussian_epsilon_closed_form",
    "NONNEG_ENTRIES", "NORM_FLOOR", "add_round_noise", "clip_tree_by_l2",
    "clip_upload_aux", "dp_enabled", "l2_clip_factor", "l2_sq_norm",
    "released_entry_count", "resolve_dp_noise",
]
