"""Client-level DP mechanism: per-client L2 clipping + seeded Gaussian
noise on the aggregated uploads (docs/privacy.md).

The round engine (:mod:`repro.core.rounds`) drives three jittable hooks,
in BOTH placement layouts:

* :func:`clip_tree_by_l2` — each client's raw ``delta`` is clipped to
  ``FedConfig.dp_clip`` inside ``local_phase`` BEFORE ``alg.upload``
  runs, i.e. before any upload codec encodes it (wire bytes unchanged,
  and the codec quantizes exactly the bounded values);
* :func:`clip_upload_aux` — every other aggregated upload entry
  (FedAdamW's block-mean ``v``, SCAFFOLD's ``c_new_minus_c`` and the
  post-``commit`` ``dc``) is clipped per client to the same bound;
  client-resident comm state (error-feedback residuals) is never
  aggregated and passes through unclipped;
* :func:`add_round_noise` — Gaussian noise with std ``sigma * C / S``
  is added to each entry of the aggregated mean AFTER the cross-client
  reduction (server-side, secure-agg-style: only the aggregate is ever
  noised). The noise key is ``fold_in(PRNGKey(dp_seed), round_index)``
  plus a per-leaf counter — a pure function of ``(dp_seed, round
  index, leaf position)``, never of trace structure, so eager,
  host-prefetched, and ``rounds_per_call``-fused execution draw
  BIT-identical noise (the scenario-engine seeding pattern).

Everything is statically gated on ``fed.dp_clip > 0``: the disabled
config traces the exact pre-privacy program (bit-exactness is
structural, as with the degenerate participation scenario).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Tree = Any

NORM_FLOOR = 1e-12      # guards all-zero updates in the clip factor
# aggregated entries that are second-moment estimates: noise can push
# them negative, which would NaN the sqrt in the next round's update —
# clamping at zero is post-processing of the released value (DP holds)
NONNEG_ENTRIES = ("v_mean", "v_full")


def dp_enabled(fed) -> bool:
    return fed.dp_clip > 0.0


def l2_sq_norm(tree: Tree) -> jax.Array:
    """Squared global L2 norm, accumulated left-to-right over the leaves
    in a FIXED association order (one leaf at a time) so the reduction
    lowers identically inside the single-round program and the fused
    multi-round scan body — the ``_weighted_mean`` determinism idiom."""
    leaves = jax.tree.leaves(tree)
    acc = jnp.sum(jnp.square(leaves[0].astype(jnp.float32)))
    for leaf in leaves[1:]:
        acc = acc + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    return acc


def l2_clip_factor(tree: Tree, clip: float) -> jax.Array:
    """``min(1, clip / ||tree||_2)`` — 1.0 exactly when within bound."""
    norm = jnp.sqrt(l2_sq_norm(tree))
    return jnp.minimum(1.0, clip / jnp.maximum(norm, NORM_FLOOR))


def clip_tree_by_l2(tree: Tree, clip: float) -> Tree:
    """Scale the whole pytree so its JOINT L2 norm is <= ``clip``."""
    factor = l2_clip_factor(tree, clip)
    return jax.tree.map(
        lambda x: (x.astype(jnp.float32) * factor).astype(x.dtype), tree)


def clip_upload_aux(upload: Dict[str, Tree], clip: float) -> Dict[str, Tree]:
    """Clip every aggregated upload entry EXCEPT ``delta`` (already
    clipped pre-codec in ``local_phase``) and the client-resident comm
    state keys, each independently to ``clip``."""
    from repro.comm.error_feedback import COMM_STATE_KEYS
    return {k: (v if k == "delta" or k in COMM_STATE_KEYS
                else clip_tree_by_l2(v, clip))
            for k, v in upload.items()}


def released_entry_count(upload: Dict[str, Any]) -> int:
    """Number of separately noised aggregates one round releases (the
    accountant's ``released_entries``): the upload's top-level entries
    minus client-resident comm state."""
    from repro.comm.error_feedback import COMM_STATE_KEYS
    return len([k for k in upload if k not in COMM_STATE_KEYS])


def add_round_noise(mean_up: Dict[str, Tree], fed, round_index,
                    cohort_size=None) -> Dict[str, Tree]:
    """Server-side Gaussian noise on the aggregated mean, one
    independent draw per leaf, std ``dp_noise_multiplier * dp_clip / S``
    (the clipped SUM takes ``sigma * C``; the engine aggregates the
    uniform mean, so the mean takes ``sigma * C / S``).

    ``cohort_size`` (a traced scalar) replaces the static S when the
    fault-defense layer rejected uploads: the mean is then taken over
    the SURVIVING clients, so the same per-client guarantee needs
    ``sigma * C / S_valid`` — the noise grows as survivors shrink. The
    default (None) keeps the static-S expression, so defense-free
    programs trace unchanged. The RDP accountant consumes the matching
    per-round survivor counts via the ``agg_survivors`` round metric
    (``repro.launch.train``).

    Keys depend only on ``(dp_seed, round_index, leaf counter)`` with a
    fixed (sorted-entry, flatten-order) leaf numbering, so every
    execution mode and both placement layouts draw the same bits.
    """
    from repro.comm.error_feedback import COMM_STATE_KEYS
    denom = (fed.clients_per_round if cohort_size is None
             else jnp.maximum(cohort_size, 1.0))
    std = fed.dp_noise_multiplier * fed.dp_clip / denom
    rkey = jax.random.fold_in(jax.random.PRNGKey(fed.dp_seed),
                              round_index)
    out: Dict[str, Tree] = {}
    counter = 0
    for name in sorted(mean_up):
        entry = mean_up[name]
        if name in COMM_STATE_KEYS:
            out[name] = entry
            continue
        leaves, treedef = jax.tree_util.tree_flatten(entry)
        noised = []
        for leaf in leaves:
            key = jax.random.fold_in(rkey, counter)
            counter += 1
            noise = std * jax.random.normal(key, leaf.shape, jnp.float32)
            noised.append(
                (leaf.astype(jnp.float32) + noise).astype(leaf.dtype))
        entry = jax.tree_util.tree_unflatten(treedef, noised)
        if name in NONNEG_ENTRIES:
            entry = jax.tree.map(lambda x: jnp.maximum(x, 0.0), entry)
        out[name] = entry
    return out


def resolve_dp_noise(fed, *, released_entries: int = 1):
    """Turn ``FedConfig.target_epsilon`` into a concrete
    ``dp_noise_multiplier`` at config time (bisection on the accountant,
    at the run's own ``q = S/N``, R, delta and entry count). Returns the
    config unchanged when DP is off or the multiplier is already set.
    """
    if not dp_enabled(fed) or fed.target_epsilon <= 0.0:
        return fed
    from repro.privacy.accountant import calibrate_noise_multiplier
    sigma = calibrate_noise_multiplier(
        fed.target_epsilon,
        q=fed.clients_per_round / fed.num_clients,
        rounds=fed.rounds, delta=fed.dp_delta,
        released_entries=released_entries)
    return dataclasses.replace(fed, dp_noise_multiplier=sigma,
                               target_epsilon=0.0)
