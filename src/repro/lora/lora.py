"""LoRA adapters for federated fine-tuning (paper's RoBERTa+LoRA setting).

Low-rank additive deltas on selected weight matrices: for a target leaf
``W (…, in, out-ish)`` we keep ``A (…, in, r)`` and ``B (…, r, out)`` and use
``W + scale * A @ B`` at forward time. Head-factored attention weights
``(D, H, hd)`` are treated as ``(D, H*hd)`` for the low-rank factorization
and reshaped back — equivalent to LoRA on the unfactored projection.

Federated fine-tuning freezes the base tree: the round engine sees only the
LoRA tree (a regular pytree), so every FL algorithm — including FedAdamW's
block-mean aggregation — applies unchanged; the Hessian-block partitioner
falls back to per-tensor blocks for A/B (Appendix D Algorithm 4), matching
the paper's RoBERTa-LoRA experiments where each LoRA matrix is one block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = ("attn_wq", "attn_wv")


def _path_names(kp) -> Tuple[str, ...]:
    return tuple(k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
                 for k in kp)


def init_lora(params, rng: jax.Array, *, rank: int = 16, alpha: float = 32.0,
              targets: Tuple[str, ...] = DEFAULT_TARGETS) -> Dict[str, Any]:
    """Build the LoRA tree: {joined_path: {"A": ..., "B": ...}}.

    Handles stacked scan-layer leaves transparently (leading L axis kept)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    lora: Dict[str, Any] = {}
    keys = jax.random.split(rng, max(len(flat), 1))
    for i, (kp, leaf) in enumerate(flat):
        names = _path_names(kp)
        if not names[-1].endswith(targets):
            continue
        shape = tuple(leaf.shape)
        if len(shape) < 2:
            continue
        # figure out (lead, d_in, d_out): head-factored 3-D -> (in, H*hd)
        if len(shape) == 2:
            lead, d_in, d_out = (), shape[0], shape[1]
        elif len(shape) == 3:
            lead, d_in, d_out = (shape[0],), shape[1], shape[2]
            if names[-1].endswith(("attn_wq", "attn_wk", "attn_wv")):
                lead, d_in, d_out = (), shape[0], shape[1] * shape[2]
        elif len(shape) == 4:  # stacked (L, D, H, hd)
            lead, d_in, d_out = (shape[0],), shape[1], shape[2] * shape[3]
        else:
            continue
        a = jax.random.normal(keys[i], lead + (d_in, rank)) * (d_in ** -0.5)
        b = jnp.zeros(lead + (rank, d_out))
        lora["\x1f".join(names)] = {"A": a.astype(jnp.float32), "B": b}
    if not lora:
        raise ValueError(f"no LoRA targets matched {targets}")
    return {"lora": lora, "scale": jnp.asarray(alpha / rank, jnp.float32)}


def merge_lora(params, lora_tree) -> Any:
    """Return params with LoRA deltas added (differentiable w.r.t. lora)."""
    scale = lora_tree["scale"]
    adapters = lora_tree["lora"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    for kp, leaf in flat:
        key = "\x1f".join(_path_names(kp))
        if key in adapters:
            a = adapters[key]["A"].astype(leaf.dtype)
            b = adapters[key]["B"].astype(leaf.dtype)
            delta = jnp.einsum("...ir,...ro->...io", a, b) * scale.astype(leaf.dtype)
            leaves.append(leaf + delta.reshape(leaf.shape))
        else:
            leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass(frozen=True)
class LoraModel:
    """Adapter exposing the Model API over the LoRA tree only: the round
    engine optimizes ``lora_tree`` while the base params stay frozen."""

    model: Any
    base_params: Any

    def init(self, rng: jax.Array, *, rank: int = 16, alpha: float = 32.0,
             targets: Tuple[str, ...] = DEFAULT_TARGETS):
        return init_lora(self.base_params, rng, rank=rank, alpha=alpha,
                         targets=targets)

    def loss(self, lora_tree, batch):
        merged = merge_lora(jax.lax.stop_gradient(self.base_params), lora_tree)
        return self.model.loss(merged, batch)

    def forward(self, lora_tree, batch):
        merged = merge_lora(jax.lax.stop_gradient(self.base_params), lora_tree)
        return self.model.forward(merged, batch)

    @property
    def cfg(self):
        return self.model.cfg


def build_lora_model(model, base_params) -> LoraModel:
    return LoraModel(model=model, base_params=base_params)
