from repro.lora.lora import (
    init_lora,
    merge_lora,
    LoraModel,
    build_lora_model,
)

__all__ = ["init_lora", "merge_lora", "LoraModel", "build_lora_model"]
