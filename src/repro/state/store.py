"""Unified per-client server-side state store (``ClientStateStore``).

Every stateful-per-client mechanism in the repo — SCAFFOLD control
variates, the error-feedback residual table of lossy upload codecs, and
any future per-client momentum / personalization / DP-accountant table —
keeps a ``num_clients x params`` table on the server. Before this module
each mechanism allocated and indexed its own dense f32 table, which (a)
duplicated the gather/scatter logic, (b) replicated the table on every
device, and (c) made per-client state impossible under the
``client_sequential`` layout. The store centralizes all of it behind one
functional API:

    store = store_for(fed, specs)
    table = store.init()                   # zero rows, storage per policy
    rows  = store.gather(table, cids)      # dense f32 rows (decoded)
    table = store.scatter(table, cids, rows)

``cids`` may be a scalar (one client at a time — the ``client_sequential``
scan) or an ``(S,)`` vector (the vmapped ``client_parallel`` round); the
gathered/scattered values carry a matching leading axis.

Storage policies (``FedConfig.client_state_policy``):

``dense``
    ``(num_clients, *leaf.shape)`` f32 per leaf — exact, 4 bytes/elem/client.
``blockmean``
    ``(num_clients, n_blocks)`` f32 per leaf via the Hessian-block
    ``partition`` machinery — O(n_blocks) per client; gather broadcasts
    the block means back to full shape (lossy, same approximation the
    paper applies to ``v``).
``int8``
    symmetric per-row int8 rows + one f32 scale per (client, leaf) via the
    quantpack codec math — ~4x memory cut, error <= scale/2 per element.

The table is an ordinary pytree (nested dicts/arrays) so it lives inside
server state, traverses jit/scan/vmap, and checkpoints like everything
else. :func:`table_pspecs` shards the leading client axis over the
(``pod``, ``data``) mesh axes so the table is distributed instead of
replicated (``sharding.specs.state_pspecs`` applies the same rule).

Usage — a 4-client dense table over one weight leaf (runs under
``python -m doctest``):

>>> import jax.numpy as jnp
>>> from repro.state.store import ClientStateStore, specs_like
>>> template = {"w": jnp.zeros((3, 2))}
>>> store = ClientStateStore(num_clients=4, policy="dense",
...                          specs=specs_like(template))
>>> table = store.init()             # lives inside server state
>>> table["w"].shape                 # one row per client
(4, 3, 2)
>>> rows = store.gather(table, jnp.asarray([1, 3]))   # (S,) cids
>>> rows["w"].shape                  # decoded dense rows, leading S axis
(2, 3, 2)
>>> table = store.scatter(table, jnp.asarray(1),      # scalar cid:
...                       {"w": jnp.ones((3, 2))})    # sequential layout
>>> [float(table["w"][c].sum()) for c in range(4)]
[0.0, 6.0, 0.0, 0.0]
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.partition import LeafBlockSpec

Array = jax.Array
Tree = Any

POLICIES = ("dense", "blockmean", "int8")

# identical constants to repro.comm.codecs so int8 rows are bit-compatible
# with the quantpack wire format (single f32-rounded reciprocal multiply)
_SCALE_FLOOR = 1e-12
_INV_QMAX8 = float(np.float32(1.0 / 127.0))


def _is_spec(x) -> bool:
    return isinstance(x, LeafBlockSpec)


def _leaf_elems(spec: LeafBlockSpec) -> int:
    return int(np.prod(spec.shape)) if spec.shape else 1


@dataclasses.dataclass(frozen=True)
class ClientStateStore:
    """Policy + shape metadata for one per-client state table.

    ``specs`` is the LeafBlockSpec tree of the stored quantity (same
    structure as the param tree); it provides the leaf shapes for every
    policy and the block structure for ``blockmean``.
    """

    num_clients: int
    policy: str = "dense"
    specs: Tree = None

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown client_state_policy {self.policy!r}; "
                f"known: {POLICIES}")
        if self.specs is None:
            raise ValueError("ClientStateStore needs a LeafBlockSpec tree "
                             "(build one with partition.build_block_specs "
                             "or specs_like)")

    # -- per-leaf kernels ---------------------------------------------------

    def _init_leaf(self, spec: LeafBlockSpec):
        n_c = self.num_clients
        if self.policy == "dense":
            return jnp.zeros((n_c,) + tuple(spec.shape), jnp.float32)
        if self.policy == "blockmean":
            return jnp.zeros((n_c, spec.n_blocks), jnp.float32)
        return {"q": jnp.zeros((n_c, _leaf_elems(spec)), jnp.int8),
                "scale": jnp.zeros((n_c,), jnp.float32)}

    def _gather_leaf(self, spec: LeafBlockSpec, tleaf, cids):
        if self.policy == "dense":
            return tleaf[cids]
        if self.policy == "blockmean":
            rows = tleaf[cids]                       # (..., n_blocks)
            dec = lambda r: partition.broadcast_means(r, spec)  # noqa: E731
            return dec(rows) if rows.ndim == 1 else jax.vmap(dec)(rows)
        q = tleaf["q"][cids].astype(jnp.float32)     # (..., n)
        s = tleaf["scale"][cids]
        x = q * (s[..., None] if q.ndim > 1 else s)
        lead = (x.shape[0],) if q.ndim > 1 else ()
        return x.reshape(lead + tuple(spec.shape))

    def _scatter_leaf(self, spec: LeafBlockSpec, tleaf, cids, value):
        v32 = jnp.asarray(value).astype(jnp.float32)
        batched = v32.ndim > len(spec.shape)
        if self.policy == "dense":
            return tleaf.at[cids].set(v32)
        if self.policy == "blockmean":
            enc = lambda x: partition.block_means(x, spec)  # noqa: E731
            return tleaf.at[cids].set(
                jax.vmap(enc)(v32) if batched else enc(v32))

        def enc(x):
            flat = x.reshape(-1)
            scale = jnp.maximum(jnp.max(jnp.abs(flat)),
                                _SCALE_FLOOR) * _INV_QMAX8
            q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
            return q, scale

        q, s = jax.vmap(enc)(v32) if batched else enc(v32)
        return {"q": tleaf["q"].at[cids].set(q),
                "scale": tleaf["scale"].at[cids].set(s)}

    # -- tree-level API -----------------------------------------------------

    def init(self) -> Tree:
        """Zero table; per-leaf storage layout set by the policy."""
        return jax.tree.map(self._init_leaf, self.specs, is_leaf=_is_spec)

    def gather(self, table: Tree, cids) -> Tree:
        """Decode the rows of ``cids`` to dense f32 param-shaped values."""
        return jax.tree.map(
            lambda s, t: self._gather_leaf(s, t, cids),
            self.specs, table, is_leaf=_is_spec)

    def scatter(self, table: Tree, cids, values: Tree) -> Tree:
        """Encode ``values`` (dense rows matching ``cids``) into the table."""
        return jax.tree.map(
            lambda s, t, v: self._scatter_leaf(s, t, cids, v),
            self.specs, table, values, is_leaf=_is_spec)

    def table_bytes(self, table: Tree = None) -> int:
        """Exact storage footprint of the table (shape-static)."""
        if table is None:
            table = jax.eval_shape(self.init)
        return sum(int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree.leaves(table))


# ---------------------------------------------------------------------------
# constructors / sharding
# ---------------------------------------------------------------------------

def specs_like(tree: Tree) -> Tree:
    """Trivial one-block-per-tensor LeafBlockSpec tree for an arbitrary
    pytree of arrays (enough for ``dense``/``int8``; ``blockmean`` wants
    the real Hessian-block specs from :func:`partition.build_block_specs`)."""
    return jax.tree.map(
        lambda x: LeafBlockSpec(tuple(x.shape), (), ()), tree)


def store_for(fed, specs: Tree, *, policy: str = None) -> ClientStateStore:
    """Store for ``fed``'s client-state policy over the given spec tree."""
    return ClientStateStore(
        num_clients=fed.num_clients,
        policy=policy or getattr(fed, "client_state_policy", "dense"),
        specs=specs)


# server-state keys that hold ClientStateStore tables — the sharding
# rules (table_pspecs here, sharding.specs.state_pspecs) key off this
# list; extend it when adding a new per-client mechanism. "comm_ef" is
# repro.comm.error_feedback.EF_KEY (kept literal: state must not depend
# on comm).
CLIENT_TABLE_KEYS = ("c_all", "comm_ef")


def client_row_pspec(leaf, mesh, num_clients: int):
    """PartitionSpec for ONE table leaf: shard the leading client axis
    over the mesh's client axes (``pod`` + ``data``); replicate when the
    leaf has no ``num_clients`` leading axis or the axis product does not
    divide it. The single source of the rule — ``table_pspecs`` and
    ``sharding.specs.state_pspecs`` both apply it."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.specs import client_axes

    cax = client_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in cax])) if cax else 1
    shard = (cax and size > 1 and leaf.ndim >= 1
             and leaf.shape[0] == num_clients and num_clients % size == 0)
    if not shard:
        return P(*([None] * leaf.ndim))
    ax = cax if len(cax) > 1 else cax[0]
    return P(ax, *([None] * (leaf.ndim - 1)))


def table_pspecs(table: Tree, mesh, num_clients: int) -> Tree:
    """PartitionSpecs for a whole table (leaf-wise client_row_pspec)."""
    return jax.tree.map(
        lambda leaf: client_row_pspec(leaf, mesh, num_clients), table)
