"""Per-client server-side state (README.md §Client-state store).

``store``  ClientStateStore: one table API (init/gather/scatter) with
           dense | blockmean | int8 storage policies and a client-axis
           sharding rule.
"""
from repro.state.store import (
    CLIENT_TABLE_KEYS,
    POLICIES,
    ClientStateStore,
    client_row_pspec,
    specs_like,
    store_for,
    table_pspecs,
)

__all__ = [
    "CLIENT_TABLE_KEYS", "POLICIES", "ClientStateStore",
    "client_row_pspec", "specs_like", "store_for", "table_pspecs",
]
