"""Lightweight metric logging: CSV / JSONL files + an EMA meter."""
from __future__ import annotations

import csv
import json
import os
import time
from typing import Any, Dict, Optional


class CSVLogger:
    """CSV logger whose column set may grow mid-run.

    The header is NOT frozen on the first row: pass ``fieldnames`` as a
    superset up front, or let a later row introduce new keys — the file
    is rewritten with the extended header so no column is silently
    dropped (training loops log eval-only keys like ``test_acc`` on a
    subset of rounds).

    Usable as a context manager; ``close()`` is idempotent and every
    ``log()`` flushes, so a crashed run leaves at worst a complete,
    parseable file missing only post-crash rows."""

    @staticmethod
    def _writer(fh):
        return csv.writer(fh, lineterminator="\n")

    def __init__(self, path: str, fieldnames=None):
        self.path = path
        self.fieldnames = list(fieldnames) if fieldnames else []
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "w", newline="")
            if self.fieldnames:
                self._writer(self._fh).writerow(self.fieldnames)
                self._fh.flush()

    def _write_row(self, fh, row: Dict[str, Any]) -> None:
        self._writer(fh).writerow(
            [str(row.get(k, "")) for k in self.fieldnames])

    def _rewrite(self, old_fields) -> None:
        """Re-key the on-disk rows (every row is flushed, so the file IS
        the row buffer — nothing is held in memory) under the widened
        header; atomic via temp-file + rename so a crash mid-rewrite
        cannot lose already-flushed rows."""
        self._fh.close()
        with open(self.path, newline="") as f:
            lines = list(csv.reader(f))
        data = lines[1:] if old_fields else lines
        tmp = self.path + ".tmp"
        with open(tmp, "w", newline="") as f:
            self._writer(f).writerow(self.fieldnames)
            for values in data:
                self._write_row(f, dict(zip(old_fields, values)))
        os.replace(tmp, self.path)
        self._fh = open(self.path, "a", newline="")

    def log(self, row: Dict[str, Any]) -> None:
        new_keys = [k for k in row if k not in self.fieldnames]
        if new_keys:
            old_fields = list(self.fieldnames)
            self.fieldnames.extend(new_keys)
            if self._fh:
                self._rewrite(old_fields)
        if self._fh:
            self._write_row(self._fh, row)
            self._fh.flush()

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "CSVLogger":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class JSONLLogger:
    """Line-delimited JSON logger; context manager, idempotent close,
    flushed per record (same crash guarantees as :class:`CSVLogger`)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "w")

    def log(self, record: Dict[str, Any]) -> None:
        if self._fh is None:
            raise ValueError(f"JSONLLogger {self.path!r} is closed")
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JSONLLogger":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class Meter:
    """Wall-time + EMA loss meter."""

    def __init__(self, ema: float = 0.9):
        self.ema = ema
        self.value: Optional[float] = None
        self.count = 0
        self.t0 = time.perf_counter()

    def update(self, x: float) -> float:
        x = float(x)
        self.value = x if self.value is None else (
            self.ema * self.value + (1 - self.ema) * x)
        self.count += 1
        return self.value

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self.t0
