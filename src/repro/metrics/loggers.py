"""Lightweight metric logging: CSV / JSONL files + an EMA meter."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class CSVLogger:
    def __init__(self, path: str, fieldnames=None):
        self.path = path
        self.fieldnames = list(fieldnames) if fieldnames else None
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "w")

    def log(self, row: Dict[str, Any]) -> None:
        if self.fieldnames is None:
            self.fieldnames = list(row.keys())
            if self._fh:
                self._fh.write(",".join(self.fieldnames) + "\n")
        if self._fh:
            self._fh.write(",".join(str(row.get(k, "")) for k in
                                    self.fieldnames) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh:
            self._fh.close()


class JSONLLogger:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "w")

    def log(self, record: Dict[str, Any]) -> None:
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()


class Meter:
    """Wall-time + EMA loss meter."""

    def __init__(self, ema: float = 0.9):
        self.ema = ema
        self.value: Optional[float] = None
        self.count = 0
        self.t0 = time.perf_counter()

    def update(self, x: float) -> float:
        x = float(x)
        self.value = x if self.value is None else (
            self.ema * self.value + (1 - self.ema) * x)
        self.count += 1
        return self.value

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self.t0
