from repro.metrics.deferred import MetricsSpool
from repro.metrics.loggers import CSVLogger, JSONLLogger, Meter

__all__ = ["CSVLogger", "JSONLLogger", "Meter", "MetricsSpool"]
