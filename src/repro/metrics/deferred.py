"""Deferred per-round metrics: keep device scalars unfetched until a
boundary, so the dispatch queue stays full between evals.

The eager seed loop called ``float(metrics["loss_mean"])`` every round —
a host<->device round-trip that drains the dispatch queue and leaves the
device idle while the host assembles the next batch. ``MetricsSpool``
instead holds the (0-d or per-round-stacked) device arrays and fetches
them in ONE blocking transfer at eval boundaries.

Usage — append per-round (scalar) or per-block (stacked) metrics, flush
once at a boundary (runs under ``python -m doctest``):

>>> import jax.numpy as jnp
>>> from repro.metrics.deferred import MetricsSpool
>>> spool = MetricsSpool()
>>> spool.append(0, {"loss": jnp.asarray(1.5)})          # round 0
>>> spool.append(1, {"loss": jnp.asarray([2.5, 3.5])},   # rounds 1-2,
...              num_rounds=2)                           # one fused block
>>> len(spool)
3
>>> spool.flush()                    # ONE device_get, per-round records
[(0, {'loss': 1.5}), (1, {'loss': 2.5}), (2, {'loss': 3.5})]
>>> spool.flush()                    # drained
[]
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np


class MetricsSpool:
    """Accumulates per-round metric pytrees on device; ``flush()`` does a
    single blocking ``jax.device_get`` and expands them to per-round
    ``(round, {name: float})`` records.

    ``append(start_round, metrics)`` accepts either scalar leaves (one
    round) or leaves with a leading round axis of length ``num_rounds``
    (a fused multi-round block).
    """

    def __init__(self):
        self._pending: List[Tuple[int, int, Dict[str, Any]]] = []

    def append(self, start_round: int, metrics: Dict[str, Any],
               num_rounds: int = 1) -> None:
        self._pending.append((int(start_round), int(num_rounds), metrics))

    def __len__(self) -> int:
        return sum(n for _, n, _ in self._pending)

    def flush(self) -> List[Tuple[int, Dict[str, float]]]:
        """One blocking fetch of everything spooled since the last flush,
        in round order."""
        if not self._pending:
            return []
        fetched = jax.device_get([m for _, _, m in self._pending])
        out: List[Tuple[int, Dict[str, float]]] = []
        for (start, n, _), metrics in zip(self._pending, fetched):
            arrs = {k: np.asarray(v) for k, v in metrics.items()}
            for i in range(n):
                out.append((start + i, {
                    k: float(a) if a.ndim == 0 else float(a[i])
                    for k, a in arrs.items()}))
        self._pending.clear()
        return out
