"""Deferred per-round metrics: keep device scalars unfetched until a
boundary, so the dispatch queue stays full between evals.

The eager seed loop called ``float(metrics["loss_mean"])`` every round —
a host<->device round-trip that drains the dispatch queue and leaves the
device idle while the host assembles the next batch. ``MetricsSpool``
instead holds the (0-d or per-round-stacked) device arrays and fetches
them in ONE blocking transfer at eval boundaries.

Usage — append per-round (scalar) or per-block (stacked) metrics, flush
once at a boundary (runs under ``python -m doctest``):

>>> import jax.numpy as jnp
>>> from repro.metrics.deferred import MetricsSpool
>>> spool = MetricsSpool()
>>> spool.append(0, {"loss": jnp.asarray(1.5)})          # round 0
>>> spool.append(1, {"loss": jnp.asarray([2.5, 3.5])},   # rounds 1-2,
...              num_rounds=2)                           # one fused block
>>> len(spool)
3
>>> spool.flush()                    # ONE device_get, per-round records
[(0, {'loss': 1.5}), (1, {'loss': 2.5}), (2, {'loss': 3.5})]
>>> spool.flush()                    # drained
[]

Non-scalar metrics (the per-client flight-recorder block) declare their
per-round rank via ``array_ndim`` and come back as numpy arrays instead
of floats — same single fetch, same fused-block splitting:

>>> spool = MetricsSpool(array_ndim={"blk": 1})
>>> spool.append(0, {"blk": jnp.asarray([1.0, 2.0])})    # one round
>>> spool.append(1, {"blk": jnp.asarray([[3.0], [4.0]])},
...              num_rounds=2)                           # fused block
>>> [(r, m["blk"].tolist()) for r, m in spool.flush()]
[(0, [1.0, 2.0]), (1, [3.0]), (2, [4.0])]
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np


class MetricsSpool:
    """Accumulates per-round metric pytrees on device; ``flush()`` does a
    single blocking ``jax.device_get`` and expands them to per-round
    ``(round, {name: float})`` records.

    ``append(start_round, metrics)`` accepts either scalar leaves (one
    round) or leaves with a leading round axis of length ``num_rounds``
    (a fused multi-round block).

    ``array_ndim`` maps metric names to their PER-ROUND rank (default 0
    = scalar). A leaf whose rank exceeds its per-round rank carries the
    leading fused-round axis and is split per round; rank-0 entries are
    converted to ``float``, higher ranks stay numpy arrays.
    """

    def __init__(self, array_ndim: Optional[Mapping[str, int]] = None):
        self._pending: List[Tuple[int, int, Dict[str, Any]]] = []
        self._array_ndim = dict(array_ndim or {})

    def append(self, start_round: int, metrics: Dict[str, Any],
               num_rounds: int = 1) -> None:
        self._pending.append((int(start_round), int(num_rounds), metrics))

    def __len__(self) -> int:
        return sum(n for _, n, _ in self._pending)

    def flush(self) -> List[Tuple[int, Dict[str, Any]]]:
        """One blocking fetch of everything spooled since the last flush,
        in round order."""
        if not self._pending:
            return []
        fetched = jax.device_get([m for _, _, m in self._pending])
        out: List[Tuple[int, Dict[str, Any]]] = []
        for (start, n, _), metrics in zip(self._pending, fetched):
            arrs = {k: np.asarray(v) for k, v in metrics.items()}
            for i in range(n):
                rec: Dict[str, Any] = {}
                for k, a in arrs.items():
                    base = self._array_ndim.get(k, 0)
                    v = a if a.ndim == base else a[i]
                    rec[k] = np.asarray(v) if base else float(v)
                out.append((start + i, rec))
        self._pending.clear()
        return out
