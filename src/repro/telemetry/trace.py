"""Chrome-trace span recording for the round lifecycle.

Zero-dependency (stdlib only) tracer producing Chrome-trace / Perfetto
JSON.  Spans are *host-side* timers: ``with span("dispatch"): ...``
records one complete ("X") event with microsecond ``ts``/``dur``
against the calling thread's id, so producer-thread work from
``HostPrefetcher`` shows up on its own track in the viewer.

Spans never touch the traced XLA program.  Inside jitted code bodies
(``core/rounds.py``) spans fire only while jax *traces* the function —
they time program construction, not device execution — and are emitted
under the ``trace`` category so the viewer groups them separately.

When no session is installed (`install()` not called), ``span()``
returns a shared no-op context manager: tracing fully off costs one
global read per call site and records nothing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List


class _NullSpan:
    """Shared no-op context manager returned when tracing is off."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One open span; records an "X" complete event on exit."""
    __slots__ = ("_tracer", "_name", "_cat", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str):
        self._tracer = tracer
        self._name = name
        self._cat = cat

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self._tracer._record(self._name, self._cat, self._t0,
                             time.perf_counter())
        return False


class Tracer:
    """Collects spans from any thread; exports Chrome-trace JSON."""

    def __init__(self) -> None:
        self.pid = os.getpid()
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._threads_seen: Dict[int, str] = {}

    def span(self, name: str, cat: str = "host") -> _Span:
        return _Span(self, name, cat)

    def _record(self, name: str, cat: str, t0: float, t1: float) -> None:
        thread = threading.current_thread()
        tid = thread.ident or 0
        ev = {
            "name": name, "cat": cat, "ph": "X",
            "ts": (t0 - self._t0) * 1e6, "dur": (t1 - t0) * 1e6,
            "pid": self.pid, "tid": tid,
        }
        with self._lock:
            if tid not in self._threads_seen:
                self._threads_seen[tid] = thread.name
            self._events.append(ev)

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of recorded span events (no metadata events)."""
        with self._lock:
            return list(self._events)

    def trace_json(self) -> Dict[str, Any]:
        """Chrome-trace document: metadata events + complete events."""
        with self._lock:
            meta = [{
                "name": "thread_name", "ph": "M", "pid": self.pid,
                "tid": tid, "args": {"name": tname},
            } for tid, tname in sorted(self._threads_seen.items())]
            return {"traceEvents": meta + list(self._events),
                    "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the trace document to ``path`` (JSON); returns path."""
        doc = self.trace_json()
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(doc, fh)
        os.replace(tmp, path)
        return path


def aggregate_spans(events: List[Dict[str, Any]]
                    ) -> Dict[str, Dict[str, float]]:
    """Per-span-name stats {name: {count, total_ms, mean_ms, max_ms}}."""
    agg: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        st = agg.setdefault(ev["name"],
                            {"count": 0.0, "total_ms": 0.0, "max_ms": 0.0})
        dur_ms = ev["dur"] / 1e3
        st["count"] += 1
        st["total_ms"] += dur_ms
        st["max_ms"] = max(st["max_ms"], dur_ms)
    for st in agg.values():
        st["mean_ms"] = st["total_ms"] / st["count"]
    return agg
