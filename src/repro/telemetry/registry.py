"""Counters/gauges registry unifying the repo's scattered run metrics.

``Counter`` is a monotonically-added float (thread-safe: the prefetcher
producer thread adds to it); ``Gauge`` is a last-value float.  A
``Registry`` names them; asking for an existing name returns the same
object (prometheus-style), so two components that agree on a name share
one accumulator — that is the "one source of truth" contract between
``HostPrefetcher.wait_s`` and ``benchmarks/round_throughput.py``.

Canonical names (see docs/observability.md for the full catalog):

====================================  =======  ==========================
name                                  kind     meaning
====================================  =======  ==========================
``prefetch/wait_s``                   counter  consumer blocked on queue
``prefetch/produce_s``                counter  producer assemble+stage
``prefetch/queue_depth``              gauge    queue fill after last put
``scenario/valid_step_frac``          gauge    straggler-valid step frac
``round/cohort_size``                 gauge    sampled clients last round
``rounds/completed``                  counter  rounds dispatched
``comm/wire_bytes_total``             counter  uploaded wire bytes
``dp/epsilon``                        gauge    RDP ε at last eval round
``faults/injected``                   counter  faulted uploads injected
``faults/rejected_uploads``           counter  uploads the defense zeroed
``rounds/quorum_skipped``             counter  rounds frozen by quorum
``watchdog/rollbacks``                counter  checkpoint rollbacks taken
``prefetch/shutdown_abandoned``       gauge    1 if close() hit deadline
``jit/compiles``                      counter  jit cache entries compiled
``jit/compile_s``                     counter  wall time inside compiles
``jit/steady_state_recompiles``       counter  recompiles of a seen
                                               program signature (== 0
                                               in a healthy run)
``mem/live_bytes``                    gauge    device bytes in use at
                                               last eval boundary
``mem/peak_bytes``                    gauge    peak device bytes in use
``ledger/rounds_recorded``            counter  flight-recorder rounds
``ledger/exports``                    counter  ledger npz+manifest writes
====================================  =======  ==========================

Usage::

    >>> from repro.telemetry.registry import Registry
    >>> reg = Registry()
    >>> reg.counter("prefetch/wait_s").add(0.25)
    >>> reg.counter("prefetch/wait_s") is reg.counter("prefetch/wait_s")
    True
    >>> reg.gauge("round/cohort_size").set(8)
    >>> reg.snapshot()
    {'prefetch/wait_s': 0.25, 'round/cohort_size': 8.0}
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Union

#: The machine-readable form of the docstring table above: every metric
#: name that may appear as a STRING LITERAL in source. The RA104 lint
#: rule (``repro.analysis``) checks literal ``telemetry.add(...)`` /
#: ``set_gauge(...)`` / ``counter(...)`` / ``gauge(...)`` names against
#: this catalog — a typo'd name silently registers a second accumulator
#: and splits the metric. Dynamically-built names (round diagnostics'
#: ``diag/...`` keys, test scratch names) are out of scope by design.
#: Keep this dict, the table above, and docs/observability.md in sync.
CANONICAL_METRICS: Dict[str, str] = {
    "prefetch/wait_s": "counter",
    "prefetch/produce_s": "counter",
    "prefetch/queue_depth": "gauge",
    "scenario/valid_step_frac": "gauge",
    "round/cohort_size": "gauge",
    "rounds/completed": "counter",
    "comm/wire_bytes_total": "counter",
    "dp/epsilon": "gauge",
    "faults/injected": "counter",
    "faults/rejected_uploads": "counter",
    "rounds/quorum_skipped": "counter",
    "watchdog/rollbacks": "counter",
    "prefetch/shutdown_abandoned": "gauge",
    "jit/compiles": "counter",
    "jit/compile_s": "counter",
    "jit/steady_state_recompiles": "counter",
    "mem/live_bytes": "gauge",
    "mem/peak_bytes": "gauge",
    "ledger/rounds_recorded": "counter",
    "ledger/exports": "counter",
}


class Counter:
    """Thread-safe monotonically-increasing float."""
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def add(self, x: float) -> None:
        with self._lock:
            self._value += float(x)

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-written float value."""
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, x: float) -> None:
        self._value = float(x)

    @property
    def value(self) -> float:
        return self._value


class Registry:
    """Named counters and gauges; name collisions return the same
    object so independent components can share an accumulator."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Union[Counter, Gauge]] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"telemetry metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def value(self, name: str, default: float = 0.0) -> float:
        m = self._metrics.get(name)
        return default if m is None else m.value

    def snapshot(self) -> Dict[str, float]:
        """{name: value} for every registered counter/gauge."""
        with self._lock:
            return {k: v.value for k, v in sorted(self._metrics.items())}

    def export(self, path: str) -> str:
        """Write the snapshot to ``path`` as JSON; returns path."""
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.snapshot(), fh, indent=2, sort_keys=True)
        os.replace(tmp, path)
        return path
