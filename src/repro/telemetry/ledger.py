"""Per-client flight recorder (docs/observability.md).

The PR 6 diagnostics answer "how much drift / v̄ variance this round,
on average"; this module answers "which CLIENT drifted, got clipped,
was dropped or rejected, and how many bytes it put on the wire" — the
per-client view of the paper's Figure-2 decomposition that the async
and personalization roadmap items need.

Device side: when ``FedConfig.telemetry_ledger`` is on,
``core.rounds`` adds a handful of per-client scalar stats to the
local-phase metrics (``led_*`` keys), strips them back out of the
cross-client metric reduction, and attaches one ``(S, n_stats)``
f32 block per round to the output metrics under
:data:`LEDGER_METRIC_KEY`. The block rides the existing
:class:`~repro.metrics.MetricsSpool` exactly like any scalar metric —
no extra host sync, and under ``rounds_per_call`` fusion it comes back
``(M, S, n_stats)``-stacked with everything else. Both placement
layouts funnel through the same :func:`finalize_ledger_block`, so the
recorded math is identical by construction.

Host side: :class:`FlightRecorder` collects the blocks the launcher
pops off each spool flush, scales the wire column by the static
per-client wire bytes, and spills an atomic ``ledger.npz`` + JSON
manifest — exported on crash through the same ``finally`` path as the
trace files. ``tools/ledger_report.py`` renders it stdlib-only.

Off (default) is statically gated: no keys, byte-identical jaxpr
(RA201 rows ``ledger_off[*]`` in ``analysis/jaxpr_audit.py``).
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.faults.defense import (INJECTED_CODES, VERDICT_CODES,
                                  injected_codes, verdict_codes)
from repro.telemetry.diagnostics import tree_sqnorm

Tree = Dict[str, object]

#: metrics-dict key the per-round ``(S, n_stats)`` block rides under.
#: The leading underscore keeps it out of CSV/history scalar paths —
#: the launcher pops it right after every spool flush.
LEDGER_METRIC_KEY = "_ledger"

#: column order of the stats block (axis -1). ``wire_bytes`` is
#: recorded on device as a 0/1 arrival indicator and scaled by the
#: static per-client wire bytes in :meth:`FlightRecorder.record`.
LEDGER_COLUMNS = (
    "client_id",       # population client id (f32 cast)
    "steps",           # local steps actually executed (straggler mask)
    "upload_l2",       # L2 norm of this client's upload delta
    "drift_sq",        # ||delta_i||^2 - ||mean delta||^2 — this
    #                    client's contribution to the Fig. 2 drift
    #                    variance (mean over clients = drift RMS^2)
    "dp_clipped",      # 1.0 if the DP clip actually bit (raw norm > C)
    "wire_bytes",      # arrival indicator on device; bytes after record
    "fault_injected",  # defense.INJECTED_CODES
    "verdict",         # defense.VERDICT_CODES
)

# device-side per-client scalar stat keys riding the local-phase
# metrics dict (vmapped / scanned with everything else, then stripped
# from the cross-client reduction by split_ledger_stats)
LEDGER_STAT_PREFIX = "led_"
LED_STEPS = "led_steps"          # executed local steps
LED_UPLOAD_SQ = "led_upload_sq"  # squared L2 of the upload delta
LED_CLIP_SQ = "led_clip_sq"      # PRE-clip squared L2 (dp_clip > 0 only)


def local_ledger_stats(raw_sq: Optional[jax.Array],
                       upload_delta: Tree,
                       *, step_valid: Optional[jax.Array],
                       num_steps: int) -> Dict[str, jax.Array]:
    """Per-client scalar stats computed inside the local phase.

    ``raw_sq`` is the squared norm of the raw (pre-DP-clip) delta —
    pass ``None`` when DP is off and the clip-activation column should
    stay statically absent.
    """
    if step_valid is not None:
        steps = step_valid.astype(jnp.float32).sum()
    else:
        steps = jnp.full((), num_steps, jnp.float32)
    led = {LED_STEPS: steps, LED_UPLOAD_SQ: tree_sqnorm(upload_delta)}
    if raw_sq is not None:
        led[LED_CLIP_SQ] = raw_sq
    return led


def split_ledger_stats(metrics: Tree) -> Tuple[Tree, Dict[str, jax.Array]]:
    """Pop the ``led_*`` stat keys out of a metrics dict so they bypass
    the cross-client metric reduction (mean in the parallel layout,
    online sum in the sequential scan)."""
    rest = dict(metrics)
    led = {k: rest.pop(k) for k in list(rest)
           if k.startswith(LEDGER_STAT_PREFIX)}
    return rest, led


def finalize_ledger_block(led: Dict[str, jax.Array],
                          *, client_ids: jax.Array,
                          mean_delta_sq: jax.Array,
                          dp_clip: float,
                          arrived: Optional[jax.Array] = None,
                          valid: Optional[jax.Array] = None,
                          injected: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Assemble the ``(S, n_stats)`` block from (S,)-shaped per-client
    ingredients. Shared by both layouts: the parallel layout passes
    vmapped vectors, the sequential layout passes its scan-stacked
    outputs — every column is elementwise from there, so the layouts
    agree bit-for-bit given equal inputs.
    """
    cid = jnp.asarray(client_ids).astype(jnp.float32)
    s = cid.shape[0]
    upload_sq = led[LED_UPLOAD_SQ]
    cols = {
        "client_id": cid,
        "steps": led[LED_STEPS],
        "upload_l2": jnp.sqrt(upload_sq),
        "drift_sq": upload_sq - mean_delta_sq,
    }
    if LED_CLIP_SQ in led:
        clip_sq = jnp.float32(float(dp_clip) ** 2)
        cols["dp_clipped"] = (led[LED_CLIP_SQ] > clip_sq).astype(
            jnp.float32)
    else:
        cols["dp_clipped"] = jnp.zeros((s,), jnp.float32)
    arr = (jnp.ones((s,), jnp.bool_) if arrived is None
           else jnp.asarray(arrived, jnp.bool_))
    cols["wire_bytes"] = arr.astype(jnp.float32)
    inj = injected
    cols["fault_injected"] = (jnp.zeros((s,), jnp.float32)
                              if inj is None else jnp.asarray(inj))
    if arrived is None and valid is None:
        cols["verdict"] = jnp.zeros((s,), jnp.float32)
    else:
        cols["verdict"] = verdict_codes(arrived, valid)
    return jnp.stack([jnp.broadcast_to(cols[name], (s,))
                      for name in LEDGER_COLUMNS], axis=-1)


# ----------------------------------------------------------- host side

LEDGER_NPZ = "ledger.npz"
LEDGER_MANIFEST = "ledger_manifest.json"

_WIRE_COL = LEDGER_COLUMNS.index("wire_bytes")


class FlightRecorder:
    """Host-side accumulator for per-round ledger blocks.

    The launcher pops :data:`LEDGER_METRIC_KEY` off every spool flush
    and feeds the blocks here; ``export()`` writes ``ledger.npz``
    (arrays ``rounds`` (R,) and ``stats`` (R, S, n_stats)) plus a JSON
    manifest, both atomically (tmp + ``os.replace``), so a crash
    mid-export never leaves a torn file. ``trim()`` mirrors the
    watchdog's history rollback: rounds at or past the resume point are
    re-recorded after the retry.
    """

    def __init__(self, ledger_dir: str, *,
                 wire_bytes_per_client: int = 0,
                 meta: Optional[dict] = None):
        self.ledger_dir = ledger_dir
        self.wire_bytes_per_client = int(wire_bytes_per_client)
        self.meta = dict(meta or {})
        self._rows: Dict[int, "object"] = {}  # round -> (S, C) ndarray

    def record(self, round_index: int, block) -> None:
        import numpy as np
        blk = np.array(block, dtype=np.float32, copy=True)
        if blk.ndim != 2 or blk.shape[-1] != len(LEDGER_COLUMNS):
            raise ValueError(f"ledger block shape {blk.shape} != "
                             f"(S, {len(LEDGER_COLUMNS)})")
        if self.wire_bytes_per_client:
            blk[:, _WIRE_COL] *= self.wire_bytes_per_client
        self._rows[int(round_index)] = blk
        telemetry.add("ledger/rounds_recorded", 1)

    def trim(self, resume_round: int) -> None:
        """Drop rounds >= ``resume_round`` (watchdog rollback)."""
        for r in [r for r in self._rows if r >= resume_round]:
            del self._rows[r]

    def __len__(self) -> int:
        return len(self._rows)

    def export(self) -> str:
        import numpy as np
        os.makedirs(self.ledger_dir, exist_ok=True)
        rounds = sorted(self._rows)
        stats = (np.stack([self._rows[r] for r in rounds])
                 if rounds else np.zeros((0, 0, len(LEDGER_COLUMNS)),
                                         np.float32))
        npz_path = os.path.join(self.ledger_dir, LEDGER_NPZ)
        tmp = npz_path + ".tmp"
        with open(tmp, "wb") as fh:
            np.savez_compressed(
                fh, rounds=np.asarray(rounds, np.int64), stats=stats)
        os.replace(tmp, npz_path)
        manifest = {
            "columns": list(LEDGER_COLUMNS),
            "injected_codes": INJECTED_CODES,
            "verdict_codes": VERDICT_CODES,
            "rounds_recorded": len(rounds),
            "clients_per_round": int(stats.shape[1]) if rounds else 0,
            "wire_bytes_per_client": self.wire_bytes_per_client,
            "wire_col_scaled": bool(self.wire_bytes_per_client),
            "meta": self.meta,
        }
        man_path = os.path.join(self.ledger_dir, LEDGER_MANIFEST)
        tmp = man_path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=1, sort_keys=True)
        os.replace(tmp, man_path)
        telemetry.add("ledger/exports", 1)
        return self.ledger_dir


def load_ledger(ledger_dir: str):
    """Load an exported flight recording: ``(manifest, rounds, stats)``
    with ``rounds`` (R,) int64 and ``stats`` (R, S, n_stats) f32."""
    import numpy as np
    with open(os.path.join(ledger_dir, LEDGER_MANIFEST)) as fh:
        manifest = json.load(fh)
    with np.load(os.path.join(ledger_dir, LEDGER_NPZ)) as npz:
        rounds, stats = npz["rounds"], npz["stats"]
    return manifest, rounds, stats
