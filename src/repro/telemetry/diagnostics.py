"""Live model-quality diagnostics: the paper's Figure-2 quantities.

FedAdamW's analysis (Theorem 1) bounds client drift ``||Δᵢ − Δ̄||`` and
its Figure 2 plots (a) the cross-client variance of the second-moment
mean v̄ and (b) the client-drift norm over rounds.  The repo could only
measure them post-hoc (``benchmarks/fig2_variance_drift.py`` re-runs
local phases outside the engine); these helpers compute per-round
equivalents *inside* the jitted round program from scalar accumulators,
so they ride the existing metrics path (``MetricsSpool`` — no extra
host syncs) in both client layouts.

Per-client scalars added by ``make_local_phase`` when
``fed.telemetry_diagnostics`` is on:

* ``diag_delta_sqnorm`` — ``||Δᵢ||²`` of the client's raw local delta;
* ``diag_v_sqnorm``     — ``||vᵢ||²`` of the client's uploaded second
  moment (``v_mean`` block means or ``v_full``), when the algorithm
  uploads one.

Both layouts reduce metrics with the *uniform client mean* (vmap+mean
in ``client_parallel``, online sum x 1/S in ``client_sequential``), so
after reduction the metrics hold ``E_i[||xᵢ||²]``.  The round function
then calls :func:`attach_round_diagnostics` with the **pre-noise**
aggregated upload and the identity ``E‖x − x̄‖² = E‖x‖² − ‖x̄‖²``
(uniform mean) turns the scalars into:

* ``client_drift_rms``  = sqrt(max(0, E_i‖Δᵢ‖² − ‖Δ̄‖²))
  — the RMS of Figure 2(b)'s drift ‖Δᵢ − Δ̄‖;
* ``v_bar_variance``    = max(0, E_i‖vᵢ‖² − ‖v̄‖²) / numel(v)
  — per-element cross-client variance of the v-upload, Figure 2(a).

The ``max(0, ·)`` clamp guards float cancellation and the two engine
paths where the decomposition is approximate by design: upload codecs
(Δ̄ averages *decoded* deltas while ‖Δᵢ‖² measures the raw ones) and the
fused clipacc kernel (Δ̄ is clipped, the per-client scalars are not).
Weighted aggregation scenarios reuse the uniform-mean identity as an
approximation — the gauges are diagnostics, not training inputs.

Everything here is statically gated: with ``telemetry_diagnostics``
off (the default) no key is added and the traced program is exactly
the pre-telemetry engine's.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

# upload entries that carry the second-moment payload, by algorithm:
# fedadamw uploads "v_mean" (block means) or "v_full"; fedlada "v_full"
V_ENTRY_KEYS = ("v_mean", "v_full")

DELTA_SQNORM_KEY = "diag_delta_sqnorm"
V_SQNORM_KEY = "diag_v_sqnorm"

# metric keys attach_round_diagnostics emits (train.py logs these)
DIAGNOSTIC_KEYS = ("client_drift_rms", "v_bar_variance")


def tree_sqnorm(tree) -> jax.Array:
    """Scalar f32 squared L2 norm over every leaf of a pytree."""
    leaves = jax.tree.leaves(tree)
    total = jnp.zeros((), jnp.float32)
    for leaf in leaves:
        x = leaf.astype(jnp.float32)
        total = total + jnp.sum(x * x)
    return total


def tree_numel(tree) -> int:
    """Static total element count over a pytree's leaves."""
    return sum(leaf.size for leaf in jax.tree.leaves(tree))


def v_entry_key(upload) -> str:
    """Name of the second-moment entry in an upload dict ('' if none).
    Key presence is pytree structure, so this is a static decision."""
    for k in V_ENTRY_KEYS:
        if k in upload:
            return k
    return ""


def local_diagnostics(delta, upload) -> Dict[str, jax.Array]:
    """Per-client scalar accumulators added to the metrics dict."""
    out = {DELTA_SQNORM_KEY: tree_sqnorm(delta)}
    vk = v_entry_key(upload)
    if vk:
        out[V_SQNORM_KEY] = tree_sqnorm(upload[vk])
    return out


def attach_round_diagnostics(metrics: Dict[str, jax.Array], mean_up
                             ) -> Dict[str, jax.Array]:
    """Replace the client-meaned sqnorm accumulators with the round
    gauges, using the PRE-noise aggregated upload ``mean_up``."""
    out = dict(metrics)
    mean_sq = out.pop(DELTA_SQNORM_KEY)
    drift_var = jnp.maximum(mean_sq - tree_sqnorm(mean_up["delta"]), 0.0)
    out["client_drift_rms"] = jnp.sqrt(drift_var)
    mean_vsq = out.pop(V_SQNORM_KEY, None)
    if mean_vsq is not None:
        vk = v_entry_key(mean_up)
        vbar = mean_up[vk]
        var = jnp.maximum(mean_vsq - tree_sqnorm(vbar), 0.0)
        out["v_bar_variance"] = var / tree_numel(vbar)
    return out
