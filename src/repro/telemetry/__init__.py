"""Telemetry: round-lifecycle tracing, counters/gauges, diagnostics.

One module-level **session** holds a :class:`Tracer` plus a
:class:`Registry`.  It is installed with::

    with telemetry.session(trace_dir="out/run1") as tele:
        ...   # spans + counters record; exported on exit

or left uninstalled, in which case every ``span()`` returns a shared
no-op and ``counter()``/``gauge()`` hand out *free-floating* metrics
(still usable by the caller that holds the reference, just not
aggregated or exported).  A plain module-level global — not a
contextvar — is deliberate: the ``HostPrefetcher`` producer *thread*
must see the same session as the training loop, and contextvars do not
propagate to already-running threads.

Device-side diagnostics (the paper's Figure-2 quantities) live in
:mod:`repro.telemetry.diagnostics` and are gated statically by
``FedConfig.telemetry_diagnostics`` — off means the traced XLA program
is byte-identical to an engine built before this subsystem existed.
"""
from __future__ import annotations

import os
import threading
from typing import Optional

from repro.telemetry.registry import Counter, Gauge, Registry
from repro.telemetry.trace import NULL_SPAN, Tracer, aggregate_spans

__all__ = [
    "Counter", "Gauge", "Registry", "Tracer", "aggregate_spans",
    "Session", "session", "install", "uninstall", "active",
    "span", "counter", "gauge", "add", "set_gauge",
    "TRACE_FILE", "COUNTERS_FILE",
]

TRACE_FILE = "trace.json"
COUNTERS_FILE = "counters.json"


class Session:
    """A tracer + registry pair with optional on-exit export."""

    def __init__(self, trace_dir: Optional[str] = None):
        self.trace_dir = trace_dir
        self.tracer = Tracer()
        self.counters = Registry()

    # -- context manager: install as the active session, export on exit
    def __enter__(self) -> "Session":
        install(self)
        return self

    def __exit__(self, *exc: object) -> bool:
        uninstall(self)
        self.export()
        return False

    def export(self) -> Optional[str]:
        """Write trace.json + counters.json to ``trace_dir`` (if set)."""
        if not self.trace_dir:
            return None
        os.makedirs(self.trace_dir, exist_ok=True)
        self.tracer.export(os.path.join(self.trace_dir, TRACE_FILE))
        self.counters.export(os.path.join(self.trace_dir, COUNTERS_FILE))
        return self.trace_dir


_LOCK = threading.Lock()
_ACTIVE: Optional[Session] = None


def session(trace_dir: Optional[str] = None) -> Session:
    """New session; use as ``with telemetry.session(...) as tele:``."""
    return Session(trace_dir)


def install(sess: Session) -> None:
    global _ACTIVE
    with _LOCK:
        _ACTIVE = sess


def uninstall(sess: Session) -> None:
    """Deactivate ``sess`` if it is the active session (idempotent)."""
    global _ACTIVE
    with _LOCK:
        if _ACTIVE is sess:
            _ACTIVE = None


def active() -> Optional[Session]:
    return _ACTIVE


def span(name: str, cat: str = "host"):
    """Span against the active session, or a shared no-op when none."""
    sess = _ACTIVE
    return NULL_SPAN if sess is None else sess.tracer.span(name, cat)


def counter(name: str) -> Counter:
    """Named counter from the active session, else free-floating."""
    sess = _ACTIVE
    return Counter(name) if sess is None else sess.counters.counter(name)


def gauge(name: str) -> Gauge:
    """Named gauge from the active session, else free-floating."""
    sess = _ACTIVE
    return Gauge(name) if sess is None else sess.counters.gauge(name)


def add(name: str, x: float) -> None:
    """Add to a session counter; no-op when no session is active."""
    sess = _ACTIVE
    if sess is not None:
        sess.counters.counter(name).add(x)


def set_gauge(name: str, x: float) -> None:
    """Set a session gauge; no-op when no session is active."""
    sess = _ACTIVE
    if sess is not None:
        sess.counters.gauge(name).set(x)
