from repro.checkpoint.checkpointer import (CorruptCheckpointError,
                                           list_checkpoints,
                                           restore_checkpoint,
                                           save_checkpoint)

__all__ = ["CorruptCheckpointError", "list_checkpoints",
           "restore_checkpoint", "save_checkpoint"]
