"""Minimal sharded-tree checkpointer.

Flattens any pytree (params + server state) into path-keyed arrays stored in
one ``.npz`` plus a JSON manifest carrying round index, tree structure and
the PartitionSpec of every leaf, so a restore onto a different mesh can
re-shard with ``jax.device_put``. No external deps (container is offline).

Writes are ATOMIC: the ``.npz``/``.json`` payloads land in temp files in
the same directory and are ``os.replace``-d into place (payloads first,
the ``latest`` pointer last), so a mid-write kill leaves either the
previous complete checkpoint or the new complete checkpoint — never a
truncated ``.npz`` that ``latest`` points at. Failed writes clean their
temp files up.

The training driver (``repro.launch.train``) wires this in via
``--ckpt-dir/--ckpt-every/--resume``; resume replays the batch
generator's rng stream for the completed rounds, so ``train R`` and
``train R/2 + resume R/2`` are trajectory-identical (tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "\x1f"  # unit separator: safe against '/' or '.' in keys


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = SEP.join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        out[key] = np.asarray(leaf)
    return out


def _atomic_write(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace`` (atomic on
    POSIX), cleaning the temp up if the write itself dies."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def save_checkpoint(directory: str, step: int, *, params, server_state=None,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically persist ``params`` (+ optional server state) as
    ``ckpt_<step>.npz`` + ``.json`` and repoint ``latest``. The pointer
    is replaced LAST, after both payloads are complete on disk."""
    os.makedirs(directory, exist_ok=True)
    name = f"ckpt_{step:08d}"
    path = os.path.join(directory, name)
    arrays = {}
    for prefix, tree in (("params", params), ("state", server_state)):
        if tree is None:
            continue
        for k, v in _flatten(tree).items():
            arrays[prefix + SEP + k] = v
    manifest = {"step": step, "extra": extra or {},
                "keys": sorted(arrays.keys())}
    # np.savez appends ".npz" to bare paths but writes file objects
    # verbatim, which is what lets the temp file carry the .tmp suffix
    _atomic_write(path + ".npz", lambda f: np.savez(f, **arrays))
    _atomic_write(path + ".json",
                  lambda f: f.write(json.dumps(manifest).encode()))
    _atomic_write(os.path.join(directory, "latest"),
                  lambda f: f.write(name.encode()))
    return path


def _unflatten_into(template, stored: Dict[str, np.ndarray], prefix: str):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = prefix + SEP + SEP.join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = stored[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {tuple(leaf.shape)}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(directory: str, *, params_template,
                       state_template=None,
                       step: Optional[int] = None) -> Tuple[Any, Any, int]:
    if step is None:
        with open(os.path.join(directory, "latest")) as f:
            name = f.read().strip()
    else:
        name = f"ckpt_{step:08d}"
    stored = dict(np.load(os.path.join(directory, name + ".npz")))
    with open(os.path.join(directory, name + ".json")) as f:
        manifest = json.load(f)
    params = _unflatten_into(params_template, stored, "params")
    state = (None if state_template is None
             else _unflatten_into(state_template, stored, "state"))
    return params, state, manifest["step"]
