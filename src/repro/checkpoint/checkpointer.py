"""Minimal sharded-tree checkpointer.

Flattens any pytree (params + server state) into path-keyed arrays stored in
one ``.npz`` plus a JSON manifest carrying round index, tree structure and
the PartitionSpec of every leaf, so a restore onto a different mesh can
re-shard with ``jax.device_put``. No external deps (container is offline).

Writes are ATOMIC: the ``.npz``/``.json`` payloads land in temp files in
the same directory and are ``os.replace``-d into place (payloads first,
the ``latest`` pointer last), so a mid-write kill leaves either the
previous complete checkpoint or the new complete checkpoint — never a
truncated ``.npz`` that ``latest`` points at. Failed writes clean their
temp files up.

Restores are VERIFIED: ``save_checkpoint`` records the SHA-256 of the
finished ``.npz`` in the manifest (hashed AFTER the write completes —
``np.savez`` seeks inside the zip container, so a streaming hash of the
write would not match the final bytes), and ``restore_checkpoint``
recomputes it before deserializing. A mismatch (bit rot, a torn copy, a
truncation the atomic-write protocol cannot see, e.g. an external sync)
raises :class:`CorruptCheckpointError` — or, when restoring "latest",
falls back to the newest checkpoint in the directory that DOES verify,
so the NaN-watchdog rollback path (docs/faults.md) always lands on
intact state. Pre-checksum manifests (no ``npz_sha256`` key) restore
unverified for compatibility.

The training driver (``repro.launch.train``) wires this in via
``--ckpt-dir/--ckpt-every/--resume``; resume replays the batch
generator's rng stream for the completed rounds, so ``train R`` and
``train R/2 + resume R/2`` are trajectory-identical (tests/test_checkpoint.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "\x1f"  # unit separator: safe against '/' or '.' in keys


class CorruptCheckpointError(RuntimeError):
    """A checkpoint payload failed its integrity check (checksum
    mismatch, unreadable archive, or missing/mangled manifest)."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = SEP.join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        out[key] = np.asarray(leaf)
    return out


def _atomic_write(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace`` (atomic on
    POSIX), cleaning the temp up if the write itself dies."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, *, params, server_state=None,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically persist ``params`` (+ optional server state) as
    ``ckpt_<step>.npz`` + ``.json`` and repoint ``latest``. The pointer
    is replaced LAST, after both payloads are complete on disk; the
    manifest carries the SHA-256 of the completed ``.npz``."""
    os.makedirs(directory, exist_ok=True)
    name = f"ckpt_{step:08d}"
    path = os.path.join(directory, name)
    arrays = {}
    for prefix, tree in (("params", params), ("state", server_state)):
        if tree is None:
            continue
        for k, v in _flatten(tree).items():
            arrays[prefix + SEP + k] = v
    # np.savez appends ".npz" to bare paths but writes file objects
    # verbatim, which is what lets the temp file carry the .tmp suffix
    _atomic_write(path + ".npz", lambda f: np.savez(f, **arrays))
    manifest = {"step": step, "extra": extra or {},
                "keys": sorted(arrays.keys()),
                "npz_sha256": _sha256_file(path + ".npz")}
    _atomic_write(path + ".json",
                  lambda f: f.write(json.dumps(manifest).encode()))
    _atomic_write(os.path.join(directory, "latest"),
                  lambda f: f.write(name.encode()))
    return path


def _unflatten_into(template, stored: Dict[str, np.ndarray], prefix: str):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = prefix + SEP + SEP.join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = stored[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {tuple(leaf.shape)}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _load_verified(directory: str, name: str):
    """Load ``(stored, manifest)`` for one checkpoint, raising
    :class:`CorruptCheckpointError` on any integrity failure."""
    npz_path = os.path.join(directory, name + ".npz")
    json_path = os.path.join(directory, name + ".json")
    try:
        with open(json_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"unreadable manifest for {name}: {e}") from e
    digest = manifest.get("npz_sha256")
    if digest is not None and _sha256_file(npz_path) != digest:
        raise CorruptCheckpointError(
            f"checksum mismatch for {name}.npz — payload corrupt")
    try:
        stored = dict(np.load(npz_path))
    except Exception as e:  # zipfile raises several unrelated types
        raise CorruptCheckpointError(
            f"unreadable archive {name}.npz: {e}") from e
    return stored, manifest


def list_checkpoints(directory: str):
    """Checkpoint base names present in ``directory``, newest first."""
    names = [f[:-len(".json")] for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".json")]
    return sorted(names, reverse=True)


def restore_checkpoint(directory: str, *, params_template,
                       state_template=None,
                       step: Optional[int] = None) -> Tuple[Any, Any, int]:
    """Restore the checkpoint at ``step``, or the newest VALID one.

    With an explicit ``step``, an integrity failure raises
    :class:`CorruptCheckpointError` — the caller asked for those exact
    bytes. With ``step=None``, the ``latest`` pointer is tried first and
    every remaining checkpoint is then scanned newest-first, skipping
    (with a warning) any that fail verification, so one flipped bit or
    truncated file degrades to the previous save instead of killing the
    run.
    """
    if step is not None:
        stored, manifest = _load_verified(directory, f"ckpt_{step:08d}")
    else:
        candidates = list_checkpoints(directory)
        try:
            with open(os.path.join(directory, "latest")) as f:
                latest = f.read().strip()
            if latest in candidates:
                candidates.remove(latest)
                candidates.insert(0, latest)
        except OSError:
            pass
        if not candidates:
            raise FileNotFoundError(
                f"no checkpoints found in {directory!r}")
        stored = manifest = None
        errors = []
        for name in candidates:
            try:
                stored, manifest = _load_verified(directory, name)
                break
            except CorruptCheckpointError as e:
                errors.append(str(e))
                warnings.warn(
                    f"skipping corrupt checkpoint {name}: {e}",
                    stacklevel=2)
        if stored is None:
            raise CorruptCheckpointError(
                "every checkpoint in {!r} failed verification: {}".format(
                    directory, "; ".join(errors)))
    params = _unflatten_into(params_template, stored, "params")
    state = (None if state_template is None
             else _unflatten_into(state_template, stored, "state"))
    return params, state, manifest["step"]
