"""NaN-watchdog: detect post-aggregation global-state divergence and
drive checkpoint rollback (docs/faults.md has the state machine).

The robust-aggregation layer defends the server boundary; the watchdog
is the last line behind it — if non-finite values DO reach the global
params (defense off, quorum too low, a genuinely diverged trajectory),
training must not silently continue multiplying NaN into every
subsequent round, and it must not crash without exporting telemetry.

``NaNWatchdog.healthy`` is one jitted whole-tree finite check; the
driver calls it once per round block and raises
:class:`WatchdogRollback` on corruption. ``repro.launch.train`` then
restores the newest valid checkpoint (``repro.checkpoint`` verifies
content checksums and skips corrupt payloads), replays the data
stream's rng to the restored round, and retries — at most
``max_rollbacks`` times before aborting cleanly with the telemetry
artifacts exported and the ``watchdog/rollbacks`` counter recording
every attempt.

The check costs one device->host scalar sync per block, which is why it
is opt-in (``--watchdog``). Note the engine is deterministic: when the
corruption comes from a seeded fault schedule, the replay hits the same
fault again — the retry budget exists for the nondeterministic failures
of real deployments (hardware faults, preempted writes) and, in the
deterministic simulator, bounds the run to a clean abort instead of an
infinite rollback loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class WatchdogRollback(Exception):
    """Raised by the training driver when the watchdog finds non-finite
    global state after committing round ``round_index``."""

    def __init__(self, round_index: int, bad_leaves: int):
        self.round_index = int(round_index)
        self.bad_leaves = int(bad_leaves)
        super().__init__(
            f"non-finite global state after round {round_index} "
            f"({bad_leaves} corrupt leaves)")


class NaNWatchdog:
    """Finite-check the global (params, server-state) trees.

    >>> wd = NaNWatchdog()
    >>> wd.healthy({"w": jnp.ones((2, 2))})
    True
    >>> wd.healthy({"w": jnp.array([1.0, jnp.nan])})
    False
    """

    def __init__(self, max_rollbacks: int = 2):
        if max_rollbacks < 0:
            raise ValueError(
                f"max_rollbacks must be >= 0, got {max_rollbacks}")
        self.max_rollbacks = max_rollbacks
        self.rollbacks = 0

        @jax.jit
        def _bad_leaf_count(tree):
            # count LEAVES with any non-finite element (not elements:
            # the count is a diagnostic, and per-leaf alls reduce small)
            leaves = jax.tree.leaves(tree)
            acc = jnp.zeros((), jnp.int32)
            for leaf in leaves:
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    continue
                ok = jnp.all(jnp.isfinite(leaf))
                acc = acc + jnp.where(ok, 0, 1).astype(jnp.int32)
            return acc

        self._bad_leaf_count = _bad_leaf_count

    def bad_leaves(self, *trees) -> int:
        """Number of float leaves holding any non-finite element, summed
        over the given trees (one blocking device fetch)."""
        return sum(int(self._bad_leaf_count(t)) for t in trees
                   if t is not None)

    def healthy(self, *trees) -> bool:
        return self.bad_leaves(*trees) == 0

    def check(self, round_index: int, *trees) -> None:
        """Raise :class:`WatchdogRollback` if any tree is corrupt."""
        bad = self.bad_leaves(*trees)
        if bad:
            raise WatchdogRollback(round_index, bad)

    def budget_left(self) -> bool:
        return self.rollbacks < self.max_rollbacks
