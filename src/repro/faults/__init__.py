"""Fault injection + graceful degradation (docs/faults.md).

The ``scenario`` package models WHO shows up and how much work they
finish; this package models what goes WRONG after the cohort is drawn —
the failure modes a deployed FedAdamW server actually sees:

``injection``   :class:`FaultModel` — seeded per-round fault schedules
                (upload dropout, NaN/Inf corruption, norm inflation)
                realized as static-shape masks/multipliers riding the
                round batch pytree under reserved keys
``defense``     the server-side upload validator (per-client finite +
                norm-outlier screen) and the robust-aggregation registry
                (``mean`` / ``trimmed<f>`` / ``coordinate_median`` /
                ``norm_filter``), all jittable, no host sync
``watchdog``    :class:`NaNWatchdog` — host-side finite check of the
                post-aggregation global state, driving checkpoint
                rollback with a bounded retry budget

Everything follows the scenario-engine contract: schedules are pure
functions of ``(fault_seed, round_index)`` drawn host-side (never from
the shared batch rng stream), so eager, host-prefetched, and
``rounds_per_call``-fused execution see BIT-identical faults in both
placement layouts; key presence is pytree structure, so the fault-free
config traces the exact pre-fault round program.
"""

# Reserved keys of the round batch pytree (the STEP_MASK_KEY /
# AGG_WEIGHTS_KEY pattern — core.rounds pops them at trace time, the
# leading underscore keeps them out of any model input namespace).
# Both keys are always emitted together when any fault process is
# active, so every active fault config shares one pytree structure.
FAULT_DROP_KEY = "_fault_drop"  # (S,) bool: True = upload never arrived
FAULT_MULT_KEY = "_fault_mult"  # (S,) f32: 1.0 clean, NaN corrupt, or
#                                 the norm-inflation factor

from repro.faults.injection import FaultModel  # noqa: E402
from repro.faults.defense import (  # noqa: E402
    INJECTED_CODES,
    ROBUST_AGGREGATORS,
    VERDICT_CODES,
    apply_fault_mult,
    clamp_nonneg_entries,
    client_sq_norms,
    injected_codes,
    masked_median,
    parse_robust_agg,
    robust_aggregate,
    upload_validity,
    verdict_codes,
)
from repro.faults.watchdog import NaNWatchdog, WatchdogRollback  # noqa: E402

__all__ = [
    "FAULT_DROP_KEY", "FAULT_MULT_KEY",
    "FaultModel",
    "ROBUST_AGGREGATORS", "parse_robust_agg", "apply_fault_mult",
    "upload_validity", "client_sq_norms", "masked_median",
    "robust_aggregate", "clamp_nonneg_entries",
    "INJECTED_CODES", "VERDICT_CODES", "injected_codes", "verdict_codes",
    "NaNWatchdog", "WatchdogRollback",
]
