"""Server-side upload defense: validator + robust-aggregation registry.

Everything here is jittable and traced INTO the round program by
``repro.core.rounds`` — per-client validity is an (S,) bool mask on
device, rejected clients are zero-weighted inside the cross-client
reduction, and the surviving count is a traced scalar (fed to DP noise
scaling and, via the round metrics, to the RDP accountant). No host
sync anywhere.

The registry (``FedConfig.robust_agg``):

``mean``              masked (weighted) mean over the valid clients —
                      the paper's reduction, minus rejected uploads.
                      The only entry the ``client_sequential`` layout
                      supports: it needs no cross-client ranking, so it
                      folds into the scan's online accumulation.
``trimmed<f>``        coordinate-wise trimmed mean: drop the
                      ``floor(f*n)`` smallest and largest values per
                      coordinate among the n valid clients, mean the
                      rest. ``f`` in (0, 0.5).
``coordinate_median`` coordinate-wise median over the valid clients.
``norm_filter``       mean, after additionally rejecting clients whose
                      joint upload L2 norm exceeds ``robust_norm_mult``
                      times the median norm of the finite clients — the
                      defense matched to the norm-inflation fault.

Validity is always at least the per-client finite check over every
aggregated entry (delta, block-mean v, SCAFFOLD dc — client-resident
comm state is never aggregated and never screened); ``norm_filter``
adds the norm-outlier screen. Rank-based entries (trimmed, median)
tolerate norm inflation intrinsically and skip the screen.

Every registry entry clamps the second-moment entries
(``repro.privacy.NONNEG_ENTRIES``) at zero after aggregation — the
coordinate-median / trimmed mean of nonneg values is nonneg in exact
arithmetic, but the clamp makes the invariant unconditional (matching
the post-noise clamp in ``add_round_noise``), because a negative v̄
NaNs the next round's sqrt.

Reductions use the fixed left-to-right association order of
``core.rounds._weighted_mean`` so eager and ``rounds_per_call``-fused
execution stay bit-identical under active faults too.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Dict[str, object]

ROBUST_AGGREGATORS = ("mean", "trimmed", "coordinate_median",
                      "norm_filter")
#: registry entries that reduce by rank across the full (S,) client
#: stack — impossible to fold into the sequential layout's online
#: accumulation, and insensitive to aggregation weights
RANK_BASED = ("trimmed", "coordinate_median")

_DENOM_FLOOR = 1e-12  # guards the all-rejected round's 0/0


def parse_robust_agg(spec: str) -> Tuple[str, float]:
    """``FedConfig.robust_agg`` spec -> ``(kind, trim_frac)``.

    >>> parse_robust_agg("none")
    ('none', 0.0)
    >>> parse_robust_agg("trimmed0.2")
    ('trimmed', 0.2)
    >>> parse_robust_agg("coordinate_median")
    ('coordinate_median', 0.0)
    """
    if spec == "none":
        return "none", 0.0
    if spec in ("mean", "coordinate_median", "norm_filter"):
        return spec, 0.0
    if spec.startswith("trimmed"):
        try:
            frac = float(spec[len("trimmed"):])
        except ValueError:
            raise ValueError(
                f"bad robust_agg spec {spec!r}: 'trimmed' takes the "
                "trim fraction inline, e.g. 'trimmed0.1'") from None
        if not 0.0 < frac < 0.5:
            raise ValueError(
                f"robust_agg trim fraction must be in (0, 0.5), got "
                f"{frac} (trimming half or more leaves nothing to mean)")
        return "trimmed", frac
    raise ValueError(
        f"unknown robust_agg {spec!r}; known: none | mean | "
        "trimmed<f> (e.g. trimmed0.1) | coordinate_median | norm_filter")


def _agg_entries(upload: Tree) -> Tree:
    """The upload entries that cross the wire and get aggregated —
    client-resident comm state (EF residuals) is neither faulted nor
    screened."""
    from repro.comm.error_feedback import COMM_STATE_KEYS
    return {k: v for k, v in upload.items() if k not in COMM_STATE_KEYS}


def apply_fault_mult(upload: Tree, mult: jax.Array, *,
                     stacked: bool = True) -> Tree:
    """Realize NaN-corruption / norm-inflation faults: multiply every
    aggregated entry by the per-client factor (NaN poisons, scale
    inflates, 1.0 is a no-op numerically — but the multiply is always
    traced when the fault keys ride the batch). ``stacked``: entries
    carry a leading (S,) client axis; ``mult`` is then (S,), else a
    scalar (inside the sequential scan)."""
    from repro.comm.error_feedback import COMM_STATE_KEYS

    def scale(u):
        m = (mult.reshape((-1,) + (1,) * (u.ndim - 1)) if stacked
             else mult)
        return (u.astype(jnp.float32) * m).astype(u.dtype)

    return {k: (v if k in COMM_STATE_KEYS else jax.tree.map(scale, v))
            for k, v in upload.items()}


def _finite_mask(uploads: Tree, *, stacked: bool) -> jax.Array:
    """(S,) bool (or scalar when not stacked): client's every aggregated
    element is finite. Fixed left-to-right AND chain over leaves."""
    leaves = jax.tree.leaves(_agg_entries(uploads))

    def leaf_ok(leaf):
        fin = jnp.isfinite(leaf.astype(jnp.float32))
        return jnp.all(fin, axis=tuple(range(1, fin.ndim)) if stacked
                       else None)

    ok = leaf_ok(leaves[0])
    for leaf in leaves[1:]:
        ok = jnp.logical_and(ok, leaf_ok(leaf))
    return ok


def client_sq_norms(uploads: Tree) -> jax.Array:
    """(S,) joint squared L2 norm of each client's aggregated entries
    (the quantity the norm-outlier screen thresholds), accumulated
    left-to-right per leaf like ``repro.privacy.l2_sq_norm``."""
    leaves = jax.tree.leaves(_agg_entries(uploads))

    def leaf_sq(leaf):
        x = leaf.astype(jnp.float32)
        return jnp.sum(x * x, axis=tuple(range(1, x.ndim)))

    acc = leaf_sq(leaves[0])
    for leaf in leaves[1:]:
        acc = acc + leaf_sq(leaf)
    return acc


def masked_median(values: jax.Array, valid: jax.Array) -> jax.Array:
    """Median of ``values[valid]`` with static shapes: invalid entries
    sort to +inf and the two middle VALID ranks are selected by one-hot
    sums (S is small and static). Returns 0 when nothing is valid."""
    s = values.shape[0]
    n = valid.astype(jnp.int32).sum()
    ordered = jnp.sort(jnp.where(valid, values, jnp.inf))
    lo, hi = (n - 1) // 2, n // 2

    def pick(rank):
        acc = jnp.where(rank == 0, ordered[0], 0.0)
        for i in range(1, s):
            acc = acc + jnp.where(rank == i, ordered[i], 0.0)
        return acc

    # n == 0 would select the +inf sentinel at rank 0 — report 0 instead
    return jnp.where(n > 0, 0.5 * (pick(lo) + pick(hi)), 0.0)


def upload_validity(uploads: Tree, *, arrived: Optional[jax.Array],
                    kind: str, norm_mult: float,
                    stacked: bool = True) -> jax.Array:
    """The validator: (S,) bool validity over the stacked uploads (or a
    scalar for one client inside the sequential scan).

    Always the per-client finite check (AND the transport-level
    ``arrived`` mask when dropout faults ride the batch); ``norm_filter``
    adds the norm-outlier screen — reject clients whose joint norm
    exceeds ``norm_mult`` times the median norm of the finite arrivals.
    The screen needs the full client stack, so it is stacked-only
    (config validation pins ``norm_filter`` to ``client_parallel``).
    """
    valid = _finite_mask(uploads, stacked=stacked)
    if arrived is not None:
        valid = jnp.logical_and(valid, arrived)
    if kind == "norm_filter" and stacked:
        norms = jnp.sqrt(client_sq_norms(uploads))
        med = masked_median(norms, valid)
        bound = norm_mult * jnp.maximum(med, _DENOM_FLOOR)
        valid = jnp.logical_and(valid, norms <= bound)
    return valid


def _zero_invalid(uploads: Tree, valid: jax.Array) -> Tree:
    """Replace rejected clients' rows with zeros BEFORE any weighted
    reduction: zero-weighting alone is not enough because the corrupt
    rows hold NaN and ``NaN * 0 = NaN``."""
    def z(u):
        v = valid.reshape((-1,) + (1,) * (u.ndim - 1))
        return jnp.where(v, u, jnp.zeros((), u.dtype))

    return jax.tree.map(z, uploads)


def _chain_sum(terms):
    """Fixed left-to-right association (the ``_weighted_mean`` idiom)."""
    acc = terms[0]
    for t in terms[1:]:
        acc = acc + t
    return acc


def clamp_nonneg_entries(mean_up: Tree) -> Tree:
    """Clamp the aggregated second-moment entries at zero (the
    ``add_round_noise`` post-processing invariant, applied by every
    robust registry entry)."""
    from repro.privacy import NONNEG_ENTRIES
    return {k: (jax.tree.map(lambda x: jnp.maximum(x, 0.0), v)
                if k in NONNEG_ENTRIES else v)
            for k, v in mean_up.items()}


def robust_aggregate(uploads: Tree, valid: jax.Array,
                     weights: Optional[jax.Array], *, kind: str,
                     trim_frac: float = 0.0
                     ) -> Tuple[Tree, jax.Array]:
    """Aggregate the stacked (S, ...) uploads over the valid clients ->
    ``(mean_up, n_valid)``. ``weights`` is the scenario aggregation
    weight vector (None = uniform); rank-based entries ignore it
    (validated at config time). ``n_valid`` is the traced survivor
    count the engine feeds to DP noise scaling, the quorum check, and
    the ``agg_survivors`` round metric."""
    s = valid.shape[0]
    valid_f = valid.astype(jnp.float32)
    n_valid = valid_f.sum()

    if kind in ("mean", "norm_filter"):
        base = (jnp.full((s,), 1.0 / s, jnp.float32) if weights is None
                else weights)
        w = base * valid_f
        w = w / jnp.maximum(w.sum(), _DENOM_FLOOR)
        zeroed = _zero_invalid(uploads, valid)

        def wmean(u):
            x = u.astype(jnp.float32)
            acc = _chain_sum([x[i] * w[i] for i in range(s)])
            return acc.astype(u.dtype)

        mean_up = jax.tree.map(wmean, zeroed)

    elif kind == "trimmed":
        n = valid.astype(jnp.int32).sum()
        k = jnp.floor(trim_frac * n_valid).astype(jnp.int32)
        kept = jnp.maximum(n - 2 * k, 1).astype(jnp.float32)

        def tmean(u):
            x = u.astype(jnp.float32)
            v = valid.reshape((-1,) + (1,) * (x.ndim - 1))
            # invalid rows sort to +inf per coordinate: ranks [k, n-k)
            # are exactly the kept VALID values (rejected-but-finite
            # values are still data here — validity decides membership,
            # rank decides trimming)
            ordered = jnp.sort(jnp.where(v, x, jnp.inf), axis=0)
            terms = []
            for i in range(s):
                keep = jnp.logical_and(i >= k, i < n - k)
                terms.append(jnp.where(keep, ordered[i], 0.0))
            return (_chain_sum(terms) / kept).astype(u.dtype)

        mean_up = jax.tree.map(tmean, uploads)

    elif kind == "coordinate_median":
        n = valid.astype(jnp.int32).sum()
        lo, hi = (n - 1) // 2, n // 2

        def cmed(u):
            x = u.astype(jnp.float32)
            v = valid.reshape((-1,) + (1,) * (x.ndim - 1))
            ordered = jnp.sort(jnp.where(v, x, jnp.inf), axis=0)

            def pick(rank):
                terms = [jnp.where(rank == i, ordered[i], 0.0)
                         for i in range(s)]
                return _chain_sum(terms)

            med = 0.5 * (pick(lo) + pick(hi))
            # all-rejected: hi == 0 would select the +inf sentinel —
            # report a zero update (quorum is the real guard here)
            return jnp.where(n > 0, med, 0.0).astype(u.dtype)

        mean_up = jax.tree.map(cmed, uploads)

    else:
        raise ValueError(f"unknown robust aggregator kind {kind!r}; "
                         f"known: {ROBUST_AGGREGATORS}")

    return clamp_nonneg_entries(mean_up), n_valid


# ------------------------------------------------------------------
# Reason-code taxonomy for the per-client flight recorder
# (repro.telemetry.ledger, docs/observability.md). Codes are small
# floats so they ride the f32 ledger stats block unchanged; both
# helpers are elementwise and layout-agnostic — the parallel layout
# calls them on (S,) vectors, the sequential layout on per-client
# scalars inside its scan — so the two layouts record identical rows.

#: what the fault injector did to this client's upload this round
INJECT_NONE = 0.0    # clean upload
INJECT_DROP = 1.0    # upload never arrived (FAULT_DROP_KEY)
INJECT_NAN = 2.0     # NaN/Inf corruption (FAULT_MULT_KEY is non-finite)
INJECT_SCALE = 3.0   # norm inflation (FAULT_MULT_KEY != 1, finite)

#: what the server concluded about this client's upload this round
VERDICT_ACCEPTED = 0.0  # arrived and passed validation
VERDICT_DROPPED = 1.0   # never arrived (transport fault)
VERDICT_REJECTED = 2.0  # arrived but rejected by the upload validator

INJECTED_CODES = {"none": INJECT_NONE, "drop": INJECT_DROP,
                  "nan": INJECT_NAN, "scale": INJECT_SCALE}
VERDICT_CODES = {"accepted": VERDICT_ACCEPTED, "dropped": VERDICT_DROPPED,
                 "rejected": VERDICT_REJECTED}


def injected_codes(f_drop: Optional[jax.Array],
                   f_mult: Optional[jax.Array]) -> Optional[jax.Array]:
    """Elementwise fault-injection reason code from the reserved-key
    payloads (``None`` payloads mean the fault process is off). Drop
    dominates corruption: a dropped upload never reaches the validator,
    so its corruption (if any) is unobservable.

    >>> import jax.numpy as jnp
    >>> d = jnp.array([False, True, False, False])
    >>> m = jnp.array([1.0, 1.0, jnp.nan, 1e3])
    >>> [int(c) for c in injected_codes(d, m)]
    [0, 1, 2, 3]
    >>> injected_codes(None, None) is None
    True
    """
    if f_drop is None and f_mult is None:
        return None
    ref = f_drop if f_drop is not None else f_mult
    drop = (jnp.zeros(jnp.shape(ref), jnp.bool_)
            if f_drop is None else jnp.asarray(f_drop, jnp.bool_))
    mult = (jnp.ones(jnp.shape(ref), jnp.float32)
            if f_mult is None else jnp.asarray(f_mult, jnp.float32))
    return jnp.where(
        drop, INJECT_DROP,
        jnp.where(~jnp.isfinite(mult), INJECT_NAN,
                  jnp.where(mult != 1.0, INJECT_SCALE,
                            INJECT_NONE))).astype(jnp.float32)


def verdict_codes(arrived: Optional[jax.Array],
                  valid: Optional[jax.Array]) -> jax.Array:
    """Elementwise server verdict. ``arrived`` is the transport mask
    (``None`` = no drop process, everyone arrived); ``valid`` is the
    validator's combined mask as produced by :func:`upload_validity`
    (which already ANDs in ``arrived`` — ``None`` = no validator ran).

    >>> import jax.numpy as jnp
    >>> a = jnp.array([True, False, True])
    >>> v = jnp.array([True, False, False])
    >>> [int(c) for c in verdict_codes(a, v)]
    [0, 1, 2]
    >>> [int(c) for c in verdict_codes(None, jnp.array([True, False]))]
    [0, 2]
    """
    ref = valid if valid is not None else arrived
    arr = (jnp.ones(jnp.shape(ref), jnp.bool_)
           if arrived is None else jnp.asarray(arrived, jnp.bool_))
    ok = arr if valid is None else jnp.asarray(valid, jnp.bool_)
    return jnp.where(
        ~arr, VERDICT_DROPPED,
        jnp.where(~ok, VERDICT_REJECTED,
                  VERDICT_ACCEPTED)).astype(jnp.float32)
