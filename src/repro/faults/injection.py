"""Seeded, deterministic fault schedules (docs/faults.md).

:class:`FaultModel` draws, per round, which clients fail and how:

* **upload dropout** (``p_drop``) — the client trained but its upload
  never arrived (transport failure). Realized as an (S,) bool mask the
  engine uses to zero-weight the missing uploads — observable by any
  server, defended or not, because an absent upload is absent.
* **NaN/Inf corruption** (``p_nan``) — the client's loss blew up and it
  shipped non-finite values. Realized as a NaN entry in the (S,) f32
  multiplier (``NaN * x = NaN`` poisons every element of that client's
  aggregated entries — delta, block-mean v, SCAFFOLD dc — exactly like
  a diverged local AdamW run).
* **norm inflation** (``p_scale``) — a byzantine/buggy client ships a
  ``scale_factor``-times-too-large update. Realized as the same
  multiplier. The multiplier lands AFTER the engine's DP clipping
  (deliberately: a faulty client does not politely clip itself, and the
  sweep in benchmarks/table_faults.py shows DP clipping alone is not a
  defense).

Draws follow the availability/straggler seeding idiom: one
``np.random.default_rng([seed, salt, round_index])`` generator per
round, evaluated for ALL N clients and then indexed by the sampled
cohort — a pure function of ``(fault_seed, round_index, client_id)``,
never of the shared batch rng stream or of which execution mode is
running. Eager/prefetched/fused execution and both placement layouts
therefore see bit-identical schedules, and whether a given client is
faulty this round does not depend on who else was sampled.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro import telemetry

_FAULT_SALT = 0xFA17  # rng stream id, distinct from scenario salts


class FaultModel:
    """Per-round fault draws for ``num_clients`` clients.

    >>> fm = FaultModel(8, p_nan=0.5, seed=3)
    >>> cids = np.arange(4)
    >>> p1 = fm.round_payload(0, cids)
    >>> p2 = fm.round_payload(0, cids)
    >>> bool(np.array_equal(p1["_fault_mult"], p2["_fault_mult"],
    ...                     equal_nan=True))            # deterministic
    True
    >>> FaultModel(8).round_payload(0, cids)            # inactive: no keys
    {}
    """

    def __init__(self, num_clients: int, *, p_drop: float = 0.0,
                 p_nan: float = 0.0, p_scale: float = 0.0,
                 scale_factor: float = 1e3, seed: int = 0):
        for name, p in (("p_drop", p_drop), ("p_nan", p_nan),
                        ("p_scale", p_scale)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if scale_factor <= 0.0:
            raise ValueError(
                f"scale_factor must be > 0, got {scale_factor}")
        self.num_clients = num_clients
        self.p_drop = p_drop
        self.p_nan = p_nan
        self.p_scale = p_scale
        self.scale_factor = scale_factor
        self.seed = seed

    @property
    def active(self) -> bool:
        """False = the degenerate model: no keys, no engine change."""
        return (self.p_drop > 0.0 or self.p_nan > 0.0
                or self.p_scale > 0.0)

    def round_faults(self, round_index: int, client_ids: np.ndarray):
        """This round's ``(drop (S,) bool, mult (S,) f32)`` for the
        sampled cohort. NaN wins where corruption and inflation hit the
        same client (a diverged client's scale is meaningless)."""
        rng = np.random.default_rng(
            [self.seed, _FAULT_SALT, int(round_index)])
        u = rng.random((3, self.num_clients))
        mult = np.ones(self.num_clients, np.float32)
        mult[u[1] < self.p_scale] = self.scale_factor
        mult[u[2] < self.p_nan] = np.nan
        drop = u[0] < self.p_drop
        cids = np.asarray(client_ids, np.int64)
        return drop[cids], mult[cids]

    def round_payload(self, round_index: int,
                      client_ids: np.ndarray) -> Dict[str, np.ndarray]:
        """Reserved-key entries to merge into the round batch pytree —
        empty when the model is inactive (the traced program is then the
        fault-free one, structurally). Both keys are always emitted
        together so every active config shares one batch structure."""
        if not self.active:
            return {}
        from repro.faults import FAULT_DROP_KEY, FAULT_MULT_KEY
        drop, mult = self.round_faults(round_index, client_ids)
        injected = int(np.sum(drop | ~np.isfinite(mult)
                              | (np.isfinite(mult) & (mult != 1.0))))
        if injected:
            telemetry.add("faults/injected", injected)
        return {FAULT_DROP_KEY: drop, FAULT_MULT_KEY: mult}

    @classmethod
    def from_fed(cls, fed, *, seed: Optional[int] = None
                 ) -> Optional["FaultModel"]:
        """The model a ``FedConfig`` describes, or None when every fault
        probability is zero (nothing to attach to the stream)."""
        fm = cls(fed.num_clients, p_drop=fed.fault_drop,
                 p_nan=fed.fault_nan, p_scale=fed.fault_scale,
                 scale_factor=fed.fault_scale_factor,
                 seed=fed.fault_seed if seed is None else seed)
        return fm if fm.active else None
