"""Shared benchmark harness.

Every benchmark maps to one paper table/figure and runs the SAME jitted
round engine as training/dry-run, scaled to CPU budgets (reduced model,
few rounds). Set BENCH_QUICK=1 for a fast smoke pass. Results append to
``benchmarks/out/*.csv`` and the aggregate printer emits
``name,us_per_call,derived`` rows as required.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

# persistent compilation cache: the bench suite compiles ~50 distinct
# round functions; repeat invocations hit the cache instead
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_bench_cache")
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def budget(normal: int, quick: int) -> int:
    return quick if QUICK else normal


def bench_fl(algorithm: str = "fedadamw", *, dirichlet: float = 0.6,
             rounds: Optional[int] = None, seed: int = 0, **overrides):
    """One federated training run on the synthetic task; returns history."""
    from repro.launch.train import run_training
    # NOTE: the round/step budget is load-bearing for the paper's relative
    # orderings — at <=8 rounds a well-tuned Local SGD still leads the
    # adaptive methods on this task (measured); the paper-consistent
    # separation (FedAdamW lowest train loss) appears from ~15 rounds on.
    kw = dict(
        arch="vit-tiny-fl", algorithm=algorithm, dirichlet=dirichlet,
        rounds=rounds if rounds is not None else budget(15, 3),
        num_clients=budget(16, 4), clients_per_round=budget(4, 2),
        local_steps=budget(10, 2), batch_size=budget(8, 4),
        seed=seed, eval_every=1000000,  # evaluate at the end only
    )
    kw.update(overrides)
    if "eval_every" not in overrides:
        kw["eval_every"] = kw["rounds"]  # final-round eval
    return run_training(**kw)


class Rows:
    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []
        self.t0 = time.perf_counter()

    def add(self, **kw):
        self.rows.append(dict(kw))

    def save(self) -> str:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{self.name}.csv")
        if self.rows:
            keys = list(self.rows[0].keys())
            with open(path, "w") as f:
                f.write(",".join(keys) + "\n")
                for r in self.rows:
                    f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
        return path

    def wall_us(self) -> float:
        return 1e6 * (time.perf_counter() - self.t0)


def print_table(title: str, rows: List[Dict]) -> None:
    if not rows:
        print(f"[{title}] (no rows)")
        return
    keys = list(rows[0].keys())
    widths = {k: max(len(k), *(len(str(r.get(k, ""))) for r in rows))
              for k in keys}
    print(f"== {title} ==")
    print("  ".join(k.ljust(widths[k]) for k in keys))
    for r in rows:
        print("  ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys))
