"""Participation sweep: availability rate x straggler fraction x
aggregation weighting (docs/scenarios.md).

The paper's experiments live in the idealized regime — every sampled
client shows up and finishes its K local steps. This sweep opens the
partial-participation design space the FL systems literature fights
over: skewed per-client availability (Bernoulli with Beta-spread rates,
availability-constrained sampling), stragglers (K_i <= K via the
step-validity mask), and the three aggregation weightings. Every run
goes through the PIPELINED engine (prefetch + multi-round fusion), which
is trajectory-identical to the eager loop by construction.

Reads on the output (synthetic class_lm task, qualitative):

* availability skew + stragglers hurt the uniform-weighted baseline the
  most — exactly the gap adaptive-participation papers target;
* ``inv_steps`` recovers part of the straggler loss by re-balancing
  per-step contributions;
* ``data_size`` matters when Dirichlet shards are very unequal (its
  effect grows as ``--dirichlet`` shrinks).

Writes ``benchmarks/out/table_participation.csv``. BENCH_QUICK=1 for a
smoke pass.
"""
from __future__ import annotations

import common

AVAILABILITY = [
    ("always_on", "uniform"),        # idealized seed regime
    ("bernoulli0.9:4", "available"),  # mild, lightly skewed
    ("bernoulli0.6:2", "available"),  # harsh, heavily skewed
]
STRAGGLER_FRACS = [0.0, 0.5]
WEIGHTINGS = ["uniform", "data_size", "inv_steps"]


def main() -> None:
    rows = common.Rows("table_participation")
    rounds = common.budget(15, 3)
    for availability, sampling in AVAILABILITY:
        for frac in STRAGGLER_FRACS:
            for weighting in WEIGHTINGS:
                if frac == 0.0 and weighting == "inv_steps":
                    continue  # K_i = K everywhere -> identical to uniform
                hist = common.bench_fl(
                    "fedadamw", rounds=rounds, dirichlet=0.1,
                    availability=availability, sampling=sampling,
                    straggler_frac=frac, straggler_min_steps=1,
                    agg_weighting=weighting,
                    prefetch_depth=2,
                    rounds_per_call=min(3, rounds))
                rows.add(
                    availability=availability, sampling=sampling,
                    straggler_frac=frac, weighting=weighting,
                    final_train_loss=round(hist["train_loss"][-1], 4),
                    final_test_loss=round(hist["test_loss"][-1], 4),
                    final_test_acc=round(hist["test_acc"][-1], 4),
                    wall_s=round(hist["engine"]["wall_s"], 2))
                print(f"[participation] {availability:>15} "
                      f"straggler={frac} {weighting:>9}: "
                      f"train {rows.rows[-1]['final_train_loss']:.4f} "
                      f"acc {rows.rows[-1]['final_test_acc']:.4f}")
    path = rows.save()
    common.print_table("participation sweep (availability x straggler x "
                       "weighting)", rows.rows)
    print(f"saved {path}")


if __name__ == "__main__":
    main()
