"""Communication-codec sweep: codecs x algorithms, bytes-to-target-loss.

The paper's headline claim is communication efficiency; this table makes
the repo's version of that claim measurable. For each (algorithm, codec)
pair we train on the synthetic non-iid task and report

  * per-round per-client upload MB (true wire bytes from the codec),
  * final train loss after the shared round budget,
  * cumulative upload MB until train loss first reaches the uncompressed
    run's final loss + 10% (``inf`` if never reached) — the
    bytes-to-target-accuracy metric FedLADA/DP-FedAdamW compete on.

Usage: BENCH_QUICK=1 python benchmarks/table_comm_codecs.py
"""
from __future__ import annotations

import math

from benchmarks.common import Rows, bench_fl, budget, print_table

ALGORITHMS = ["fedadamw", "fedavg"]
CODECS = ["", "int8", "int4", "topk0.1", "lowrank4"]
TARGET_SLACK = 1.10
# passed explicitly so the bytes-to-target math and the runs cannot
# drift apart if bench_fl's internal default is retuned
CLIENTS_PER_ROUND = budget(4, 2)


def _run(algorithm: str):
    return bench_fl(algorithm, eval_every=1, rounds=budget(15, 3),
                    clients_per_round=CLIENTS_PER_ROUND)


def _bytes_to_target(history, target: float, clients_per_round: int):
    per_round_mb = history["upload_mbytes"][-1] * clients_per_round
    for i, loss in enumerate(history["train_loss"]):
        if loss <= target:
            return (i + 1) * per_round_mb
    return math.inf


def run() -> Rows:
    rows = Rows("table_comm_codecs")
    for alg in ALGORITHMS:
        baseline = _run(alg)
        target = baseline["train_loss"][-1] * TARGET_SLACK
        for codec in CODECS:
            name = f"{alg}+{codec}" if codec else alg
            hist = baseline if not codec else _run(name)
            rows.add(
                algorithm=alg, codec=codec or "none",
                upload_mb_per_round=round(hist["upload_mbytes"][-1], 4),
                final_train_loss=round(hist["train_loss"][-1], 4),
                mb_to_target=round(_bytes_to_target(
                    hist, target, CLIENTS_PER_ROUND), 3),
                target_loss=round(target, 4),
            )
    path = rows.save()
    print_table("comm codecs: bytes-to-target-loss", rows.rows)
    print(f"saved -> {path}")
    return rows


if __name__ == "__main__":
    run()
