"""Roofline report: per-subsystem bandwidth/compute/bottleneck table.

Two modes:

``--live`` (the CI ``roofline-report`` job, ROADMAP item 4)
    Compiles the federated round step and the semantic program of each
    Pallas kernel (quantpack, clipacc, blockmean, fused_adamw,
    uploadfuse) on this host, counts FLOPs / HBM bytes / collective
    bytes from the compiled
    HLO text (``repro.roofline.hlo_counter`` — trip-count aware), and
    prints the three-term TPU-v5e roofline per subsystem
    (``repro.roofline.analysis``). Each row also carries the *analytic*
    interface bytes ``min_bytes`` (inputs + outputs moved exactly once
    — what a perfectly fused kernel must transfer) and the
    ``bytes_ratio`` = HLO bytes / min_bytes: the bandwidth-optimality
    audit. Kernels are costed through their pure-jnp reference
    formulation — the Pallas grid itself compiles to an opaque custom
    call (TPU) or an interpreter program (CPU), neither of which the
    HLO counter can meaningfully price, so the table reports the
    semantic traffic each fused kernel competes against.

default (no flag)
    Legacy view: reads the multi-pod dry-run JSON records from
    ``experiments/dryrun`` and prints the per-(arch x shape x mesh)
    roofline recorded there.

Artifacts land in ``benchmarks/out/``: ``roofline_live.csv`` plus
``roofline_live.md`` (the markdown table CI uploads) and
``roofline_fusion.json`` — the uploadfuse fusion audit: the one-pass
kernel interface bytes vs the separate-pass pipeline's summed HLO
bytes, asserted strictly smaller. Column meanings are documented in
docs/observability.md §Roofline report.
"""
import argparse
import glob
import json
import os

from benchmarks.common import OUT_DIR, Rows, print_table

DRYRUN_DIR = os.environ.get(
    "DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun"))


def load_records(mesh: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run() -> Rows:
    rows = Rows("roofline_report")
    for r in load_records():
        if r.get("status") == "skip":
            rows.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                     status="SKIP", compute_s="-", memory_s="-",
                     collective_s="-", bottleneck="-", useful="-",
                     temp_gb="-")
            continue
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        rows.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                 status="ok",
                 compute_s=f"{rf['compute_s']:.3g}",
                 memory_s=f"{rf['memory_s']:.3g}",
                 collective_s=f"{rf['collective_s']:.3g}",
                 bottleneck=rf["bottleneck"],
                 useful=(f"{r['useful_flops_ratio']:.2f}"
                         if r.get("useful_flops_ratio") else "-"),
                 temp_gb=f"{(r['memory']['bytes_per_device'] or 0)/1e9:.1f}")
    rows.save()
    print_table("Roofline — per (arch x shape x mesh), per-chip seconds",
                rows.rows)
    return rows


# ---------------------------------------------------------------- live mode

def _tree_bytes(tree) -> int:
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _analyze(fn, *args):
    """Compile ``fn`` for ``args`` shapes and return (hlo_costs,
    min_interface_bytes)."""
    import jax
    from repro.roofline.hlo_counter import analyze_hlo
    costs = analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())
    out_shape = jax.eval_shape(fn, *args)
    return costs, _tree_bytes(args) + _tree_bytes(out_shape)


def _round_step_case(smoke: bool):
    """The full jitted round step on the reduced tiny model."""
    import jax
    import jax.numpy as jnp
    from repro.config import FedConfig, get_arch
    from repro.config.model_config import reduced_variant
    from repro.core import build_fed_state, make_round_fn
    from repro.models import build_model

    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    s, k, b, seq = (2, 2, 2, 16) if smoke else (4, 4, 4, 32)
    fed = FedConfig(algorithm="fedadamw", num_clients=8,
                    clients_per_round=s, local_steps=k, lr=1e-3)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    round_fn = make_round_fn(model, fed, specs, alg=alg,
                             cosine_total_rounds=10)
    batches = {
        "tokens": jnp.zeros((s, k, b, seq), jnp.int32),
        "labels": jnp.zeros((s, k, b, seq), jnp.int32),
    }
    cids = jnp.arange(s, dtype=jnp.int32)
    return round_fn, (params, sstate, batches, cids, jnp.zeros((), jnp.int32))


def _kernel_cases(smoke: bool):
    """(name, fn, args) per Pallas kernel, costed via the pure-jnp
    reference formulation at representative shapes."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.blockmean.ref import column_mean_ref
    from repro.kernels.clipacc.ref import clip_accumulate_ref
    from repro.kernels.fused_adamw.ref import fused_adamw_ref
    from repro.kernels.quantpack.ref import quantpack_int8_ref

    r, c = (256, 256) if smoke else (2048, 1024)
    s_n = 4 if smoke else 8
    key = jax.random.key(0)
    x2d = jax.random.normal(key, (r, c), jnp.float32)
    x3d = jax.random.normal(jax.random.fold_in(key, 1), (s_n, r, 128),
                            jnp.float32)
    w = jnp.full((s_n,), 1.0 / s_n, jnp.float32)
    five = [jax.random.normal(jax.random.fold_in(key, i), (r, c),
                              jnp.float32) for i in range(5)]
    scalars = jnp.arange(1.0, 9.0, dtype=jnp.float32)
    uf = _uploadfuse_operands(smoke)
    return [
        ("kernel:quantpack", quantpack_int8_ref, (x2d,)),
        ("kernel:clipacc",
         lambda x, wt: clip_accumulate_ref(x, wt, 1.0), (x3d, w)),
        ("kernel:blockmean", column_mean_ref, (x2d,)),
        ("kernel:fused_adamw", fused_adamw_ref, (*five, scalars)),
        ("kernel:uploadfuse", uf["semantic"], uf["args"]),
    ]


def _uploadfuse_operands(smoke: bool):
    """Operands + closures for the fused-upload costing: the DP + int4 +
    error-feedback configuration (the 3-phase path, worst unfused
    traffic)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.uploadfuse.ref import upload_fuse_semantic

    s_n = 4 if smoke else 8
    n_leaves, blocks = 2, (2 if smoke else 8)   # 8-row tiles per leaf
    r, c = n_leaves * blocks * 8, 1024
    seg = np.repeat(np.arange(n_leaves, dtype=np.int32), blocks)
    key = jax.random.key(7)
    x = jax.random.normal(key, (s_n, r, c), jnp.float32)
    e = jax.random.normal(jax.random.fold_in(key, 1), (s_n, r, c),
                          jnp.float32)
    u = jax.random.uniform(jax.random.fold_in(key, 2), (s_n, r, c),
                           jnp.float32)
    w = jnp.full((s_n,), 1.0 / s_n, jnp.float32)
    kw = dict(bits=4, dp=True, ef=True, n_leaves=n_leaves)

    def semantic(x, e, u, w):
        return upload_fuse_semantic(x, e, u, w, 0.5, seg, **kw)

    return dict(semantic=semantic, args=(x, e, u, w), seg=seg, kw=kw)


def _fusion_audit(smoke: bool) -> dict:
    """Prove the one-pass win in bytes: the fused kernel's interface
    (inputs + all outputs moved exactly once) vs the separate-pass
    pipeline (fold+clip, quantize+decode, re-clip+accumulate compiled as
    individual programs, intermediates materialized between them — the
    sum of their HLO byte counts). Written to
    ``benchmarks/out/roofline_fusion.json``; the roofline-report CI job
    asserts ``fused_interface_bytes < separate_pass_bytes``."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.uploadfuse.ref import upload_fuse_semantic
    from repro.kernels.uploadfuse.uploadfuse import (NORM_FLOOR,
                                                    upload_fuse_3d)
    from repro.roofline.hlo_counter import analyze_hlo

    uf = _uploadfuse_operands(smoke)
    x, e, u, w = uf["args"]
    seg, kw = uf["seg"], uf["kw"]

    def hlo_bytes(fn, *args):
        return analyze_hlo(
            jax.jit(fn).lower(*args).compile().as_text())["bytes"]

    def clip_stack(a):
        norm = jnp.sqrt(jnp.sum(a * a, axis=(1, 2)))
        f = jnp.minimum(1.0, 0.5 / jnp.maximum(norm, NORM_FLOOR))
        return f[:, None, None] * a

    def stage_fold_clip(x, e):
        return clip_stack(x + e)

    def stage_quant_decode(ctgt, u):
        # per-stack scale stands in for the per-leaf loop: the leaf
        # bookkeeping costs nothing in bytes, the materialized decoded
        # copy is the traffic
        scale = jnp.maximum(jnp.max(jnp.abs(ctgt), axis=(1, 2)),
                            1e-12)[:, None, None] / 7.0
        q = jnp.clip(jnp.floor(ctgt / scale + u), -8.0, 7.0)
        return q * scale

    def stage_reclip_acc(ctgt, dec, w):
        final = clip_stack(dec)
        return jnp.sum(w[:, None, None] * final, axis=0), ctgt - final

    ctgt = jax.jit(stage_fold_clip)(x, e)
    dec = jax.jit(stage_quant_decode)(ctgt, u)
    separate = (hlo_bytes(stage_fold_clip, x, e)
                + hlo_bytes(stage_quant_decode, ctgt, u)
                + hlo_bytes(stage_reclip_acc, ctgt, dec, w))
    single_jit = hlo_bytes(
        lambda x, e, u, w: upload_fuse_semantic(x, e, u, w, 0.5, seg,
                                                **kw),
        x, e, u, w)
    fused_out = jax.eval_shape(
        lambda x, e, u, w: upload_fuse_3d(x, e, u, w, 0.5,
                                          jnp.asarray(seg), **kw),
        x, e, u, w)
    fused = _tree_bytes((x, e, u, w)) + _tree_bytes(fused_out)
    return {"fused_interface_bytes": int(fused),
            "separate_pass_bytes": int(separate),
            "single_jit_bytes": int(single_jit),
            "separate_over_fused": round(separate / fused, 2)}


def live_report(smoke: bool = False) -> Rows:
    from repro.roofline.analysis import RooflineTerms

    rows = Rows("roofline_live")
    cases = [("round_step", *_round_step_case(smoke))]
    cases += [(name, fn, args) for name, fn, args in _kernel_cases(smoke)]
    for name, fn, args in cases:
        costs, min_bytes = _analyze(fn, *args)
        terms = RooflineTerms(
            flops=costs["flops"], hbm_bytes=costs["bytes"],
            collective_bytes=costs["collective_bytes"], chips=1)
        rows.add(
            subsystem=name,
            flops=f"{terms.flops:.4g}",
            hbm_bytes=f"{terms.hbm_bytes:.4g}",
            min_bytes=f"{min_bytes:.4g}",
            bytes_ratio=f"{terms.hbm_bytes / max(min_bytes, 1):.2f}",
            intensity=f"{terms.flops / max(terms.hbm_bytes, 1):.3f}",
            compute_s=f"{terms.compute_s:.3g}",
            memory_s=f"{terms.memory_s:.3g}",
            collective_s=f"{terms.collective_s:.3g}",
            bottleneck=terms.bottleneck)
    path = rows.save()
    _write_markdown(rows)
    print_table("Roofline (live) — per subsystem, TPU-v5e terms from "
                "compiled HLO", rows.rows)
    print(f"csv: {path}")
    audit = _fusion_audit(smoke)
    audit_path = os.path.join(OUT_DIR, "roofline_fusion.json")
    with open(audit_path, "w") as f:
        json.dump(audit, f, indent=2)
    print(f"fusion audit: fused one-pass interface "
          f"{audit['fused_interface_bytes']:.4g} B vs separate-pass "
          f"pipeline {audit['separate_pass_bytes']:.4g} B "
          f"({audit['separate_over_fused']}x) -> {audit_path}")
    assert (audit["fused_interface_bytes"]
            < audit["separate_pass_bytes"]), audit
    return rows


def _write_markdown(rows: Rows) -> str:
    """EXPERIMENTS.md-style markdown table — the CI artifact."""
    path = os.path.join(OUT_DIR, "roofline_live.md")
    keys = list(rows.rows[0].keys())
    with open(path, "w") as f:
        f.write("# Per-subsystem roofline (live)\n\n")
        f.write("Generated by `python benchmarks/roofline_report.py "
                "--live`; column meanings in docs/observability.md.\n\n")
        f.write("| " + " | ".join(keys) + " |\n")
        f.write("|" + "|".join("---" for _ in keys) + "|\n")
        for r in rows.rows:
            f.write("| " + " | ".join(str(r.get(k, "")) for k in keys)
                    + " |\n")
    print(f"markdown: {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="compile the round step + kernel reference "
                         "programs on this host and roofline them "
                         "(instead of reading dry-run records)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    a = ap.parse_args()
    if a.live:
        live_report(smoke=a.smoke)
    else:
        run()
