"""Roofline table generator (deliverable g): reads the dry-run JSON records
from experiments/dryrun and prints the per-(arch x shape x mesh) three-term
roofline with the dominant bottleneck. Also emits the EXPERIMENTS.md
§Roofline markdown table."""
import glob
import json
import os

from benchmarks.common import OUT_DIR, Rows, print_table

DRYRUN_DIR = os.environ.get(
    "DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun"))


def load_records(mesh: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run() -> Rows:
    rows = Rows("roofline_report")
    for r in load_records():
        if r.get("status") == "skip":
            rows.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                     status="SKIP", compute_s="-", memory_s="-",
                     collective_s="-", bottleneck="-", useful="-",
                     temp_gb="-")
            continue
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        rows.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                 status="ok",
                 compute_s=f"{rf['compute_s']:.3g}",
                 memory_s=f"{rf['memory_s']:.3g}",
                 collective_s=f"{rf['collective_s']:.3g}",
                 bottleneck=rf["bottleneck"],
                 useful=(f"{r['useful_flops_ratio']:.2f}"
                         if r.get("useful_flops_ratio") else "-"),
                 temp_gb=f"{(r['memory']['bytes_per_device'] or 0)/1e9:.1f}")
    rows.save()
    print_table("Roofline — per (arch x shape x mesh), per-chip seconds",
                rows.rows)
    return rows


if __name__ == "__main__":
    run()
