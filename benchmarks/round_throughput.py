"""Round-engine throughput: eager seed loop vs. pipelined vs. fused.

Measures rounds/s and the host-blocked fraction of the federated round
engine in three execution modes on a launch-bound configuration:

  eager            the seed driver loop — assemble batches on the host,
                   one jitted dispatch per round, block on
                   ``float(loss_mean)`` every round, no donation.
  pipelined        donated buffers + background host prefetch (depth 2)
                   + deferred metrics, still one dispatch per round.
  pipelined_fused  all of the above + ``rounds_per_call`` rounds scanned
                   inside ONE jitted call over pre-staged batch stacks.

The model is vit-tiny-fl shrunk through the repo's own reduction API
(``reduced_variant(num_layers=1, d_model=32)``): a round-ENGINE
microbenchmark wants per-round device compute small enough that the
per-round host overhead (assembly, python dispatch, scalar sync) is
visible — exactly the launch-bound regime the fused path exists for.
Wall-clock per mode is min-of-reps (the sandbox CPU is noisy); every
mode replays the identical rng stream, and the benchmark asserts the
three trajectories are BIT-EXACT before reporting any number.

Writes ``BENCH_round_throughput.json`` at the repo root (``--smoke``
writes to ``benchmarks/out/`` instead so a quick CI pass cannot clobber
the committed trajectory).

Usage:
  python benchmarks/round_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import telemetry  # noqa: E402
from repro.config import FedConfig, get_arch  # noqa: E402
from repro.config.model_config import reduced_variant  # noqa: E402
from repro.core import build_fed_state  # noqa: E402
from repro.data import RoundBatchGenerator, make_task  # noqa: E402
from repro.launch.pipeline import (HostPrefetcher, RoundEngine,  # noqa: E402
                                   plan_round_blocks)
from repro.models import build_model  # noqa: E402

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def _copy(tree):
    return jax.tree.map(jnp.copy, tree)


class Bench:
    def __init__(self, *, smoke: bool):
        self.smoke = smoke
        self.clients = 8
        self.clients_per_round = 2
        self.local_steps = 1
        self.batch_size = 2
        self.seq_len = 8
        self.rounds_per_call = 8 if smoke else 24
        self.prefetch_depth = 2
        self.rounds = 16 if smoke else 120
        self.reps = 2 if smoke else 6
        assert self.rounds % self.rounds_per_call == 0
        self.cfg = reduced_variant(get_arch("vit-tiny-fl"),
                                   num_layers=1, d_model=32)
        self.model = build_model(self.cfg, compute_dtype=jnp.float32)
        self.task = make_task(
            "class_lm", vocab_size=self.cfg.vocab_size, seq_len=self.seq_len,
            num_samples=512, num_clients=self.clients, dirichlet_alpha=0.6,
            seed=0)

    def _gen(self):
        return RoundBatchGenerator(
            self.task, num_clients=self.clients,
            clients_per_round=self.clients_per_round,
            local_steps=self.local_steps, batch_size=self.batch_size, rng=1)

    def _state(self, rounds_per_call: int, donate: bool):
        fed = FedConfig(
            algorithm="fedadamw", num_clients=self.clients,
            clients_per_round=self.clients_per_round,
            local_steps=self.local_steps, lr=3e-4,
            rounds_per_call=rounds_per_call)
        params, specs, alg, sstate = build_fed_state(
            self.model, fed, jax.random.key(0))
        engine = RoundEngine(self.model, fed, specs, alg=alg,
                             cosine_total_rounds=self.rounds, donate=donate)
        return params, sstate, engine

    # -- build a mode's engine + a closure running one full timed pass;
    # -- every pass replays identical state/data (copies, fresh rng)
    def _make_mode(self, mode: str):
        rpc = self.rounds_per_call if mode == "pipelined_fused" else 1
        donate = mode != "eager"
        depth = 0 if mode == "eager" else self.prefetch_depth
        params0, sstate0, engine = self._state(rpc, donate)
        blocks = plan_round_blocks(self.rounds, self.rounds + 1, rpc)

        def one_pass():
            params, sstate = _copy(params0), _copy(sstate0)
            gen = self._gen()
            # per-pass telemetry session: the prefetcher's wait/produce
            # counters register in it, and the report reads the SAME
            # "prefetch/wait_s" accumulator run_training logs — one
            # source of truth instead of a bench-local stopwatch
            with telemetry.session() as tele:
                pre = HostPrefetcher(gen, blocks, depth=depth,
                                     stacked=engine.stacked)
                pending = []
                t0 = time.perf_counter()
                if mode == "eager":
                    # faithful seed loop: blocking scalar fetch per round
                    for start, size, batches, cids in pre:
                        params, sstate, m = engine.run_block(
                            params, sstate, batches, cids, start, size)
                        pending.append(float(m["loss_mean"]))
                else:
                    for start, size, batches, cids in pre:
                        params, sstate, m = engine.run_block(
                            params, sstate, batches, cids, start, size)
                        pending.append(m["loss_mean"])
                    jax.block_until_ready(pending)
                wall = time.perf_counter() - t0
                wait_s = tele.counters.value("prefetch/wait_s")
            losses = np.concatenate(
                [np.atleast_1d(np.asarray(x)) for x in pending]).tolist()
            return wall, wait_s, losses, params

        meta = {"rounds_per_call": rpc, "prefetch_depth": depth,
                "donate": donate}
        return one_pass, meta

    def run(self):
        modes = ("eager", "pipelined", "pipelined_fused")
        passes, metas, best = {}, {}, {}
        for mode in modes:
            passes[mode], metas[mode] = self._make_mode(mode)
            passes[mode]()  # compile + warm
        # interleave the reps round-robin so every mode samples the same
        # machine-noise windows (the sandbox CPU drifts over seconds);
        # min-of-reps per mode is the steady-state estimate
        for _ in range(self.reps):
            for mode in modes:
                res = passes[mode]()
                if mode not in best or res[0] < best[mode][0]:
                    best[mode] = res
        results, trajs, finals = {}, {}, {}
        for mode in modes:
            wall, wait_s, trajs[mode], finals[mode] = best[mode]
            results[mode] = {
                "wall_s": wall, "host_wait_s": wait_s,
                "rounds_per_s": self.rounds / wall,
                "ms_per_round": 1e3 * wall / self.rounds,
                "host_blocked_frac": wait_s / wall, **metas[mode]}
            r = results[mode]
            print(f"{mode:16s} {r['rounds_per_s']:8.1f} rounds/s  "
                  f"{r['ms_per_round']:7.2f} ms/round  "
                  f"host_blocked {100 * r['host_blocked_frac']:5.1f}%")

        # bit-exact trajectory parity across ALL modes (loss stream AND
        # final params) — the speedup is meaningless if numerics drift
        parity = all(trajs[m] == trajs["eager"]
                     for m in ("pipelined", "pipelined_fused"))
        parity = parity and all(
            bool(jnp.array_equal(a, b))
            for m in ("pipelined", "pipelined_fused")
            for a, b in zip(jax.tree.leaves(finals["eager"]),
                            jax.tree.leaves(finals[m])))
        speedup = (results["pipelined_fused"]["rounds_per_s"]
                   / results["eager"]["rounds_per_s"])
        print(f"parity_bitexact: {parity}   "
              f"pipelined+fused vs eager: {speedup:.2f}x")
        assert parity, "trajectory parity FAILED across execution modes"

        report = {
            "bench": "round_throughput",
            "arch": ("vit-tiny-fl/reduced_variant"
                     "(num_layers=1,d_model=32)"),
            "machine": {"cpus": os.cpu_count(),
                        "backend": jax.default_backend()},
            "config": {
                "algorithm": "fedadamw", "num_clients": self.clients,
                "clients_per_round": self.clients_per_round,
                "local_steps": self.local_steps,
                "batch_size": self.batch_size, "seq_len": self.seq_len,
                "rounds_timed": self.rounds, "reps_min_of": self.reps,
                "rounds_per_call": self.rounds_per_call,
                "prefetch_depth": self.prefetch_depth,
                "smoke": self.smoke,
            },
            "modes": results,
            "speedup_pipelined_fused_vs_eager": round(speedup, 3),
            "parity_bitexact": parity,
            "note": ("launch-bound regime: per-round device compute is "
                     "shrunk (1-layer d32 reduced vit-tiny-fl) until host "
                     "dispatch/assembly/sync overhead is visible; fusion "
                     "amortizes per-call overhead by rounds_per_call. "
                     "host_blocked_frac = time the main loop waited for "
                     "the next block's inputs / wall."),
        }
        return report, speedup


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-budget CI pass: assert the pipelined paths "
                         "complete with bit-exact parity; write the report "
                         "under benchmarks/out/ instead of the repo root")
    args = ap.parse_args()
    bench = Bench(smoke=args.smoke)
    report, speedup = bench.run()
    if args.smoke:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, "BENCH_round_throughput_smoke.json")
    else:
        path = os.path.join(REPO_ROOT, "BENCH_round_throughput.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"saved -> {path}")
    if not args.smoke and speedup < 2.0:
        print(f"WARNING: pipelined+fused speedup {speedup:.2f}x < 2x target")


if __name__ == "__main__":
    main()
