"""Benchmark aggregator: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
us_per_call is the benchmark's wall time and ``derived`` is its headline
result. Set BENCH_QUICK=1 for a fast pass.
"""
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (beyond_paper, fig1_adamw_vs_sgd,
                            fig2_variance_drift, kernels_bench,
                            roofline_report, speedup_theorem1, table1_main,
                            table4_ablation, table5_alpha,
                            table6_weight_decay, table7_aggregation,
                            table_comm_codecs, table_state_store)
    benches = [
        ("fig1_adamw_vs_sgd", fig1_adamw_vs_sgd.run),
        ("fig2_variance_drift", fig2_variance_drift.run),
        ("table1_main", table1_main.run),
        ("table4_ablation", table4_ablation.run),
        ("table5_alpha", table5_alpha.run),
        ("table6_weight_decay", table6_weight_decay.run),
        ("table7_aggregation", table7_aggregation.run),
        ("table_comm_codecs", table_comm_codecs.run),
        ("table_state_store", table_state_store.run),
        ("speedup_theorem1", speedup_theorem1.run),
        ("beyond_paper", beyond_paper.run),
        ("kernels_bench", kernels_bench.run),
        ("roofline_report", roofline_report.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        try:
            rows = fn()
            derived = ""
            if rows.rows:
                last = rows.rows[-1]
                derived = ";".join(f"{k}={v}" for k, v in last.items())
            print(f"{name},{rows.wall_us():.0f},{derived}")
        except Exception:
            failures += 1
            print(f"{name},FAILED,")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
