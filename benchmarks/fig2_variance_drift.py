"""Paper Figure 2: (a) data heterogeneity inflates the cross-client
variance of the second-moment estimate v under Local AdamW, and FedAdamW's
block-mean aggregation suppresses it; (b) Local AdamW drifts further from
the global average than Local SGD, and the alpha-correction reduces drift.

Measured directly on the round engine by instrumenting per-client local
phases (no jit barrier needed at this scale)."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, budget, print_table
from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.core import build_fed_state
from repro.core.tree_util import global_norm, tree_sub
from repro.data import make_task, round_batches, sample_clients
from repro.models import build_model


def _per_client_final_states(model, cfg, fed, task, rounds, seed=0):
    """Runs rounds manually, returning per-client (v, x) after local
    training in the final round."""
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(seed), cfg=cfg)
    rng = np.random.default_rng(seed)
    loss_fn = model.loss

    import repro.core.rounds as rounds_mod
    local_phase = rounds_mod.make_local_phase(loss_fn, alg, fed, specs)

    @jax.jit
    def run_client(gparams, sst, batches):
        cstate = alg.init_client(gparams, sst, fed, specs=specs)

        def step(carry, batch):
            p, cst = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            p, cst = alg.local_step(p, g, cst, sst, fed, 1.0)
            return (p, cst), loss

        (p_k, cst_k), losses = jax.lax.scan(step, (gparams, cstate), batches)
        return p_k, cst_k, losses.mean()

    b = budget(16, 4)
    for r in range(rounds):
        cids = sample_clients(fed.num_clients, fed.clients_per_round, rng)
        rb = round_batches(task, cids, fed.local_steps, b, rng)
        rb = {k: jnp.asarray(v) for k, v in rb.items()}
        clients = []
        for si in range(fed.clients_per_round):
            cb = {k: v[si] for k, v in rb.items()}
            clients.append(run_client(params, sstate, cb))
        deltas = [tree_sub(p_k, params) for (p_k, _, _) in clients]
        mean_delta = jax.tree.map(
            lambda *xs: jnp.mean(jnp.stack(xs), 0), *deltas)
        uploads = [alg.upload(d, cst, specs, fed)
                   for d, (_, cst, _) in zip(deltas, clients)]
        mean_up = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs), 0),
                               *uploads)
        params, sstate = alg.server_update(params, sstate, mean_up, specs,
                                           fed)
    return params, sstate, clients


def run() -> Rows:
    rows = Rows("fig2_variance_drift")
    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    rounds = budget(4, 2)

    for label, algo, agg, alpha in (
            ("local_adamw", "local_adamw", "none", 0.0),
            ("fedadamw", "fedadamw", "mean_v", 0.5)):
        for dirichlet in (0.6, 0.1):
            fed = FedConfig(algorithm=algo, v_aggregation=agg, alpha=alpha,
                            num_clients=budget(16, 4),
                            clients_per_round=budget(8, 2),
                            local_steps=budget(10, 2), lr=3e-4)
            task = make_task("class_lm", vocab_size=cfg.vocab_size,
                             seq_len=32, num_samples=2048,
                             num_clients=fed.num_clients,
                             dirichlet_alpha=dirichlet, seed=0)
            params, sstate, clients = _per_client_final_states(
                model, cfg, fed, task, rounds)
            # (a) cross-client variance of v (flattened, mean over dims)
            vs = [jnp.concatenate([x.reshape(-1) for x in
                                   jax.tree.leaves(cst["v"])])
                  for (_, cst, _) in clients]
            vstack = jnp.stack(vs)
            v_var = float(jnp.mean(jnp.var(vstack, axis=0)))
            # (b) client drift: mean distance of client model from average
            ps = [p for (p, _, _) in clients]
            pavg = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs), 0), *ps)
            drift = float(np.mean([
                float(global_norm(tree_sub(p, pavg))) for p in ps]))
            rows.add(setting=label, dirichlet=dirichlet,
                     v_variance=f"{v_var:.3e}", client_drift=round(drift, 4))
    rows.save()
    print_table("Fig.2 — v-variance & client drift", rows.rows)
    return rows


if __name__ == "__main__":
    run()
