"""Paper Table 7: second-moment aggregation strategies vs communication.
NoAgg / Agg-v (full) / Agg-vm (full) / Agg-mean-v (ours, O(B))."""
from benchmarks.common import Rows, bench_fl, print_table

STRATEGIES = [
    ("NoAgg", "none"),
    ("Agg-v", "full_v"),
    ("Agg-vm", "full_vm"),
    ("Agg-mean-v", "mean_v"),
]


def run() -> Rows:
    rows = Rows("table7_aggregation")
    for label, agg in STRATEGIES:
        h = bench_fl("fedadamw", dirichlet=0.1, v_aggregation=agg)
        rows.add(strategy=label,
                 test_acc=round(h["test_acc"][-1], 4),
                 train_loss=round(h["train_loss"][-1], 4),
                 comm_mb_per_client=round(h["upload_mbytes"][-1], 3))
    rows.save()
    print_table("Table 7 — aggregation strategy vs communication", rows.rows)
    return rows


if __name__ == "__main__":
    run()
