"""Fault tolerance: accuracy retention under injected upload faults.

Sweeps the NaN-corruption rate against the defense registry
(docs/faults.md) on the 15-round vit-tiny-fl run and reports each cell's
final train loss / test accuracy plus ``retention`` — the fraction of
the fault-free accuracy the defended run keeps. The acceptance contract:
at ``p_nan = 0.1``, ``norm_filter`` + quorum stays finite and within 10%
of the fault-free train loss, while undefended mean aggregation
demonstrably diverges (one NaN upload poisons the global params — rows
show ``diverged``).
"""
from benchmarks.common import Rows, bench_fl, budget, print_table

FAULT_RATES = (0.0, 0.1, 0.3)
DEFENSES = ("none", "mean", "trimmed0.25", "coordinate_median",
            "norm_filter")


def _cell(p_nan: float, defense: str):
    kw = dict(fault_nan=p_nan, fault_seed=1)
    if defense != "none":
        kw.update(robust_agg=defense, min_quorum=1)
    h = bench_fl("fedadamw", **kw)
    loss = h["train_loss"][-1]
    acc = h["test_acc"][-1] if h["test_acc"] else float("nan")
    return loss, acc


def run() -> Rows:
    rows = Rows("table_faults")
    base_loss, base_acc = _cell(0.0, "none")
    for p_nan in FAULT_RATES:
        for defense in DEFENSES:
            if p_nan == 0.0 and defense != "none":
                continue                     # one fault-free baseline row
            loss, acc = _cell(p_nan, defense)
            finite = loss == loss and abs(loss) != float("inf")
            rows.add(
                p_nan=p_nan, defense=defense,
                train_loss=(round(loss, 4) if finite else "diverged"),
                test_acc=(round(acc, 4) if acc == acc else "diverged"),
                loss_vs_clean=(round(loss / base_loss, 3)
                               if finite else "diverged"),
                retention=(round(acc / base_acc, 3)
                           if acc == acc and base_acc else "diverged"))
    rows.save()
    print_table("Fault tolerance — NaN-fault rate x defense "
                f"({budget(15, 3)} rounds, vit-tiny-fl)", rows.rows)
    # the acceptance pair, machine-checkable from the saved rows
    cells = {(r["p_nan"], r["defense"]): r for r in rows.rows}
    defended = cells.get((0.1, "norm_filter"), {})
    undefended = cells.get((0.1, "none"), {})
    print(f"acceptance: norm_filter@0.1 loss_vs_clean="
          f"{defended.get('loss_vs_clean')} (want <= 1.10), "
          f"undefended@0.1 train_loss={undefended.get('train_loss')} "
          "(want diverged)")
    return rows


if __name__ == "__main__":
    run()
