"""Paper Figure 1: Local AdamW converges faster than Local SGD when
training Transformer models in FL."""
from benchmarks.common import Rows, bench_fl, print_table


def run() -> Rows:
    from benchmarks.common import budget
    rows = Rows("fig1_adamw_vs_sgd")
    # the AdamW-vs-SGD gap needs a longer horizon than the other tables
    # (the paper runs 300 rounds); give this bench 3x the round budget
    for algo in ("fedavg", "local_adam", "local_adamw"):
        h = bench_fl(algo, dirichlet=0.6, rounds=budget(24, 3),
                     local_steps=budget(10, 2))
        rows.add(algorithm="local_sgd" if algo == "fedavg" else algo,
                 final_train_loss=round(h["train_loss"][-1], 4),
                 final_test_acc=round(h["test_acc"][-1], 4))
    rows.save()
    print_table("Fig.1 — Local AdamW vs Local SGD (synthetic non-iid)",
                rows.rows)
    return rows


if __name__ == "__main__":
    run()
