"""Privacy-utility frontier: noise multiplier sweep -> (epsilon, test
accuracy), fedadamw vs fedavg (docs/privacy.md).

DP-FedAdamW (PAPERS.md) motivates the sweep: FedAdamW is only
deployable on real user populations once client updates are clipped and
noised with an accounted budget, and the interesting question is how
much utility each epsilon costs — and whether FedAdamW's advantage over
FedAvg survives the noise. Note the accounting asymmetry: FedAdamW
releases TWO aggregates per round (delta + block-mean v), so at equal
sigma it spends sqrt(2)x the budget of FedAvg's single release — the
frontier shows whether the adaptivity pays for that.

Every run goes through the PIPELINED engine (prefetch + multi-round
fusion) with the RDP accountant consuming the actual per-round cohorts;
the sigma=0 rows are the clip-only baselines (epsilon = inf).

Cohort-size reality check: per-coordinate noise on the mean is
sigma*C/S, while the clipped signal spreads C over sqrt(d) coordinates
— the noise-to-signal ratio scales like sigma*sqrt(d)/S, INDEPENDENT of
the clip. Utility at single-digit epsilon therefore needs cohorts of
hundreds+; this CPU-scale sweep runs S=16 so the frontier is visible
(the sigma where accuracy collapses), with epsilons far above
deployment targets. That is the physics, not a bug — scale S, not C.

Writes ``benchmarks/out/table_privacy.csv`` (>= 3 noise multipliers per
algorithm, accountant-computed epsilon). BENCH_QUICK=1 for a smoke pass.
"""
from __future__ import annotations

import common

ALGORITHMS = ["fedadamw", "fedavg"]
NOISE_MULTIPLIERS = [0.02, 0.1, 0.5]
DP_CLIP = 1.0
DP_DELTA = 1e-5


def main() -> None:
    rows = common.Rows("table_privacy")
    rounds = common.budget(15, 3)
    cohort = dict(num_clients=common.budget(32, 4),
                  clients_per_round=common.budget(16, 2))
    for algorithm in ALGORITHMS:
        # non-DP reference (no clip, no noise)
        ref = common.bench_fl(algorithm, rounds=rounds, dirichlet=0.1,
                              prefetch_depth=2,
                              rounds_per_call=min(3, rounds), **cohort)
        rows.add(algorithm=algorithm, dp_clip=0.0, noise_multiplier=0.0,
                 epsilon="", released_entries="",
                 final_train_loss=round(ref["train_loss"][-1], 4),
                 final_test_loss=round(ref["test_loss"][-1], 4),
                 final_test_acc=round(ref["test_acc"][-1], 4),
                 wall_s=round(ref["engine"]["wall_s"], 2))
        for sigma in [0.0] + NOISE_MULTIPLIERS:
            hist = common.bench_fl(
                algorithm, rounds=rounds, dirichlet=0.1,
                dp_clip=DP_CLIP, dp_noise_multiplier=sigma,
                dp_delta=DP_DELTA,
                prefetch_depth=2, rounds_per_call=min(3, rounds),
                **cohort)
            eps = hist["epsilon"][-1]
            rows.add(algorithm=algorithm, dp_clip=DP_CLIP,
                     noise_multiplier=sigma,
                     epsilon=("inf" if eps == float("inf")
                              else round(eps, 3)),
                     released_entries=hist["engine"]["dp"][
                         "released_entries"],
                     final_train_loss=round(hist["train_loss"][-1], 4),
                     final_test_loss=round(hist["test_loss"][-1], 4),
                     final_test_acc=round(hist["test_acc"][-1], 4),
                     wall_s=round(hist["engine"]["wall_s"], 2))
            print(f"[privacy] {algorithm:>9} sigma={sigma:>4}: "
                  f"eps={rows.rows[-1]['epsilon']} "
                  f"acc={rows.rows[-1]['final_test_acc']:.4f}")
    path = rows.save()
    common.print_table("privacy-utility frontier (epsilon vs test acc)",
                       rows.rows)
    print(f"saved {path}")


if __name__ == "__main__":
    main()
