"""Client-state store: table memory by policy + trajectory fidelity.

Two claims this table makes measurable (ISSUE 2 acceptance):

  * memory — the per-client state table (SCAFFOLD control variates / EF
    residuals) under ``dense`` vs ``blockmean`` vs ``int8`` storage, with
    the reduction factor vs dense (int8 must be >= 3.5x);
  * fidelity — final train loss of SCAFFOLD and fedadamw+int4 (EF on)
    per (policy, layout), showing the lossy policies track dense and the
    two placement layouts agree (client_sequential used to be banned for
    both algorithms).

Usage: BENCH_QUICK=1 python benchmarks/table_state_store.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, bench_fl, budget, print_table

POLICIES = ["dense", "blockmean", "int8"]
ALGORITHMS = ["scaffold", "fedadamw+int4"]
LAYOUTS = ["client_parallel", "client_sequential"]


def _table_mb():
    """Exact per-policy table bytes for the benchmark model's param tree."""
    from repro.config import FedConfig, get_arch
    from repro.config.model_config import reduced_variant
    from repro.core.partition import build_block_specs
    from repro.models import build_model
    from repro.state import store_for

    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    fed = FedConfig(num_clients=budget(16, 4))
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = build_block_specs(params, cfg, fed)
    return {p: store_for(fed, specs, policy=p).table_bytes()
            for p in POLICIES}


def run() -> Rows:
    rows = Rows("table_state_store")
    sizes = _table_mb()
    dense_b = sizes["dense"]

    final = {}
    for alg in ALGORITHMS:
        for layout in LAYOUTS:
            for policy in POLICIES:
                hist = bench_fl(alg, layout=layout,
                                client_state_policy=policy)
                final[(alg, layout, policy)] = hist["train_loss"][-1]

    for policy in POLICIES:
        row = dict(
            policy=policy,
            table_mb=round(sizes[policy] / 1e6, 4),
            reduction_vs_dense=round(dense_b / sizes[policy], 2),
        )
        for alg in ALGORITHMS:
            short = alg.replace("fedadamw", "fadamw")
            for layout in LAYOUTS:
                row[f"{short}_{layout.split('_')[1]}_loss"] = round(
                    final[(alg, layout, policy)], 4)
        rows.add(**row)

    path = rows.save()
    print_table("client-state store: memory x fidelity", rows.rows)
    assert dense_b / sizes["int8"] >= 3.5, sizes
    # layout parity on the dense policy: the sequential run must land on
    # the parallel trajectory (same clients, same batches, same noise)
    for alg in ALGORITHMS:
        a = final[(alg, "client_parallel", "dense")]
        b = final[(alg, "client_sequential", "dense")]
        assert abs(a - b) <= 0.02 * max(abs(a), 1e-9), (alg, a, b)
    print(f"int8 table reduction: {dense_b / sizes['int8']:.2f}x "
          f"(>= 3.5x required)")
    print(f"saved -> {path}")
    return rows


if __name__ == "__main__":
    run()
