"""Paper Table 4: component ablations.
A1 w/o v-bar aggregation; A2 w/o global alignment (alpha=0); A3 w/o
decoupled weight decay (coupled L2); A4 full FedAdamW."""
from benchmarks.common import Rows, bench_fl, print_table


def run() -> Rows:
    rows = Rows("table4_ablation")
    variants = [
        ("A1_no_v_agg", dict(v_aggregation="none")),
        ("A2_no_global_align", dict(alpha=0.0)),
        ("A3_coupled_wd", dict(decoupled_wd=False)),
        ("A4_full", dict()),
    ]
    for name, extra in variants:
        # extra FedConfig fields ride through run_training via fed overrides
        h = bench_fl("fedadamw", dirichlet=0.1, **_as_overrides(extra))
        rows.add(variant=name, test_acc=round(h["test_acc"][-1], 4),
                 train_loss=round(h["train_loss"][-1], 4))
    rows.save()
    print_table("Table 4 — ablations (Dir-0.1)", rows.rows)
    return rows


def _as_overrides(extra):
    out = {}
    if "v_aggregation" in extra:
        out["v_aggregation"] = extra["v_aggregation"]
    if "alpha" in extra:
        out["alpha"] = extra["alpha"]
    if "decoupled_wd" in extra:
        out["decoupled_wd"] = extra["decoupled_wd"]
    return out


if __name__ == "__main__":
    run()
